"""On-demand ``jax.profiler`` device-trace capture for a round window.

``--profile-rounds A:B`` captures a profiler trace for exactly rounds A..B
(inclusive, 0-indexed round numbers as the launchers log them): the trace
starts before round A's dispatch and stops after round B completes, so the
capture holds whole rounds -- XLA device timelines, host/device transfer
lanes, and (on TPU) the per-kernel breakdown -- viewable in Perfetto or
TensorBoard's profile plugin.

Why a WINDOW and not the whole run: the profiler's overhead and trace size
are per-event, so profiling a 10^4-round job is both slow and unreadable;
two or three steady-state rounds after compilation has settled is what the
popstore/async tuning work actually needs.

Zero cost when unset: ``RoundProfiler.parse(None, ...)`` returns None and
the launchers guard every call site on that.
"""
from __future__ import annotations

import os
import pathlib
import warnings
from typing import Optional


class RoundProfiler:
    """Start/stop ``jax.profiler`` around a [start, stop] round window.

    The launcher calls ``before_round(r)`` ahead of each dispatch and
    ``after_round(r)`` once the round's results are materialised; ``close``
    is the crash/early-exit backstop (a trace left open at process exit is
    truncated and unreadable)."""

    def __init__(self, start: int, stop: int, out_dir: str | os.PathLike):
        if start < 0 or stop < start:
            raise ValueError(
                f"--profile-rounds window must be 0 <= A <= B, got {start}:{stop}")
        self.start = start
        self.stop = stop
        self.out_dir = str(out_dir)
        self.active = False
        self.captured = False

    @classmethod
    def parse(cls, spec: Optional[str],
              out_dir: str | os.PathLike) -> Optional["RoundProfiler"]:
        """``"A:B"`` -> profiler for rounds A..B; ``"A"`` -> just round A;
        None/"" -> None (profiling off)."""
        if not spec:
            return None
        parts = str(spec).split(":")
        try:
            if len(parts) == 1:
                a = b = int(parts[0])
            elif len(parts) == 2:
                a, b = int(parts[0]), int(parts[1])
            else:
                raise ValueError(spec)
        except ValueError:
            raise ValueError(
                f"--profile-rounds expects 'A:B' or 'A' (round numbers), "
                f"got {spec!r}") from None
        return cls(a, b, out_dir)

    def before_round(self, round_idx: int) -> None:
        if self.active or self.captured or round_idx < self.start:
            return
        if round_idx > self.stop:
            return  # window already passed (e.g. resumed beyond it)
        import jax

        pathlib.Path(self.out_dir).mkdir(parents=True, exist_ok=True)
        try:
            jax.profiler.start_trace(self.out_dir)
        except Exception as e:  # profiler backend unavailable: degrade loudly
            warnings.warn(f"[telemetry] jax.profiler capture unavailable: {e}",
                          RuntimeWarning, stacklevel=2)
            self.captured = True
            return
        self.active = True
        print(f"[telemetry] jax.profiler capture started at round "
              f"{round_idx} -> {self.out_dir}", flush=True)

    def after_round(self, round_idx: int) -> None:
        if self.active and round_idx >= self.stop:
            self._stop()

    def _stop(self) -> None:
        import jax

        try:
            jax.profiler.stop_trace()
        except Exception as e:
            warnings.warn(f"[telemetry] jax.profiler stop failed: {e}",
                          RuntimeWarning, stacklevel=2)
        else:
            print(f"[telemetry] jax.profiler capture written to "
                  f"{self.out_dir}", flush=True)
        self.active = False
        self.captured = True

    def close(self) -> None:
        if self.active:
            self._stop()
