"""Optional-hypothesis shim: ``from _hyp import given, settings, st``.

When hypothesis is installed (the ``[test]`` extra) this re-exports the real
``given``/``settings``/``strategies``.  When it is not, the stand-ins turn
every ``@given(...)`` test into a zero-argument test that calls
``pytest.importorskip("hypothesis")`` -- so the property-based blocks skip
cleanly instead of erroring the whole module at collection time.
"""
from __future__ import annotations

import functools

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        def deco(f):
            # zero-arg replacement: pytest must not treat the strategy
            # parameters as fixtures, and the skip must fire at run time
            @functools.wraps(f)
            def _skipped():
                pytest.importorskip("hypothesis")

            # wraps() copies __wrapped__/__doc__ but the signature pytest
            # introspects is the replacement's (no args), which is the point
            del _skipped.__wrapped__
            return _skipped

        return deco

    class _Strategies:
        """Attribute sink so module-level strategy expressions still parse."""

        @staticmethod
        def composite(f):
            return lambda *a, **k: None

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
