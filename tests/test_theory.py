"""Convergence-theory checks: Theorem 1 (linear rate via the Q^r Lyapunov
functional) and Theorem 2 (sublinear trend for mu = 0)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederatedConfig
from repro.core import arena, make, quadratic, theory
from repro.core import tree_util as T
from repro.core.api import resolved_rho


@pytest.fixture(scope="module")
def prob():
    return quadratic.generate(jax.random.key(3), m=6, n=80, d=16)


def test_beta_bound_valid(prob):
    eta = 0.5 / prob.L
    rho = 1.0 / (5 * eta)
    beta = theory.gpdmm_beta(prob.L, prob.mu, eta, rho)
    assert 0.0 < beta < 1.0


def test_q_functional_linear_decay(prob):
    """Q^{r+1} <= beta Q^r along a real GPDMM trajectory (Theorem 1)."""
    K = 5
    eta = 0.5 / prob.L
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=eta, use_avg=True)
    rho = resolved_rho(cfg)
    theta = phi = 0.5
    beta = theory.gpdmm_beta(prob.L, prob.mu, eta, rho, theta, phi)

    opt = make(cfg)
    x0 = jnp.zeros((prob.d,))
    s = opt.init(x0, prob.m)
    lam_star = prob.lam_star()

    qs = []
    # x_i^{0,K} = x_s^1 = x0 (Alg. 1); built here rather than read from the
    # state, whose client half is arena-resident on the default path
    x_c_prev = T.tree_broadcast(x0, prob.m)
    for r in range(25):
        s, metrics = opt.round(s, prob.grad, prob.batch(), return_trace=True)
        tr = metrics["trace"]
        q = theory.q_functional(
            cfg,
            x_c_prev=x_c_prev,
            x_bar=tr["x_bar"],
            lam_is=tr["lam_is"],
            x_star=prob.x_star,
            lam_star=lam_star,
            L=prob.L,
            mu=prob.mu,
            theta=theta,
            phi=phi,
        )
        qs.append(float(q))
        x_c_prev = tr["x_K"]

    qs = np.asarray(qs)
    ratios = qs[1:] / np.maximum(qs[:-1], 1e-30)
    # Theorem 1: every ratio <= beta (tiny numerical slack)
    assert np.all(ratios <= beta + 1e-3), (ratios.max(), beta)
    # and Q decays by orders of magnitude overall
    assert qs[-1] < qs[0] * beta ** (len(qs) - 1) * 10


def test_kkt_residuals_vanish(prob):
    # use_arena=True (not the "auto" default, which keeps this paper-scale
    # width on the pytree path): this test also guards the arena KKT maths
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=5, eta=0.5 / prob.L,
                          use_arena=True)
    opt = make(cfg)
    s = opt.init(jnp.zeros((prob.d,)), prob.m)
    rf = jax.jit(lambda s: opt.round(s, prob.grad, prob.batch())[0])
    for _ in range(300):
        s = rf(s)
    # lam_s is arena-resident (m, width) on this path; unpack it
    spec = arena.ArenaSpec.from_tree(s["x_s"])
    res = theory.kkt_residuals(prob, s["x_s"], spec.unpack_stacked(s["lam_s"]))
    assert float(res["dual_sum"]) < 1e-3
    assert float(res["primal_gap"]) < 1e-2
    assert float(res["grad_match"]) < 1e-1


def test_sublinear_general_convex():
    """mu = 0 (rank-deficient clients): the running-average optimality gap
    trends like O(1/R) -- gap(2R) <~ 0.7 * gap(R)."""
    key = jax.random.key(7)
    m, n, d = 4, 10, 24  # n < d: each client is rank-deficient => mu = 0
    A = jax.random.normal(key, (m, n, d))
    y0 = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    b = jnp.einsum("mnd,d->mn", A, y0)
    AtA = jnp.einsum("mnd,mne->mde", A, A)
    Atb = jnp.einsum("mnd,mn->md", A, b)
    H, g = AtA.sum(0), Atb.sum(0)
    # minimum-norm solution for the singular system
    x_star = jnp.linalg.pinv(H) @ g
    f_star = 0.5 * x_star @ H @ x_star - g @ x_star + 0.5 * jnp.einsum("mn,mn->", b, b)
    L = float(jnp.linalg.eigvalsh(AtA).max())

    def gap(x):
        return float(0.5 * x @ H @ x - g @ x + 0.5 * jnp.einsum("mn,mn->", b, b) - f_star)

    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=3, eta=0.5 / L)
    opt = make(cfg)
    s = opt.init(jnp.zeros((d,)), m)
    batch = {"AtA": AtA, "Atb": Atb}
    grad = lambda x, cb: cb["AtA"] @ x - cb["Atb"]  # noqa: E731
    gaps = {}
    rf = jax.jit(lambda s: opt.round(s, grad, batch)[0])
    for r in range(1, 241):
        s = rf(s)
        if r in (60, 120, 240):
            gaps[r] = gap(opt.server_params(s))
    assert gaps[120] < 0.75 * gaps[60] + 1e-12, gaps
    assert gaps[240] < 0.75 * gaps[120] + 1e-12, gaps
