"""GPDMM (Algorithm 1, Zhang et al. 2021): gradient-based PDMM for a
centralised network, one transmitted variable per direction per round.

Per round r (client i, K inner steps, rho = 1/(K eta) by default):

    x_i^{r,0}   = x_i^{r-1,K}                        (carry, NOT x_s - lam/rho:
                                                      the Inexact-FedSplit fix)
    x_i^{r,k+1} = x_i^{r,k} - (1/(1/eta+rho)) [grad f_i(x_i^{r,k})
                                               + rho (x_i^{r,k} - x_s^r)
                                               + lam_{s|i}^r]        (eq. 20)
    lam_{i|s}^{r+1} = rho (x_s^r - xref_i) - lam_{s|i}^r             (eq. 23/24)
    uplink   u_i   = xref_i - lam_{i|s}^{r+1} / rho                 (ONE var)
    x_s^{r+1}      = mean_i u_i                                      (all-reduce)
    lam_{s|i}^{r+1} = rho (xref_i - x_s^{r+1}) - lam_{i|s}^{r+1}     (local)

where xref_i = mean_k x_i^{r,k} (eq. 23, Alg. 1) or x_i^{r,K} (eq. 24,
Remark 1) when ``use_avg=False``.

Communication note (recorded in EXPERIMENTS.md): in the SPMD mapping the
uplink-mean is one all-reduce of a single parameter-sized tensor; the downlink
combination x_s - lam_{s|i}/rho is reconstructed client-locally, so GPDMM's
1-variable-per-direction claim is exactly one collective per round.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import tree_util as T
from repro.core.api import FedOpt, resolved_rho
from repro.kernels import ops


def inner_steps(grad_fn, x0, x_s_b, lam_s, batch, *, K, eta, rho, per_step,
                vr_snapshot=None):
    """Runs the K inexact-PDMM client steps (shared by GPDMM/AGPDMM).

    x0, x_s_b, lam_s: stacked (m, ...) pytrees.  Returns (x_K, x_bar).

    ``vr_snapshot`` (beyond paper; requires ``per_step`` batches): SVRG-style
    variance reduction in the stochastic setting the paper names as future
    work (SSVII), following [14]'s PDMM+SVRG for P2P networks.  With snapshot
    z (the round's fresh server estimate) the step-k gradient becomes

        g_k(x) - g_k(z) + mean_j g_j(z)

    -- unbiased, with variance -> 0 as x -> z, restoring the deterministic
    rates under minibatch noise at the cost of 2x gradient evals per step
    plus one pass at the snapshot.
    """
    step_c = 1.0 / (1.0 / eta + rho)
    vgrad = jax.vmap(grad_fn)

    gbar = None
    if vr_snapshot is not None:
        assert per_step, "SVRG needs per-step minibatches (K, m, ...)"
        # full-pass gradient at the snapshot: mean over the K step batches
        snap_grads = jax.lax.map(lambda b: vgrad(vr_snapshot, b), batch)
        gbar = T.tmap(lambda t: jnp.mean(t, axis=0), snap_grads)

    def one_step(carry, xs_k):
        x, xsum = carry
        b = xs_k if per_step else batch
        g = vgrad(x, b)
        if gbar is not None:
            g_snap = vgrad(vr_snapshot, b)
            g = T.tmap(lambda a, c, d: a - c + d, g, g_snap, gbar)
        x_new = T.tmap(
            lambda xx, gg, ss, ll: ops.fused_update(xx, gg, ss, ll, step_c, rho),
            x, g, x_s_b, lam_s,
        )
        return (x_new, T.tree_add(xsum, x_new)), None

    init = (x0, T.tree_zeros_like(x0))
    if per_step:
        (x_K, xsum), _ = jax.lax.scan(one_step, init, batch)
    else:
        (x_K, xsum), _ = jax.lax.scan(one_step, init, None, length=K)
    return x_K, T.tree_scale(xsum, 1.0 / K)


def _round(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches=False, return_trace=False):
    rho = resolved_rho(cfg)
    K = cfg.inner_steps
    x_s, lam_s, x_c = state["x_s"], state["lam_s"], state["x_c"]
    m = jax.tree.leaves(lam_s)[0].shape[0]
    x_s_b = T.tree_broadcast(x_s, m)

    x_K, x_bar = inner_steps(
        grad_fn, x_c, x_s_b, lam_s, batch, K=K, eta=cfg.eta, rho=rho,
        per_step=per_step_batches,
        vr_snapshot=x_s_b if cfg.variance_reduction == "svrg" else None,
    )
    x_ref = x_bar if cfg.use_avg else x_K

    lam_is = T.tmap(lambda s, xr, l: rho * (s - xr) - l, x_s_b, x_ref, lam_s)
    uplink = T.tmap(lambda xr, l: xr - l / rho, x_ref, lam_is)
    new_state = {}
    mask = None
    if cfg.uplink_bits is not None:  # beyond-paper: EF21 delta-quantised uplink
        uplink = T.tree_quantize_delta(uplink, state["u_hat"], cfg.uplink_bits)
    if cfg.participation < 1.0:  # beyond-paper: async PDMM (partial rounds)
        mask = T.participation_mask(
            jax.random.fold_in(jax.random.key(17), state["round"]), m, cfg.participation
        )
        # silent clients transmit nothing; the server keeps its cached view
        uplink = T.tree_select(mask, uplink, state["u_hat"])
    if cfg.uplink_bits is not None or cfg.participation < 1.0:
        new_state["u_hat"] = uplink  # the server's per-client view
    x_s_new = T.tree_client_mean(uplink)  # <- the round's single all-reduce
    x_s_new_b = T.tree_broadcast(x_s_new, m)
    # lam_{s|i}^{r+1} = rho (x_ref - x_s) - lam_{i|s} == rho (u_i - x_s):
    # reconstructed from the TRANSMITTED uplink, so the quantised variant
    # stays faithful to what a real server would see (it cannot separate
    # x_ref from lam_{i|s} inside u_i).
    lam_s_new = T.tmap(lambda u, s: rho * (u - s), uplink, x_s_new_b)

    # silent clients did not really run their inner steps: keep their carry
    x_c_new = x_K if mask is None else T.tree_select(mask, x_K, x_c)
    new_state |= {"x_s": x_s_new, "lam_s": lam_s_new, "x_c": x_c_new, "round": state["round"] + 1}
    metrics = {
        # KKT invariant (25): sum_i lam_{s|i} == 0 identically
        "lam_sum_norm": T.tree_norm(T.tree_client_sum(lam_s_new)),
        "client_drift": jnp.mean(T.tree_client_sqnorms(T.tree_sub(x_K, x_s_b))),
    }
    if return_trace:  # quantities the convergence-theory checks need
        metrics["trace"] = {"x_ref": x_ref, "x_bar": x_bar, "lam_is": lam_is, "x_K": x_K}
    return new_state, metrics


def make(cfg: FederatedConfig) -> FedOpt:
    def init(params, m):
        st = {
            "x_s": params,
            "lam_s": T.tree_zeros_like(T.tree_broadcast(params, m)),
            "x_c": T.tree_broadcast(params, m),  # x_i^{0,K} = x_s^1 (Alg. 1)
            "round": jnp.zeros((), jnp.int32),
        }
        if cfg.uplink_bits is not None or cfg.participation < 1.0:
            # server's running view of each client's uplink (EF21 integrator /
            # async-PDMM cache); init == round-0 uplink x_c - 0/rho
            st["u_hat"] = st["x_c"]
        return st

    return FedOpt(
        name="gpdmm",
        init=init,
        round=partial(_round, cfg),
        server_params=lambda s: s["x_s"],
    )
