"""Jit-ready kernel wrappers with implementation dispatch.

``impl`` selects the backend:
  * ``"xla"``              -- chunked pure-jnp path (default; what the CPU
                              dry-run and the smoke tests lower)
  * ``"pallas"``           -- Pallas TPU kernel (the deployment target)
  * ``"pallas_interpret"`` -- Pallas kernel body interpreted on CPU; used by
                              the kernel test-suite to validate the TPU code.

The global default can be set once via ``set_default_impl`` (the launcher does
this based on ``jax.default_backend()``).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref


def _step_arr(step):
    """None for a host scalar step (the baked-constant kernel path, bitwise
    unchanged from before per-client stepsizes existed); a (m,) f32 array for
    the per-client auto-eta path (``core.autotune``), fed to the kernels as a
    per-client stepsize OPERAND instead of a baked constant."""
    if np.ndim(step) == 0:
        return None
    return jnp.asarray(step, jnp.float32)

_DEFAULT_IMPL = "xla"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "pallas_interpret")
    _DEFAULT_IMPL = impl


def _resolve(impl: Optional[str]) -> str:
    return impl or _DEFAULT_IMPL


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _flash_xla(q, k, v, q_pos, k_pos, *, causal, window, q_chunk, k_chunk, causal_skip):
    """Chunked online-softmax attention (memory O(q_chunk * k_chunk)).

    Outer python loop over q chunks (so ``causal_skip`` can shrink the k range
    statically per chunk -- that halves causal FLOPs); inner ``lax.scan`` over
    k chunks carrying the online-softmax accumulators.
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0, (Sq, q_chunk, Sk, k_chunk)
    nq = Sq // q_chunk

    q5 = q.reshape(B, Sq, Hkv, G, hd)
    out_chunks = []
    for i in range(nq):
        qi = jax.lax.dynamic_slice_in_dim(q5, i * q_chunk, q_chunk, axis=1)
        qpi = jax.lax.dynamic_slice_in_dim(q_pos, i * q_chunk, q_chunk, axis=0)
        lo, hi = 0, Sk
        if causal_skip and causal:
            # static bounds: this q chunk covers absolute q positions
            # [i*q_chunk, (i+1)*q_chunk) when q_pos is an arange (train or
            # full prefill); key positions beyond hi are always masked.
            hi = min(Sk, _ceil_to((i + 1) * q_chunk, k_chunk))
            if window is not None:
                lo = max(0, ((i * q_chunk - window) // k_chunk) * k_chunk)
        nk = (hi - lo) // k_chunk
        ks = jax.lax.dynamic_slice_in_dim(k, lo, hi - lo, axis=1).reshape(B, nk, k_chunk, Hkv, hd)
        vs = jax.lax.dynamic_slice_in_dim(v, lo, hi - lo, axis=1).reshape(B, nk, k_chunk, Hkv, vd)
        kps = jax.lax.dynamic_slice_in_dim(k_pos, lo, hi - lo, axis=0).reshape(nk, k_chunk)

        def kv_step(carry, inp, qi=qi, qpi=qpi):
            m, l, acc = carry
            kj, vj, kpj = inp  # (B,kc,Hkv,hd), (B,kc,Hkv,vd), (kc,)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qi.astype(jnp.float32), kj.astype(jnp.float32)
            ) * scale
            valid = kpj[None, :] >= 0
            if causal:
                valid = valid & (kpj[None, :] <= qpi[:, None])
            if window is not None:
                valid = valid & (kpj[None, :] > qpi[:, None] - window)
            s = jnp.where(valid[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhv->bhgqv", p, vj.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, vd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), kps),
        )
        o = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,G,qc,vd)
        out_chunks.append(jnp.moveaxis(o, 3, 1).reshape(B, q_chunk, H, vd))
    return jnp.concatenate(out_chunks, axis=1).astype(q.dtype)


def flash_attention(
    q,
    k,
    v,
    q_pos,
    k_pos,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    causal_skip: bool = True,
    impl: Optional[str] = None,
):
    """Causal (optionally sliding-window) GQA attention.

    q (B,Sq,H,hd); k (B,Sk,Hkv,hd); v (B,Sk,Hkv,vd); positions as in
    ``ref.attention_ref``.
    """
    impl = _resolve(impl)
    if impl == "xla":
        return _flash_xla(
            q, k, v, q_pos, k_pos,
            causal=causal, window=window,
            q_chunk=q_chunk, k_chunk=k_chunk, causal_skip=causal_skip,
        )
    from repro.kernels import flash_attention as fa

    return fa.flash_attention_pallas(
        q, k, v, q_pos, k_pos,
        causal=causal, window=window,
        interpret=(impl == "pallas_interpret"),
    )


def attend_cache(q, k_cache, v_cache, q_pos, k_pos, *, window: Optional[int] = None):
    """Single-token decode attention against a (possibly ring-buffer) cache.

    q: (B, 1, H, hd); caches (B, S, Hkv, hd/vd); q_pos scalar int; k_pos (S,).
    """
    B, _, H, hd = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32)) / math.sqrt(hd)
    valid = (k_pos >= 0) & (k_pos <= q_pos)
    if window is not None:
        valid = valid & (k_pos > q_pos - window)
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhv->bhgv", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# rwkv6 chunked wkv
# ---------------------------------------------------------------------------

def _wkv6_chunked_xla(r, k, v, w, u, s0, *, chunk: int):
    """Chunked-parallel WKV6: O(S/C * C^2) intra-chunk matmuls + O(S/C) state
    updates, mathematically identical to the sequential recurrence.

    Let la_t = sum_{tau<=t} log w_tau (within chunk; la_0 = 0 at chunk start).
      y_t   = (r_t * exp(la_{t-1})) @ S_0
            + sum_{tau<t} [(r_t * exp(la_{t-1} - la_tau)) . k_tau] v_tau
            + (r_t . u . k_t) v_t
      S_C   = diag(exp(la_C)) S_0 + sum_tau diag(exp(la_C - la_tau)) k_tau v_tau^T
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))  # (B,S,H,K)
    uf = u.astype(jnp.float32)

    def chunk_step(s, inp):
        rc, kc, vc, lwc = inp  # (B,C,H,K) etc.
        la = jnp.cumsum(lwc, axis=1)  # (B,C,H,K), inclusive
        la_prev = la - lwc  # exclusive cumsum: sum_{tau < t}
        # inter-chunk: contribution of carried state (la_prev <= 0, exp safe)
        r_dec = rc * jnp.exp(la_prev)
        y_inter = jnp.einsum("bchk,bhkv->bchv", r_dec, s)
        # intra-chunk: pairwise decay exp(la_prev[t] - la[tau]) for tau < t.
        # Computed as a clamped pairwise difference -- the two factors
        # exp(la_prev) * exp(-la) can individually overflow even though the
        # product is <= 1 for tau < t.
        diff = la_prev[:, :, None] - la[:, None, :]  # (B, t, tau, H, K)
        dec = jnp.exp(jnp.minimum(diff, 0.0))
        att = jnp.einsum("bthk,bchk,btchk->bhtc", rc, kc, dec)
        t_idx = jnp.arange(chunk)
        mask = t_idx[:, None] > t_idx[None, :]
        att = jnp.where(mask[None, None], att, 0.0)
        bonus = jnp.einsum("bthk,bthk->bth", rc * uf[None, None], kc)
        y = y_inter + jnp.einsum("bhtc,bchv->bthv", att, vc) + bonus[..., None] * vc
        # state update
        la_end = la[:, -1:]  # (B,1,H,K)
        dec_k = kc * jnp.exp(la_end - la)  # decay from tau to chunk end
        s_new = jnp.exp(la_end[:, 0])[..., None] * s + jnp.einsum("bchk,bchv->bhkv", dec_k, vc)
        return s_new, y

    resh = lambda a: jnp.moveaxis(a.reshape(B, n, chunk, H, -1), 1, 0)
    s_final, ys = jax.lax.scan(
        chunk_step, s0.astype(jnp.float32), (resh(rf), resh(kf), resh(vf), resh(lw))
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, V)
    return y.astype(r.dtype), s_final


def wkv6(r, k, v, w, u, s0, *, chunk: int = 64, impl: Optional[str] = None):
    """RWKV-6 recurrence. Shapes as in ``ref.wkv6_ref``."""
    impl = _resolve(impl)
    if impl == "xla":
        return _wkv6_chunked_xla(r, k, v, w, u, s0, chunk=chunk)
    from repro.kernels import wkv6 as wk

    return wk.wkv6_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=(impl == "pallas_interpret"))


def wkv6_step(r1, k1, v1, w1, u, s):
    """Single decode step. r1,k1,w1: (B,H,K); v1: (B,H,V); s: (B,H,K,V)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r1, k1, v1, w1))
    sf = s.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", rf, sf + u.astype(jnp.float32)[None, :, :, None] * kv)
    s_new = wf[..., :, None] * sf + kv
    return y.astype(r1.dtype), s_new


# ---------------------------------------------------------------------------
# fused federated client update
# ---------------------------------------------------------------------------

def fused_update(x, g, xs, lam, step, rho, *, impl: Optional[str] = None,
                 block: Optional[int] = None):
    """Fused federated inner step (paper eq. (20)); see ``ref.fused_update_ref``.

    The Pallas kernel fuses 4 HBM reads + 1 write into one pass -- the client
    inner loop is memory-bound, so unfused XLA would read/write 6 arrays.
    ``block=None`` resolves to the single module-wide default
    (``fused_update.BLOCK_ROWS``), checked against the VMEM budget.

    ``step`` is a scalar or a per-client array already broadcastable against
    ``x`` (the pytree tmap path reshapes a (m,) stepsize to (m, 1, ..) per
    leaf); the array form rides the pure-jnp reference -- the per-leaf pytree
    layout is not the per-client-eta deployment path, the arena is.
    """
    impl = _resolve(impl)
    if impl == "xla" or _step_arr(step) is not None:
        return _ref.fused_update_ref(x, g, xs, lam, step, rho)
    from repro.kernels import fused_update as fu

    return fu.fused_update_pallas(
        x, g, xs, lam, step, rho, block=block or fu.BLOCK_ROWS,
        interpret=(impl == "pallas_interpret"),
    )


# ---------------------------------------------------------------------------
# fused round tail over the flat client-state arena (core.arena layout:
# (m, width) client buffers, (width,) server rows, width % 128 == 0)
# ---------------------------------------------------------------------------

def fused_update_arena(x, g, x_s, lam, step, rho, *, impl: Optional[str] = None,
                       block: Optional[int] = None):
    """Eq. (20) inner step over the whole packed arena: x, g (m, width);
    lam (m, width) or None (dual term dropped -- SCAFFOLD/FedAvg's rho = 0
    plain steps); x_s (width,) server row broadcast in-kernel (never
    materialised in HBM).  ONE kernel launch per inner step instead of one
    per pytree leaf.

    ``step``: scalar (baked into the kernel -- bitwise the pre-auto-eta
    graph) or (m,) per-client stepsizes (``core.autotune``), fed to the
    kernel as a broadcast row operand."""
    impl = _resolve(impl)
    step_a = _step_arr(step)
    if impl == "xla":
        step_b = step if step_a is None else step_a[:, None]
        return _ref.fused_update_ref(
            x, g, x_s[None] if x_s.ndim == 1 else x_s, lam, step_b, rho)
    from repro.kernels import round_tail as rt

    return rt.fused_update_arena_pallas(
        x, g, x_s, lam, step if step_a is None else step_a, rho,
        block=block, interpret=(impl == "pallas_interpret")
    )


def inner_loop_affine(x0, H, c, x_s, lam, step, rho, K: int, *,
                      off=None, impl: Optional[str] = None):
    """The WHOLE K-step eq. (20) inner loop for affine gradient oracles
    (grad_i(x) = H_i x - (c_i + off_i) in arena coordinates): one kernel
    keeps each client's row block + H in VMEM across all K steps -- 1 HBM
    read + 1 write of the client state for the whole loop instead of K round
    trips.

    x0, c: (m, W); H: (m, W, W); x_s: (W,).  ``lam=None`` drops the dual
    operand (SCAFFOLD/FedAvg run rho = 0 with no dual); ``off`` is the
    optional per-client offset row added to the affine constant -- the
    SCAFFOLD control-variate buffer rides here with zero extra HBM
    materialisation.  Returns (x_K, x_bar).  Callers must gate on
    ``affine_inner_fits(W)`` (the VMEM budget).

    ``step``: scalar (baked -- bitwise the pre-auto-eta kernel) or (m,)
    per-client stepsizes fed as a row operand (``core.autotune``).
    """
    impl = _resolve(impl)
    step_a = _step_arr(step)
    if impl == "xla":
        f32 = jnp.float32
        step_b = step if step_a is None else step_a[:, None]
        x_s_b = x_s.astype(f32)[None]
        lam_f = lam.astype(f32) if lam is not None else None
        Hf, cf = H.astype(f32), c.astype(f32)
        if off is not None:
            cf = cf + off.astype(f32)

        def body(carry, _):
            x, xsum = carry
            g = jnp.einsum("mij,mj->mi", Hf, x) - cf
            acc = g + rho * (x - x_s_b)
            if lam_f is not None:
                acc = acc + lam_f
            x = x - step_b * acc
            return (x, xsum + x), None

        init = (x0.astype(f32), jnp.zeros_like(x0, f32))
        (x_K, xsum), _ = jax.lax.scan(body, init, None, length=K)
        return x_K.astype(x0.dtype), (xsum * (1.0 / K)).astype(x0.dtype)
    from repro.kernels import inner_loop as il

    return il.inner_loop_affine_pallas(
        x0, H, c, x_s, lam, step if step_a is None else step_a, rho, K,
        off=off, interpret=(impl == "pallas_interpret")
    )


def scaffold_cv(c_i, x_K, c_s, x_s, alpha, *, impl: Optional[str] = None,
                block: Optional[int] = None):
    """SCAFFOLD eq. (30) control-variate refresh, fused into one pass:

        c_i' = c_i - c + alpha (x_s - x_K)          (alpha = 1/(K eta))

    c_i, x_K: (m, width) client buffers; c_s, x_s: (width,) server rows
    broadcast in-kernel.  2 client reads + 1 write instead of the ~5-pass
    per-leaf tmap chain (which additionally materialises both server
    broadcasts at (m, width)).

    ``alpha``: scalar (baked) or (m,) per-client 1/(K eta_i) under auto-eta
    (``core.autotune``), fed as a row operand."""
    impl = _resolve(impl)
    alpha_a = _step_arr(alpha)
    if impl == "xla":
        f32 = jnp.float32
        alpha_b = alpha if alpha_a is None else alpha_a[:, None]
        out = (c_i.astype(f32) - c_s.astype(f32)[None]
               + alpha_b * (x_s.astype(f32)[None] - x_K.astype(f32)))
        return out.astype(c_i.dtype)
    from repro.kernels import round_tail as rt

    return rt.scaffold_cv_pallas(
        c_i, x_K, c_s, x_s, alpha if alpha_a is None else alpha_a,
        block=block, interpret=(impl == "pallas_interpret")
    )


def affine_inner_fits(width: int) -> bool:
    """Static VMEM gate for ``inner_loop_affine`` (see ``inner_loop.vmem_bytes``)."""
    from repro.kernels import inner_loop as il

    return il.fits_vmem(width)


def round_tail(x_ref, lam_s, x_s, rho, *, with_lam_is: bool = True,
               impl: Optional[str] = None, block: Optional[int] = None):
    """Fused dual flip + uplink (eqs. 23/24 + Alg. 1 line 8):

        lam_is = rho (x_s - x_ref) - lam_s
        uplink = x_ref - lam_is / rho

    3 HBM reads + 2 writes in one pass instead of ~4 separate passes.
    x_ref, lam_s: (m, width); x_s: (width,).  Returns (lam_is, uplink);
    ``with_lam_is=False`` (the non-trace training path -- callers discard
    lam_is) skips the lam_is output: 3 reads + 1 write, returns (None, u)."""
    impl = _resolve(impl)
    if impl == "xla":
        xr = x_ref.astype(jnp.float32)
        lam = lam_s.astype(jnp.float32)
        xs = x_s.astype(jnp.float32)[None]
        lam_is = rho * (xs - xr) - lam
        uplink = (xr - lam_is / rho).astype(x_ref.dtype)
        return (lam_is.astype(x_ref.dtype) if with_lam_is else None), uplink
    from repro.kernels import round_tail as rt

    return rt.round_tail_pallas(
        x_ref, lam_s, x_s, rho, with_lam_is=with_lam_is, block=block,
        interpret=(impl == "pallas_interpret"),
    )


def dual_from_uplink(uplink, x_s, rho, *, impl: Optional[str] = None,
                     block: Optional[int] = None):
    """lam_s' = rho (u - x_s') -- the post-all-reduce dual refresh; one pass."""
    impl = _resolve(impl)
    if impl == "xla":
        out = rho * (uplink.astype(jnp.float32) - x_s.astype(jnp.float32)[None])
        return out.astype(uplink.dtype)
    from repro.kernels import round_tail as rt

    return rt.dual_from_uplink_pallas(
        uplink, x_s, rho, block=block, interpret=(impl == "pallas_interpret")
    )


def screen_uplink(u, ref, *, impl: Optional[str] = None,
                  block: Optional[int] = None):
    """Fused uplink screening (robustness layer): per-client finite flags
    and squared deviations in ONE pass over the (m, width) uplink buffer.

        finite_i = every entry of u_i is finite
        sq_i     = sum over the FINITE entries of (u_i - ref)^2

    The deviation excludes non-finite entries (the flag already demotes
    those rows), so sq is always finite and comparable across backends.
    ``ref``: (width,) broadcast downlink row -- deviation from x_s catches
    sign flips, which a plain norm cannot -- or (m, width) per-row
    reference (graph rounds screen each node against its own carry).
    Returns ``(finite (m,) bool, sq (m,) f32)``.
    """
    impl = _resolve(impl)
    if impl == "xla":
        uf = u.astype(jnp.float32)
        rf = ref.astype(jnp.float32)
        if rf.ndim == 1:
            rf = rf[None]
        fin_e = jnp.isfinite(uf)
        d = jnp.where(fin_e, uf - rf, 0.0)
        return jnp.all(fin_e, axis=1), jnp.sum(d * d, axis=1)
    from repro.kernels import screen as sk

    return sk.screen_uplink_pallas(
        u, ref, block=block, interpret=(impl == "pallas_interpret"))


def residual_norm(x, x_prev, *, impl: Optional[str] = None,
                  block: Optional[int] = None):
    """Fused fixed-point residual norms (the early-termination criterion,
    ``core.autotune``): ONE pass over the (m, width) client-state arena and
    its previous-round snapshot emitting, per client row,

        dx2_i = ||x_i - x_prev_i||^2        (fixed-point residual)
        x2_i  = ||x_i||^2                   (normaliser)

    so the driver can evaluate pfb-clean's relative stopping rule
    ``||x - x_prev|| / ||x|| < tol`` without a second read of either buffer.
    Returns ``(dx2 (m,) f32, x2 (m,) f32)``; all math in f32.
    """
    impl = _resolve(impl)
    if impl == "xla":
        xf = x.astype(jnp.float32)
        d = xf - x_prev.astype(jnp.float32)
        return jnp.sum(d * d, axis=1), jnp.sum(xf * xf, axis=1)
    from repro.kernels import residual as rs

    return rs.residual_norm_pallas(
        x, x_prev, block=block, interpret=(impl == "pallas_interpret"))


def stale_mix(uplink, cache, buf, fresh, store, w, *, impl: Optional[str] = None,
              block: Optional[int] = None):
    """Fused stale-uplink admission mix (bounded-staleness engine, ISSUE 7):
    ONE pass over the uplink + stale-buffer arenas emitting the round's
    mixed contribution rows and the updated stale buffer.

        base_i  = uplink_i if fresh_i else cache_i      (today's masked select)
        mixed_i = base_i + w_i (buf_i - base_i) if w_i > 0 else base_i
        buf'_i  = uplink_i if store_i else buf_i

    ``cache``: (width,) broadcast server row or (m, width) per-client cache.
    The ``w_i > 0`` guard keeps the w = 0 rows BITWISE equal to the plain
    select (no -0.0 flips, no 0 * non-finite NaNs), which is what collapses
    ``max_staleness=0`` to the synchronous masked round exactly.  The mix
    arithmetic runs in f32 and casts back, matching the pallas kernel.
    Returns ``(mixed, buf_new)``.
    """
    impl = _resolve(impl)
    if impl == "xla":
        cache2 = cache if cache.ndim == 2 else cache[None]
        base = jnp.where(fresh[:, None], uplink, cache2)
        bf = base.astype(jnp.float32)
        mixf = bf + w[:, None].astype(jnp.float32) * (buf.astype(jnp.float32) - bf)
        mixed = jnp.where((w > 0)[:, None], mixf.astype(base.dtype), base)
        buf_new = jnp.where(store[:, None], uplink, buf)
        return mixed, buf_new
    from repro.kernels import stale_mix as sm

    return sm.stale_mix_pallas(
        uplink, cache, buf, fresh, store, w, block=block,
        interpret=(impl == "pallas_interpret"))


def _ef21_row_scales(rowmax, leaf_rows, lo: float):
    """Expand per-(client, leaf) maxima to per-128-lane-row scales.  The
    arena pads each leaf to a 128-lane multiple, so leaf boundaries fall on
    row edges and this is a static segment reduction -- same per-(client,
    leaf) scale semantics as ``tree_util._qdq``."""
    m = rowmax.shape[0]
    parts = []
    r0 = 0
    for rk in leaf_rows:
        s = jnp.max(rowmax[:, r0:r0 + rk], axis=1, keepdims=True) / lo
        parts.append(jnp.broadcast_to(s, (m, rk)))
        r0 += rk
    assert r0 == rowmax.shape[1], (r0, rowmax.shape)
    scales = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return jnp.maximum(scales, 1e-12)


def ef21_update(u, u_hat, bits: int, leaf_rows, *, impl: Optional[str] = None,
                block: Optional[int] = None):
    """Fused EF21 quantise-delta over the arena: returns the integrated
    server view u_hat' = u_hat + qdq(u - u_hat) in TWO full-size passes
    (rowwise max-abs reduction + apply) instead of the per-leaf
    tree_sub -> _qdq -> tree_add chain (~4 passes).

    ``leaf_rows``: static per-leaf row counts (``ArenaSpec.leaf_rows()``);
    the quantisation scale is per (client, leaf), exactly as the pytree path.
    """
    impl = _resolve(impl)
    lo = float(2 ** (bits - 1) - 1)
    m, w = u.shape
    rows = w // 128
    if impl == "xla":
        d = (u.astype(jnp.float32) - u_hat.astype(jnp.float32)).reshape(m, rows, 128)
        rowmax = jnp.max(jnp.abs(d), axis=-1)
        scales = _ef21_row_scales(rowmax, leaf_rows, lo)[..., None]
        q = jnp.clip(jnp.round(d / scales), -lo, lo)
        out = u_hat.astype(jnp.float32).reshape(m, rows, 128) + q * scales
        return out.reshape(m, w).astype(u.dtype)
    from repro.kernels import round_tail as rt

    interp = impl == "pallas_interpret"
    rowmax = rt.ef21_rowmax_pallas(u, u_hat, block=block, interpret=interp)
    scales = _ef21_row_scales(rowmax, leaf_rows, lo)
    return rt.ef21_apply_pallas(u, u_hat, scales, bits, block=block, interpret=interp)


# ---------------------------------------------------------------------------
# cohort row movement (core.api cohort engine): gather the active rows out
# of the population arena, scatter the updated rows back
# ---------------------------------------------------------------------------

def row_gather(arr, idx, *, impl: Optional[str] = None, block: Optional[int] = None):
    """Cohort gather out[t] = arr[idx[t]]: arr (m, width), idx (m_active,)
    int row ids.  One read of the gathered rows + one write of the
    (m_active, width) cohort buffer; the Pallas path rides a scalar-prefetch
    input index map (no materialised permutation)."""
    impl = _resolve(impl)
    if impl == "xla":
        return jnp.take(arr, idx, axis=0)
    from repro.kernels import gather as gk

    return gk.row_gather_pallas(arr, idx, block=block,
                                interpret=(impl == "pallas_interpret"))


def row_scatter(dst, idx, rows, *, impl: Optional[str] = None,
                block: Optional[int] = None):
    """Cohort scatter: returns dst with dst[idx[t]] = rows[t] (idx unique --
    the participation draw never repeats a client).  The XLA path is a plain
    unique-index scatter (in place when dst is donated); the Pallas path
    re-phrases it as a population-grid gather through the inverse position
    table pos[idx[t]] = t with a keep-mask at silent rows, so every output
    row is written exactly once and no input/output aliasing is needed."""
    impl = _resolve(impl)
    if impl == "xla":
        return dst.at[idx].set(rows, unique_indices=True)
    from repro.kernels import gather as gk

    m = dst.shape[0]
    mc = idx.shape[0]
    pos = jnp.zeros((m,), jnp.int32).at[idx].set(
        jnp.arange(mc, dtype=jnp.int32), unique_indices=True)
    mask = jnp.zeros((m,), jnp.int32).at[idx].set(1, unique_indices=True)
    return gk.row_scatter_pallas(dst, pos, mask, rows, block=block,
                                 interpret=(impl == "pallas_interpret"))


# ---------------------------------------------------------------------------
# graph-PDMM neighbor reduce + directed dual flip over the edge-dual arena
# (core.topology layout: (2|E|, width) directed duals, width % 128 == 0)
# ---------------------------------------------------------------------------

def neighbor_reduce(z, *, seg, first, sgn, n: int,
                    impl: Optional[str] = None, block: Optional[int] = None):
    """Per-node dual offsets s_i = sum_{j in N(i)} A_{ij} z_{i|j}.

    z: (2E, width) edge-dual arena; seg/first/sgn: (2E,) static slot tables
    (``Topology``: segment id = slot owner, segment-start flag, constraint
    sign).  Node i's slots are contiguous, so the XLA reference is a sorted
    segment-sum; the Pallas kernel fuses the sign apply + reduction into one
    pass with the output row resident in VMEM across each segment."""
    impl = _resolve(impl)
    if impl == "xla":
        zf = z.astype(jnp.float32)
        signed = jnp.where(jnp.asarray(sgn)[:, None] >= 0, zf, -zf)
        out = jax.ops.segment_sum(
            signed, jnp.asarray(seg), num_segments=n, indices_are_sorted=True
        )
        return out.astype(z.dtype)
    from repro.kernels import neighbor_reduce as nr

    return nr.neighbor_reduce_pallas(
        z, seg, first, sgn, n, block=block,
        interpret=(impl == "pallas_interpret"),
    )


def edge_flip(z, x, c, *, rev, nbr, sgn, mask=None,
              impl: Optional[str] = None, block: Optional[int] = None):
    """PDMM's directed dual exchange, written at the receiving slot:

        z'[slot(j|i)] = z[slot(i|j)] + 2 c A_{ij} x_i
                      = z[rev[t]] - 2 c sgn[t] x[nbr[t]]

    (A_{ij} here carries i = nbr[t], j = src[t], so A_{ij} = sgn[rev[t]] =
    -sgn[t].)

    z: (2E, width); x: (n, width) node-primal rows; rev/nbr/sgn: (2E,)
    static slot tables.  ``mask`` (optional (2E,) bool/int, 1 = the sending
    node ``nbr[t]`` fired) keeps z[t] at silent slots -- the stochastic
    node-firing / color-schedule variant.  One pass; both gathers ride the
    Pallas scalar-prefetch index maps (no materialised z[rev] copy)."""
    impl = _resolve(impl)
    if impl == "xla":
        zf = z.astype(jnp.float32)
        flip = (zf[jnp.asarray(rev)]
                - (2.0 * c) * jnp.asarray(sgn, jnp.float32)[:, None]
                * x.astype(jnp.float32)[jnp.asarray(nbr)])
        if mask is not None:
            flip = jnp.where(jnp.asarray(mask)[:, None] != 0, flip, zf)
        return flip.astype(z.dtype)
    from repro.kernels import neighbor_reduce as nr

    return nr.edge_flip_pallas(
        z, x, c, rev, nbr, sgn,
        mask=None if mask is None else jnp.asarray(mask, jnp.int32),
        block=block, interpret=(impl == "pallas_interpret"),
    )


# ---------------------------------------------------------------------------
# rg-lru recurrence
# ---------------------------------------------------------------------------

def lru_scan(a, b, h0, *, chunk: int = 512):
    """Linear recurrence h_t = a_t h_{t-1} + b_t; a, b: (B, S, D), h0 (B, D).

    Chunked: an outer ``lax.scan`` over S/chunk carries the boundary state and
    an inner associative scan runs within each chunk.  A monolithic
    associative scan over the full sequence materialises O(log S) full-size
    f32 intermediates -- at 32k x 4096 that alone was tens of GiB/device.
    """
    B, S, D = a.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    af = a.astype(jnp.float32).reshape(B, n, chunk, D)
    bf = b.astype(jnp.float32).reshape(B, n, chunk, D)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, inp):
        ac, bc = inp  # (B, chunk, D)
        bc = bc.at[:, 0].add(ac[:, 0] * h)
        _, hs = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        return hs[:, -1], hs

    h_last, ys = jax.lax.scan(
        chunk_step, h0.astype(jnp.float32), (jnp.moveaxis(af, 1, 0), jnp.moveaxis(bf, 1, 0))
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
    return y.astype(a.dtype), h_last
