import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Terms per (arch x shape) on the single-pod 16x16 mesh, all in seconds:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

XLA's cost analysis counts a ``lax.scan`` body once, so the depth totals come
from TWO probe lowerings with 1 and 2 *unrolled* pattern units:
per_unit = probe2 - probe1, total = probe1 + per_unit * (n_units - 1 +
tail/pattern).  Probes use inner_steps K=1; the compute/memory terms scale by
K (client-local), the round collective does NOT (the paper's communication
claim) -- the report carries both K=1 and K-scaled compute columns.

MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (inference) gives the
useful-work ratio against HLO FLOPs (catching remat/dispatch waste).
"""
import argparse
import dataclasses
import json
import pathlib

import jax

from repro.configs import ARCHS, SHAPES, get_arch, get_shape
from repro.launch import hlo_stats
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh, mesh_context
from repro.launch.steps import build_step

REPORT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "roofline"
CHIPS = 256


def _probe_cfg(cfg, n_units: int):
    """Unrolled, K=1, no-grad-accum probe with `n_units` pattern units.

    ``microbatch=None``: the grad-accumulation ``lax.scan`` body is counted
    ONCE by XLA's cost analysis (verified: an 8-chunk scanned grad reports
    1/8th the flops of the equivalent plain grad), which silently divided the
    compute term by up to 64x (llama4).  Accumulation does not change the
    round's total FLOPs, so the single-pass probe measures them correctly;
    it *does* change HBM traffic (params re-read per chunk), so the memory
    term is reported for the microbatch=1 schedule -- noted in EXPERIMENTS.md.
    """
    n_layers = cfg.first_dense_layers + cfg.pattern_len * n_units
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        scan_layers=False,
        microbatch=None,
        fed=dataclasses.replace(cfg.fed, inner_steps=1),
    )


def _measure(cfg, shape, mesh):
    bundle = build_step(cfg, shape, mesh)
    with mesh_context(mesh):
        lowered = jax.jit(
            bundle.fn, in_shardings=bundle.in_shardings, out_shardings=bundle.out_shardings
        ).lower(*bundle.args)
        compiled = lowered.compile()
    flops, bytes_acc = hlo_stats.flops_and_bytes(compiled)
    coll = hlo_stats.collective_bytes(compiled.as_text())
    return {
        "flops": flops,
        "bytes": bytes_acc,
        "coll_bytes": float(coll["total"]["bytes"]),
        "coll_count": coll["total"]["count"],
        "coll_detail": {k: v for k, v in coll.items() if k != "total"},
    }


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs for the whole step (all chips)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens * cfg.fed.inner_steps
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def analyze(arch_name: str, shape_name: str, *, verbose=True) -> dict:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    if not cfg.supports_shape(shape):
        return {"arch": arch_name, "shape": shape_name, "status": "skipped"}
    mesh = make_production_mesh(multi_pod=False)

    p1 = _measure(_probe_cfg(cfg, 1), shape, mesh)
    p2 = _measure(_probe_cfg(cfg, 2), shape, mesh)

    lead, = (cfg.first_dense_layers,)
    n_units = (cfg.n_layers - lead) // cfg.pattern_len
    tail_frac = ((cfg.n_layers - lead) % cfg.pattern_len) / cfg.pattern_len
    mult = (n_units - 1) + tail_frac

    def extrap(key):
        per_unit = max(0.0, p2[key] - p1[key])
        return p1[key] + per_unit * mult

    flops_dev = extrap("flops")
    bytes_dev = extrap("bytes")
    coll_dev = extrap("coll_bytes")

    K = cfg.fed.inner_steps if shape.kind == "train" else 1
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * CHIPS * K
    ratio = mf / hlo_total if hlo_total else float("nan")

    recs = {
        "compute": "raise arithmetic efficiency: fuse attention (Pallas flash), "
                   "drop masked-block FLOPs (causal_skip), larger per-step tiles",
        "memory": "cut HBM traffic: fused client update (1 pass), bf16 collective "
                  "dtypes, larger microbatches once capacity allows, remat policy tuning",
        "collective": "overlap/shrink collectives: bf16 all-reduce, combine the "
                      "round's uplink tensors, hierarchical (pod-local first) reduction",
    }

    report = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "16x16",
        "status": "ok",
        "K": K,
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_dev,
        },
        "terms_seconds": terms,
        "terms_seconds_k_scaled": {
            "compute": t_compute * K,
            "memory": t_memory * K,
            "collective": t_coll,  # round-level: amortised over K (the paper's point)
        },
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": ratio,
        "bottleneck_note": recs[dominant],
        "collective_detail": p2["coll_detail"],
    }
    if verbose:
        print(
            f"[roofline] {arch_name:28s} {shape_name:12s} "
            f"compute={t_compute*1e3:9.3f}ms memory={t_memory*1e3:9.3f}ms "
            f"collective={t_coll*1e3:9.3f}ms -> {dominant:10s} "
            f"useful={ratio:6.2%}"
        )
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default=str(REPORT_DIR))
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    for a in archs:
        for s in shapes:
            try:
                rep = analyze(a, s)
            except Exception as e:
                rep = {"arch": a, "shape": s, "status": "failed", "error": str(e)}
                print(f"[roofline] {a:28s} {s:12s} FAIL {e}")
            (outdir / f"{a}_{s}.json").write_text(json.dumps(rep, indent=2))


if __name__ == "__main__":
    main()
