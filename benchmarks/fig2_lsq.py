"""Paper Fig. 2: FedAvg / GPDMM / AGPDMM / SCAFFOLD on least squares over a
centralised network, sweeping K (gradient steps per round), m (clients) and
eta.  Claims reproduced:
  * FedAvg stalls for K > 1 (client heterogeneity);
  * AGPDMM converges faster than GPDMM for every K;
  * AGPDMM >= SCAFFOLD for K > 1; all coincide at K = 1.

CPU budget note: the paper uses A_i in R^{5000x500}; with the precomputed
A^T A oracle the per-round cost is m*d^2*K, so the paper dims are kept for
m=25.  For m=500 the per-client rows are reduced to n=500 (the oracle only
sees A^T A, so the problem class is unchanged); recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.base import FederatedConfig
from repro.core import make, quadratic

METHODS = ["fedavg", "gpdmm", "agpdmm", "scaffold"]


def run_setting(prob, method, K, eta, rounds):
    cfg = FederatedConfig(algorithm=method, inner_steps=K, eta=eta)
    opt = make(cfg)

    @jax.jit
    def round_fn(s):
        s, _ = opt.round(s, prob.grad, prob.batch())
        return s

    s = opt.init(jnp.zeros((prob.d,)), prob.m)
    cp = max(1, rounds // 4)
    d_cp = None
    for r in range(rounds):
        s = round_fn(s)
        if r + 1 == cp:
            d_cp = float(prob.dist(opt.server_params(s)))
    d_end = float(prob.dist(opt.server_params(s)))
    gap = float(prob.gap(opt.server_params(s)))
    return gap, d_cp, d_end, round_fn, s


def run(rounds=200):
    dist_cp, dist_end = {}, {}
    settings = [
        # (m, n, eta_scale, Ks)
        (25, 5000, 1.0, [1, 3, 5, 10, 20]),
        (500, 500, 1.0, [1, 5, 20]),
    ]
    for m, n, _es, Ks in settings:
        prob = quadratic.generate(jax.random.key(0), m=m, n=n, d=500)
        eta = 0.5 / prob.L  # the paper's 5e-5/1e-4 correspond to ~1/L scaling
        for K in Ks:
            for method in METHODS:
                gap, d_cp, d_end, round_fn, s = run_setting(prob, method, K, eta, rounds)
                us = time_fn(round_fn, s, iters=3, warmup=0)
                dist_cp[(m, K, method)] = d_cp
                dist_end[(m, K, method)] = d_end
                emit(f"fig2_lsq_m={m}_K={K}_{method}", us,
                     f"dist_mid={d_cp:.3e} dist_end={d_end:.3e} gap={gap:.3e}")
        # claims for this m -- evaluated on ||x - x*|| (the f32 functional gap
        # is +-O(10) noise once converged, F ~ 1e6), at the mid-trajectory
        # checkpoint where methods are still separated
        for K in Ks:
            if K > 1:
                assert dist_cp[(m, K, "agpdmm")] <= dist_cp[(m, K, "gpdmm")] * 1.05, (m, K)
                # FedAvg stalls at a heterogeneity plateau
                assert dist_end[(m, K, "fedavg")] > 10 * dist_end[(m, K, "agpdmm")], (m, K)
    return dist_end


if __name__ == "__main__":
    run()
