"""Attention variants: GQA (full / sliding-window / local), and DeepSeek-style
MLA with a compressed KV cache (matrix-absorbed decode).

Modes:
  * ``train``   -- full sequence, no cache.
  * ``prefill`` -- full sequence; returns a cache of capacity ``cache_cap``.
  * ``decode``  -- one token against the cache; returns the updated cache.

Caches (per layer):
  GQA full:  {"k": (B, cap, Hkv, hd), "v": (B, cap, Hkv, vd)}
  GQA ring (sliding/local window W): same with cap == W; slot = pos % W and
      "k_pos": (W,) absolute position per slot (-1 = empty).
  MLA:       {"ckv": (B, cap, kv_lora), "kr": (B, cap, rope_hd)}
The scalar write position ``pos`` is carried once per model, not per layer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import layers as L


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ArchConfig, dtype):
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    wq, sq = L.dense_init(k1, (d, h, hd), ("embed", "heads", None), dtype)
    wk, sk = L.dense_init(k2, (d, hkv, hd), ("embed", "kv", None), dtype)
    wv, sv = L.dense_init(k3, (d, hkv, hd), ("embed", "kv", None), dtype)
    wo, so = L.dense_init(k4, (h, hd, d), ("heads", None, "embed"), dtype, scale=1.0 / (h * hd) ** 0.5)
    return {"wq": wq, "wk": wk, "wv": wv, "wo": wo}, {"wq": sq, "wk": sk, "wv": sv, "wo": so}


def _rope_qk(q, k, q_pos, k_pos, theta):
    cq, sq = L.rope_angles(q_pos, q.shape[-1], theta)
    ck, sk = L.rope_angles(k_pos, k.shape[-1], theta)
    return L.rope_apply(q, cq, sq), L.rope_apply(k, ck, sk)


def gqa_apply(
    cfg: ArchConfig,
    params,
    x,
    *,
    mode: str,
    cache=None,
    pos=None,
    window: Optional[int] = None,
    cache_cap: int = 0,
):
    """x: (B, S, D) (S == 1 in decode).  Returns (out, new_cache)."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])

    if mode in ("train", "prefill"):
        positions = jnp.arange(S, dtype=jnp.int32)
        q, k = _rope_qk(q, k, positions, positions, cfg.rope_theta)
        out = ops.flash_attention(q, k, v, positions, positions, causal=True, window=window)
        new_cache = None
        if mode == "prefill":
            if window is not None:
                W = min(window, cache_cap or window)
                kc = jnp.zeros((B, W, cfg.n_kv_heads, hd), k.dtype)
                vc = jnp.zeros((B, W, cfg.n_kv_heads, hd), v.dtype)
                # last W tokens land in slot pos % W
                take = min(W, S)
                src = jax.lax.dynamic_slice_in_dim(k, S - take, take, axis=1)
                srcv = jax.lax.dynamic_slice_in_dim(v, S - take, take, axis=1)
                slots = (jnp.arange(S - take, S) % W).astype(jnp.int32)
                kc = kc.at[:, slots].set(src)
                vc = vc.at[:, slots].set(srcv)
                k_pos = jnp.full((W,), -1, jnp.int32).at[slots].set(jnp.arange(S - take, S, dtype=jnp.int32))
                new_cache = {"k": kc, "v": vc, "k_pos": k_pos}
            else:
                cap = max(cache_cap, S)
                pad = cap - S
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                new_cache = {"k": kc, "v": vc}
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new_cache

    # ---- decode ----
    assert S == 1 and cache is not None and pos is not None
    q_pos = jnp.asarray(pos, jnp.int32)
    cq, sq = L.rope_angles(q_pos[None], hd, cfg.rope_theta)
    q = L.rope_apply(q, cq[None], sq[None])
    ck, sk = L.rope_angles(q_pos[None], hd, cfg.rope_theta)
    k = L.rope_apply(k, ck[None], sk[None])
    if window is not None:
        W = cache["k"].shape[1]
        slot = jnp.mod(q_pos, W)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        k_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["k_pos"], q_pos[None], slot, axis=0
        )
        out = ops.attend_cache(q, kc, vc, q_pos, k_pos, window=window)
        new_cache = {"k": kc, "v": vc, "k_pos": k_pos}
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
        cap = kc.shape[1]
        k_pos = jnp.where(jnp.arange(cap) <= q_pos, jnp.arange(cap), -1).astype(jnp.int32)
        out = ops.attend_cache(q, kc, vc, q_pos, k_pos, window=None)
        new_cache = {"k": kc, "v": vc}
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new_cache


def gqa_cache_shape(cfg: ArchConfig, batch: int, cap: int, window: Optional[int], dtype):
    hd = cfg.resolved_head_dim
    if window is not None:
        W = min(window, cap)
        return {
            "k": jax.ShapeDtypeStruct((batch, W, cfg.n_kv_heads, hd), dtype),
            "v": jax.ShapeDtypeStruct((batch, W, cfg.n_kv_heads, hd), dtype),
            "k_pos": jax.ShapeDtypeStruct((W,), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, cap, cfg.n_kv_heads, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, cap, cfg.n_kv_heads, hd), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ArchConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    nope, rope_d, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    ks = jax.random.split(key, 5)
    wq, sq = L.dense_init(ks[0], (d, h, nope + rope_d), ("embed", "heads", None), dtype)
    wdkv, sdkv = L.dense_init(ks[1], (d, kvl + rope_d), ("embed", None), dtype)
    wuk, suk = L.dense_init(ks[2], (kvl, h, nope), (None, "heads", None), dtype)
    wuv, suv = L.dense_init(ks[3], (kvl, h, vd), (None, "heads", None), dtype)
    wo, so = L.dense_init(ks[4], (h, vd, d), ("heads", None, "embed"), dtype, scale=1.0 / (h * vd) ** 0.5)
    nrm, nrm_s = L.norm_init("rmsnorm", kvl)
    nrm_s = {k: (None,) for k in nrm}
    return (
        {"wq": wq, "wdkv": wdkv, "wuk": wuk, "wuv": wuv, "wo": wo, "ckv_norm": nrm},
        {"wq": sq, "wdkv": sdkv, "wuk": suk, "wuv": suv, "wo": so, "ckv_norm": nrm_s},
    )


def mla_apply(cfg: ArchConfig, params, x, *, mode: str, cache=None, pos=None, cache_cap: int = 0):
    B, S, D = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    scale_dim = nope + rope_d

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])  # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    dkv = x @ params["wdkv"]  # (B,S,kvl+rope)
    ckv = L.norm_apply("rmsnorm", params["ckv_norm"], dkv[..., :kvl])
    k_rope = dkv[..., kvl:][:, :, None, :]  # (B,S,1,rope)

    if mode in ("train", "prefill"):
        positions = jnp.arange(S, dtype=jnp.int32)
        cq, sq_ = L.rope_angles(positions, rope_d, cfg.rope_theta)
        q_rope = L.rope_apply(q_rope, cq[None], sq_[None])
        k_rope = L.rope_apply(k_rope, cq[None], sq_[None])
        k_nope = jnp.einsum("bsl,lhk->bshk", ckv, params["wuk"])
        v = jnp.einsum("bsl,lhk->bshk", ckv, params["wuv"])
        k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, h, rope_d))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = ops.flash_attention(q_full, k_full, v, positions, positions, causal=True)
        new_cache = None
        if mode == "prefill":
            cap = max(cache_cap, S)
            pad = cap - S
            new_cache = {
                "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                "kr": jnp.pad(k_rope[:, :, 0, :], ((0, 0), (0, pad), (0, 0))),
            }
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new_cache

    # ---- decode: matrix-absorbed attention over the compressed cache ----
    assert S == 1 and cache is not None and pos is not None
    q_pos = jnp.asarray(pos, jnp.int32)
    cq, sq_ = L.rope_angles(q_pos[None], rope_d, cfg.rope_theta)
    q_rope = L.rope_apply(q_rope, cq[None], sq_[None])
    k_rope = L.rope_apply(k_rope, cq[None], sq_[None])
    ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, pos, axis=1)
    kr_c = jax.lax.dynamic_update_slice_in_dim(cache["kr"], k_rope[:, :, 0, :], pos, axis=1)
    cap = ckv_c.shape[1]
    # absorb W_uk into q: q_c[b,h,l] = sum_n q_nope[b,h,n] wuk[l,h,n]
    q_c = jnp.einsum("bhn,lhn->bhl", q_nope[:, 0].astype(jnp.float32), params["wuk"].astype(jnp.float32))
    s_nope = jnp.einsum("bhl,bkl->bhk", q_c, ckv_c.astype(jnp.float32))
    s_rope = jnp.einsum("bhr,bkr->bhk", q_rope[:, 0].astype(jnp.float32), kr_c.astype(jnp.float32))
    s = (s_nope + s_rope) / jnp.sqrt(scale_dim).astype(jnp.float32)
    valid = jnp.arange(cap) <= q_pos
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhk,bkl->bhl", p, ckv_c.astype(jnp.float32))  # (B,H,kvl)
    out = jnp.einsum("bhl,lhv->bhv", ctx_c, params["wuv"].astype(jnp.float32))
    out = out[:, None].astype(x.dtype)  # (B,1,H,vd)
    return (
        jnp.einsum("bshk,hkd->bsd", out, params["wo"]),
        {"ckv": ckv_c, "kr": kr_c},
    )


def mla_cache_shape(cfg: ArchConfig, batch: int, cap: int, dtype):
    return {
        "ckv": jax.ShapeDtypeStruct((batch, cap, cfg.kv_lora_rank), dtype),
        "kr": jax.ShapeDtypeStruct((batch, cap, cfg.rope_head_dim), dtype),
    }


def gqa_cache_spec(window):
    if window is not None:
        return {"k": ("batch", "seq", "kv", None), "v": ("batch", "seq", "kv", None), "k_pos": ("seq",)}
    return {"k": ("batch", "seq", "kv", None), "v": ("batch", "seq", "kv", None)}


def mla_cache_spec():
    return {"ckv": ("batch", "seq", None), "kr": ("batch", "seq", None)}
