"""Federated LM training launcher (runs for real on whatever devices exist).

    PYTHONPATH=src python -m repro.launch.train \
        --arch olmo-1b --reduced --steps 50 --algorithm gpdmm --k 4

On CPU this drives the reduced configs (the ~100M-scale end-to-end example
lives in examples/train_federated_lm.py); on a real TPU mesh the same code
path drives the full configs via --mesh production.

Checkpointing: ``--ckpt-dir`` saves the FULL federated state (every arena
buffer, the server pytree, and the round counter) at the end of the run;
``--resume`` restores the latest checkpoint and continues the SAME
trajectory -- the synthetic data stream is re-keyed from the restored round
counter, so save-at-r + resume equals the uninterrupted run at f32
(tests/test_cohort.py pins this).  Partial-participation runs on the cohort
engine (``core.api.use_cohort``) feed cohort-sized batches from
``data.synthetic.cohort_lm_batches`` -- data is generated only for the
clients that actually fire each round.

Robustness (docs/robustness.md): ``--faults`` injects a deterministic fault
schedule (``core.faults``), ``--screen`` gates the fused uplink screen, and
``--watchdog`` arms a divergence watchdog -- after ``--watchdog-patience``
consecutive bad logged rows (non-finite metrics, or server loss above
``--watchdog-factor`` x the attempt's best) it rolls the full federated
state back to the newest healthy checkpoint anchor and retries with the
stepsize scaled by ``--eta-backoff``.  The fault trace is a pure function
of (fault seed, round, client), so replayed rounds replay identical faults:
screening remedies corruption, the watchdog remedies stepsize divergence.

Telemetry (docs/telemetry.md): ``--telemetry`` turns on the metrics
registry (fault/rollback counters, loss/residual gauges) with a structured
end-of-run summary; ``--trace-out trace.json`` additionally records
round-phase spans (batch build / dispatch / block_until_ready / eval+log /
checkpoint save+load, plus the popstore staging phases and watchdog
strike/rollback instants) as Perfetto-loadable Chrome trace JSON;
``--metrics-out metrics.jsonl`` streams every logged history row through
the crash-safe JSONL sink as it happens, so loss curves survive a crash
instead of living only in stdout; ``--profile-rounds A:B`` captures a
``jax.profiler`` device trace for exactly those rounds.  All of it is off
by default, and the off path adds no per-round host work (the dispatch
wrappers are only installed when tracing is on).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import pathlib
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import telemetry as tel
from repro.configs import get_arch
from repro.configs.base import FaultConfig, FederatedConfig, ShapeConfig
from repro.core import make as make_fed
from repro.core import make_scan_rounds, popstore
from repro.core.api import FedOpt, use_arena, use_cohort, use_popstore
from repro.data.synthetic import cohort_lm_batches, lm_batches
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import build_train_step
from repro.models import build as build_model


def run(
    arch: str,
    *,
    reduced: bool = True,
    steps: int = 20,
    algorithm: str = "gpdmm",
    k: int = 2,
    eta: float | str = 0.3,
    tol: float = 0.0,
    patience: int = 1,
    m: int = 4,
    per_client_batch: int = 4,
    seq_len: int = 128,
    seed: int = 0,
    ckpt_dir: str | None = None,
    resume: bool = False,
    log_every: int = 5,
    uplink_bits: int | None = None,
    participation: float = 1.0,
    popstore_mode: bool | str = "auto",
    rounds_per_call: int = 1,
    faults: str | FaultConfig | None = None,
    screen: bool | str = "auto",
    deadline: float = math.inf,
    max_staleness: int = 0,
    stale_gamma: float = 0.5,
    async_rounds: bool | str = "auto",
    watchdog: bool = False,
    watchdog_factor: float = 10.0,
    watchdog_patience: int = 2,
    eta_backoff: float = 0.5,
    max_rollbacks: int = 3,
    ckpt_every: int = 0,
    ckpt_keep: int = 3,
    expect_demotions: int = 0,
    expect_rollbacks: int = 0,
    telemetry: bool = False,
    trace_out: str | None = None,
    metrics_out: str | None = None,
    profile_rounds: str | None = None,
    profile_dir: str | None = None,
):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    fault_cfg = FaultConfig.parse(faults) if isinstance(faults, str) else faults
    if watchdog and not ckpt_dir:
        raise ValueError("--watchdog needs --ckpt-dir (rollback anchors)")

    # telemetry: any output flag implies the master switch; the tracer only
    # records when it has a sink (spans without a file are dead weight).
    # The GLOBAL tracer is configured so the instrumented library paths
    # (core.popstore staging, serve's watcher) emit into the same trace.
    tel_on = (telemetry or bool(trace_out) or bool(metrics_out)
              or bool(profile_rounds))
    tracer = tel.get_tracer()
    was_tracing = tracer.enabled
    if trace_out:
        tracer.configure(enabled=True, trace_out=trace_out)
    registry = tel.Registry() if tel_on else None
    sink = tel.JsonlSink(metrics_out) if metrics_out else None
    prof = tel.RoundProfiler.parse(
        profile_rounds,
        profile_dir or (str(pathlib.Path(trace_out).parent / "jaxprof")
                        if trace_out else "telemetry/jaxprof"))

    model = build_model(cfg)  # the model ignores cfg.fed (checked)

    key = jax.random.key(seed)
    params = model.init(key)

    _eta_cache: list = []

    def resolved_eta():
        """The CLI eta, with ``"auto"`` resolved ONCE host-side into the
        per-client tuple (power-iteration L_i estimates at the init params
        over a fixed probe batch, ``core.autotune``).  Cached: every rebuild
        -- including each watchdog backoff -- reuses the same derived
        values, and the checkpoint fingerprint records the CLI value, so a
        ``--resume`` re-derives the identical tuple deterministically."""
        if not isinstance(eta, str):
            return eta
        if not _eta_cache:
            from repro.core import autotune
            probe = next(lm_batches(jax.random.key(seed + 3), 1, m,
                                    per_client_batch, seq_len, cfg.vocab_size))
            gf = lambda p, b: jax.grad(lambda q: model.loss(q, b)[0])(p)
            L = autotune.estimate_L(gf, params, m, probe)
            etas = autotune.derive_eta(L)
            print(f"[train] auto-eta: per-client L in [{L.min():.4g}, "
                  f"{L.max():.4g}], eta in [{etas.min():.4g}, "
                  f"{etas.max():.4g}]", flush=True)
            _eta_cache.append(tuple(float(e) for e in etas))
        return _eta_cache[0]

    def fed_cfg(scale: float) -> FederatedConfig:
        # eta backoff after a rollback re-derives rho = 1/(K eta') too: the
        # watchdog shrinks the stepsize of the whole primal-dual pair (under
        # auto-eta the backoff rescales every per-client entry uniformly)
        from repro.core import autotune
        fc = dataclasses.replace(
            cfg.fed, algorithm=algorithm, inner_steps=k, eta=resolved_eta(),
            num_clients=m, layout="client_axis", uplink_bits=uplink_bits,
            participation=participation, popstore=popstore_mode,
            rounds_per_call=rounds_per_call,
            faults=fault_cfg, screen=screen, async_rounds=async_rounds,
            deadline=deadline, max_staleness=max_staleness,
            stale_gamma=stale_gamma, tol=tol, patience=patience,
        )
        return autotune.scale_eta(fc, scale)

    cfg = dataclasses.replace(cfg, fed=fed_cfg(1.0))

    # fingerprint saved with every checkpoint and checked on --resume: a
    # restored state only continues the SAME trajectory if the run that
    # wrote it used the same optimiser/data hyper-parameters
    run_config = {
        "arch": arch, "reduced": reduced, "algorithm": algorithm, "k": k,
        "eta": eta, "m": m, "per_client_batch": per_client_batch,
        "seq_len": seq_len, "seed": seed, "uplink_bits": uplink_bits,
        "participation": participation,
    }
    if fault_cfg is not None:
        # the seeded fault trace is part of the trajectory, so it joins the
        # fingerprint -- but only when a schedule is active, so checkpoints
        # written before this launcher grew fault support still resume
        run_config["faults"] = dataclasses.asdict(fault_cfg)
        run_config["screen"] = screen if isinstance(screen, str) else bool(screen)
        from repro.core import faults as faults_mod

        if faults_mod.async_on(cfg.fed):
            # the staleness knobs reshape the trajectory (admission weights,
            # deadline demotions), so they join the fingerprint -- but only
            # when the async engine is actually on, so pre-ISSUE-7
            # checkpoints (and delay-as-silence runs) still resume
            run_config["deadline"] = deadline
            run_config["max_staleness"] = max_staleness
            run_config["stale_gamma"] = stale_gamma

    # cohort engine active -> feed cohort-sized batches (rows = the round's
    # active clients, sorted by id) so data is never generated for silent
    # clients; popstore additionally moves the resident (m, width) client
    # buffers to a HOST store and stages only the sampled cohort per round
    # (core.popstore), making device memory O(cohort)
    cohort = use_cohort(cfg.fed, m) and use_arena(cfg.fed, params)
    pop_on = cohort and use_popstore(cfg.fed, m)
    if pop_on:
        # the store changes the checkpointed state LAYOUT (host buffers +
        # running sums instead of device arenas), so it joins the resume
        # fingerprint -- but only when on, so older checkpoints still resume
        run_config["popstore"] = True

    def load_latest_good(what: str):
        """Newest LOADABLE checkpoint under ckpt_dir: a truncated or corrupt
        file at the newest step (a crash mid-copy, a bad disk) is skipped
        with a loud warning instead of killing the run -- resume and
        watchdog rollback both degrade to the last good anchor."""
        for step_n in sorted(ckpt.steps(ckpt_dir), reverse=True):
            try:
                with tracer.span("ckpt/load", {"step": step_n}):
                    return step_n, ckpt.load(ckpt_dir, step_n)
            except ValueError as e:
                print(f"[train] {what}: SKIPPING unreadable checkpoint step "
                      f"{step_n}: {e}", flush=True)
        raise FileNotFoundError(
            f"{what}: no loadable checkpoint under {ckpt_dir}")

    start = 0
    eta_scale = 1.0
    state = None
    if resume:
        if not ckpt_dir:
            raise ValueError("--resume needs --ckpt-dir")
        last, payload = load_latest_good("--resume")
        if "fed_state" not in payload:
            raise ValueError(
                f"checkpoint step {last} under {ckpt_dir} has no 'fed_state' "
                "(written by a pre-ISSUE-5 launcher that saved only server "
                "params); it cannot resume a trajectory -- retrain, or load "
                "payload['server'] manually for serving")
        saved_cfg = payload.get("config", {})
        diffs = {kk: (saved_cfg.get(kk), vv) for kk, vv in run_config.items()
                 if saved_cfg.get(kk) != vv}
        if diffs:
            raise ValueError(
                f"--resume config mismatch vs checkpoint (saved, requested): "
                f"{diffs}; resuming would NOT continue the same trajectory")
        if bool(saved_cfg.get("popstore", False)) != pop_on:
            # popstore state (host store + running sums) and arena state
            # (device buffers) are different LAYOUTS of the same trajectory;
            # the round drivers cannot consume each other's checkpoints
            raise ValueError(
                f"--resume popstore mismatch: checkpoint was written with "
                f"popstore={bool(saved_cfg.get('popstore', False))}, this "
                f"run resolves popstore={pop_on} (popstore_mode="
                f"{popstore_mode!r}); pass --popstore on/off to match")
        # the FULL federated state (arena buffers + server pytree + round
        # counter) resumes; the data stream re-keys from the round counter,
        # so the continuation is the uninterrupted trajectory.  fed.init is
        # skipped entirely -- at population scale the (m, width) arena
        # buffers it would broadcast just to be overwritten are the bulk of
        # the job's memory
        state = payload["fed_state"]
        start = int(payload["round"])
        # a watchdog-backed-off run resumes at its backed-off stepsize; the
        # scale rides outside the fingerprint (it IS the same trajectory,
        # continued at the eta the rollback settled on)
        eta_scale = float(payload.get("eta_scale", 1.0))
        print(f"[train] resumed full fed state at round {start} from {ckpt_dir}"
              + (f" (eta_scale={eta_scale:g})" if eta_scale != 1.0 else ""))
    if start >= steps:
        print(f"[train] checkpoint already at round {start} >= steps {steps}; "
              f"nothing to do")
        return []

    def client_grad(p, b):
        return jax.grad(lambda q: model.loss(q, b)[0])(p)

    # donate the round state: the arena/round update aliases its input
    # buffers in place instead of holding two copies of the (m, params) state.
    # With rounds_per_call > 1 the scan driver runs R full rounds per
    # dispatch over a leading-R batch stack (metrics come back stacked).
    R = max(1, rounds_per_call)
    if pop_on and R > 1:
        # the popstore round is a HOST driver (gather/scatter against host
        # numpy + the prefetch ring): it cannot run under lax.scan
        print(f"[train] popstore active: forcing rounds_per_call "
              f"{rounds_per_call} -> 1 (host-side round driver)")
        R = 1

    def _instrument(fn):
        """Dispatch/sync spans around a round function.  Installed ONLY when
        tracing is on: the telemetry-off path keeps the original callable
        (and its async-dispatch overlap) with zero added per-round host
        work.  The explicit block_until_ready span is what splits "enqueue
        the round" from "wait for the device" in the trace."""
        if not tracer.enabled:
            return fn

        def wrapped(s, b):
            with tracer.span("round/dispatch"):
                out = fn(s, b)
            with tracer.span("round/block_until_ready"):
                jax.block_until_ready(out)
            return out

        return wrapped

    def build(scale: float):
        """(fed, step_fn, round_fn) at the given eta scale -- rebuilt after
        every watchdog backoff so the jitted round sees the new stepsize."""
        if pop_on:
            runner = popstore.Runner(fed_cfg(scale), client_grad)
            # the FedOpt surface the rest of the launcher speaks, but
            # round_fn is a HOST function -- no outer jit, no donation (the
            # runner mutates its host store in place instead)
            fed = FedOpt(name=algorithm, init=runner.init,
                         round=runner.round,
                         server_params=runner.server_params)
            rf = _instrument(runner.round)
            return fed, rf, rf
        fed = make_fed(fed_cfg(scale))

        def one_round(s, b):
            s2, mets = fed.round(s, client_grad, b)
            if tol > 0.0:  # static gate: tol=0 compiles the pre-PR graph
                from repro.core import autotune
                mets = {**mets, **autotune.state_residual(s, s2)}
            return s2, mets

        round_fn = jax.jit(one_round, donate_argnums=(0,))
        if R > 1:
            scan_rounds = make_scan_rounds(fed, client_grad, tol=tol)
            step_fn = jax.jit(lambda s, b: scan_rounds(s, b),
                              donate_argnums=(0,))
        else:
            step_fn = round_fn
        return fed, _instrument(step_fn), _instrument(round_fn)

    @jax.jit
    def eval_loss(params, batch):
        # server-model loss averaged over the same stacked batch
        losses = jax.vmap(lambda b: model.loss(params, b)[0])(batch)
        return losses.mean()

    history = []
    n_rounds = steps - start

    def make_data(from_round: int):
        # re-keyed from the starting round: a rollback (or --resume)
        # regenerates the identical per-round stream the uninterrupted run
        # would have seen from that round on
        data_key = jax.random.key(seed + 1)
        if cohort:
            return cohort_lm_batches(
                data_key, steps - from_round, m, per_client_batch, seq_len,
                cfg.vocab_size, participation=participation,
                fed_seed=cfg.fed.seed, start=from_round,
            )
        return lm_batches(data_key, steps - from_round, m, per_client_batch,
                          seq_len, cfg.vocab_size, start=from_round)

    # cohort batches only cover the round's active clients, so evaluating
    # the server loss on them would track the cohort's topics, not the
    # population objective (incomparable across participation settings):
    # hold out ONE fixed full-population batch for the logged loss instead
    eval_batch = None
    if cohort:
        eval_batch = next(lm_batches(jax.random.key(seed + 2), 1, m,
                                     per_client_batch, seq_len, cfg.vocab_size))

    def metrics_row(metrics):
        # last-round values, whether stacked (R,) from the scan or scalars
        return {kk: float(jnp.asarray(v).reshape(-1)[-1])
                for kk, v in metrics.items() if kk != "trace"}

    class _Watchdog:
        """Trips after ``watchdog_patience`` consecutive bad logged rows; a
        row is bad when any metric is non-finite or the server loss exceeds
        ``watchdog_factor`` x this attempt's best loss."""

        def __init__(self):
            self.best = math.inf
            self.strikes = 0

        def note(self, row) -> bool:
            bad = (any(not math.isfinite(v) for v in row.values()
                       if isinstance(v, float))
                   or row["server_loss"] > watchdog_factor * self.best)
            if bad:
                self.strikes += 1
                tracer.instant("watchdog/strike",
                               {"round": row["round"],
                                "strikes": self.strikes,
                                "server_loss": row["server_loss"]})
                if registry is not None:
                    registry.counter("watchdog_strikes").inc()
            else:
                self.strikes = 0
                self.best = min(self.best, row["server_loss"])
            return self.strikes >= watchdog_patience

    injected_total = demoted_total = 0.0
    last_saved = None

    def note_faults(metrics):
        # fault counters sum over every executed dispatch (stacked (R,) rows
        # from the scan included), so the end-of-run summary covers rounds a
        # rollback later replayed too
        nonlocal injected_total, demoted_total
        if metrics and "faults_demoted" in metrics:
            injected_total += float(jnp.sum(jnp.asarray(metrics["faults_injected"])))
            demoted_total += float(jnp.sum(jnp.asarray(metrics["faults_demoted"])))
        if registry is not None and metrics:
            # counter-semantic device metrics sum over EVERY dispatch, so
            # the registry totals match the launcher's own accounting (the
            # --expect-demotions gate) exactly -- logged rows alone would
            # miss unlogged rounds and all but the last stacked scan row
            for key in tel.COUNTER_KEYS:
                if key in metrics:
                    v = float(jnp.sum(jnp.asarray(metrics[key])))
                    if math.isfinite(v):
                        registry.counter(key).inc(v)

    def save_anchor(fed, state, scale):
        done = int(state["round"])
        with tracer.span("ckpt/save", {"round": done}):
            t0 = time.perf_counter()
            path = ckpt.save(ckpt_dir, done, {
                "server": fed.server_params(state),
                "fed_state": state,
                "round": done,
                "config": run_config,
                "eta_scale": scale,
            }, keep=ckpt_keep)
            dt = time.perf_counter() - t0
        if registry is not None:
            registry.counter("ckpt_saves").inc()
            registry.counter("ckpt_bytes").inc(os.path.getsize(path))
            registry.histogram("ckpt_save_s").observe(dt)
        return done

    def traced_batches(it):
        """Wrap the batch stream so each ``next`` is a round/batch_build
        span.  Only installed when tracing -- the off path iterates the
        original generator untouched."""
        if not tracer.enabled:
            return it

        def gen():
            src = iter(it)
            while True:
                with tracer.span("round/batch_build"):
                    try:
                        b = next(src)
                    except StopIteration:
                        return
                yield b

        return gen()

    def attempt(fed, step_fn, round_fn, state, from_round, scale, wd):
        """One trajectory attempt from ``from_round``; returns
        ``(state, "done" | "diverged")``."""
        nonlocal last_saved
        data = traced_batches(make_data(from_round))

        ee = None
        if tol > 0.0:
            from repro.core import autotune
            ee = autotune.EarlyExit(tol, patience)

        def note_exit(i):
            saved = steps - i
            tracer.instant("autotune/early_exit",
                           {"round": i, "rounds_saved": saved,
                            "rel_residual": ee.last_rel})
            if registry is not None:
                registry.counter("rounds_saved").inc(saved)
            print(f"[train] early exit at round {i}: relative residual "
                  f"{ee.last_rel:.3g} < tol {tol:g} for {patience} "
                  f"consecutive round(s); {saved} budgeted round(s) saved",
                  flush=True)

        def log_round(i, state, metrics, eb):
            nonlocal last_saved
            with tracer.span("round/eval_log", {"round": i}):
                row = {"round": i,
                       "server_loss": float(eval_loss(fed.server_params(state), eb)),
                       **(metrics_row(metrics) if metrics is not None else {})}
            history.append(row)
            if sink is not None:
                # incremental: each logged row is flushed as it happens, so
                # the loss curve survives a crash (read_jsonl tolerates the
                # torn final line a mid-write kill leaves)
                sink.write({"kind": "round", **row})
            if registry is not None:
                # counters=(): logged rows carry LAST-dispatch values, so
                # they feed gauges/histograms only; the exact counter totals
                # come from note_faults, which sees every executed dispatch
                # (stacked scan rows and unlogged rounds included)
                registry.absorb(row, counters=())
            tracer.flush()
            print(f"[train] {json.dumps(row)}", flush=True)
            diverged = wd.note(row) if wd is not None else False
            healthy = (math.isfinite(row["server_loss"])
                       and (wd is None or wd.strikes == 0))
            if (ckpt_dir and ckpt_every > 0 and healthy
                    and (last_saved is None or i - last_saved >= ckpt_every)):
                save_anchor(fed, state, scale)
                last_saved = i
            return diverged

        if R > 1:
            # tail shorter than R (steps % R != 0) falls back to jitted,
            # donated per-round dispatches -- same step semantics, no eager
            # path
            pending = []
            i = from_round
            last = metrics = None
            for batch in data:
                pending.append(batch)
                last = batch
                if len(pending) < R:
                    continue
                with tracer.span("round/batch_stack", {"R": R}):
                    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *pending)
                pending = []
                if prof is not None:
                    # the scan dispatch is all-or-nothing: capture covers
                    # every R-round block intersecting the window
                    prof.before_round(i + 1)
                state, metrics = step_fn(state, stacked)  # metrics stacked (R,)
                note_faults(metrics)
                i += R
                if prof is not None:
                    jax.block_until_ready(state)
                    prof.after_round(i)
                if ee is not None and "res_dx2" in metrics:
                    # the scan chunk is all-or-nothing: the criterion may
                    # have fired mid-chunk, but the state already carries the
                    # whole chunk -- only the UNDISPATCHED rounds are saved
                    if ee.update(metrics["res_dx2"], metrics["res_x2"]) is not None:
                        note_exit(i)
                        eb = eval_batch if eval_batch is not None else last
                        if not history or history[-1]["round"] != i:
                            log_round(i, state, metrics, eb)
                        return state, "done"
                if (i - R) // max(1, log_every) != i // max(1, log_every):
                    eb = eval_batch if eval_batch is not None else last
                    if log_round(i, state, metrics, eb):
                        return state, "diverged"
            for batch in pending:
                state, metrics = round_fn(state, batch)
                note_faults(metrics)
                i += 1
            if last is not None and (not history or history[-1]["round"] != i):
                # always log the FINAL state (the R=1 path's i == steps-1 row)
                eb = eval_batch if eval_batch is not None else last
                if log_round(i, state, metrics, eb):
                    return state, "diverged"
            return state, "done"

        # ``i`` counts COMPLETED rounds after each dispatch (== the state's
        # round counter), the same numbering the R>1 scan path logs -- loss
        # curves from the two drivers line up row-for-row, and the guarded
        # ``max(1, log_every)`` matches it too (--log-every 0 used to
        # ZeroDivisionError here while the scan path survived)
        for i, batch in enumerate(data, start=from_round + 1):
            if prof is not None:
                prof.before_round(i)
            state, metrics = step_fn(state, batch)
            if prof is not None:
                # the capture window must hold COMPLETE rounds: force the
                # async dispatch to finish before deciding to stop
                jax.block_until_ready(state)
                prof.after_round(i)
            note_faults(metrics)
            if ee is not None and metrics and "res_dx2" in metrics:
                if ee.update(metrics["res_dx2"], metrics["res_x2"]) is not None:
                    note_exit(i)
                    eb = eval_batch if eval_batch is not None else batch
                    if not history or history[-1]["round"] != i:
                        log_round(i, state, metrics, eb)
                    return state, "done"
            if (i - 1) // max(1, log_every) != i // max(1, log_every) or i == steps:
                eb = eval_batch if eval_batch is not None else batch
                if log_round(i, state, metrics, eb):
                    return state, "diverged"
        return state, "done"

    t0 = time.perf_counter()
    rollbacks = 0
    wd = _Watchdog() if watchdog else None
    fed, step_fn, round_fn = build(eta_scale)
    if state is None:
        state = fed.init(params, m)
    if wd is not None and ckpt.latest_step(ckpt_dir) is None:
        # round-start anchor: the very first divergence has somewhere to
        # roll back to
        last_saved = save_anchor(fed, state, eta_scale)
    try:
        while True:
            state, status = attempt(fed, step_fn, round_fn, state, start,
                                    eta_scale, wd)
            if status == "done":
                break
            rollbacks += 1
            if rollbacks > max_rollbacks:
                raise RuntimeError(
                    f"divergence watchdog: {rollbacks} rollbacks exceeded "
                    f"max_rollbacks={max_rollbacks} (eta_scale={eta_scale:g}); "
                    f"the run does not converge at any tried stepsize")
            _anchor, payload = load_latest_good("watchdog rollback")
            state = payload["fed_state"]
            start = int(payload["round"])
            eta_scale *= eta_backoff
            wd = _Watchdog()
            tracer.instant("watchdog/rollback",
                           {"to_round": start, "eta_scale": eta_scale,
                            "rollbacks": rollbacks})
            if registry is not None:
                registry.counter("rollbacks").inc()
            print(f"[train] watchdog: diverged; rolled back to round {start}, "
                  f"eta_scale -> {eta_scale:g}", flush=True)
            fed, step_fn, round_fn = build(eta_scale)
        dt = time.perf_counter() - t0
        print(f"[train] {n_rounds} rounds (K={k}, m={m}) in {dt:.1f}s; algo={algorithm}, "
              f"rounds_per_call={R}" + (", cohort batches" if cohort else ""))

        if ckpt_dir:
            # the FULL fed state (arena buffers, server pytree, round counter),
            # not just server params: `load` + --resume continues the exact
            # trajectory.  "server" stays for serve-side consumers.
            done = int(state["round"])
            save_anchor(fed, state, eta_scale)
            # retention applies to the final save too, not just the periodic
            # anchors -- a finished run keeps exactly ckpt_keep
            print(f"[train] full-state checkpoint (round {done}) saved to {ckpt_dir}")
        if fault_cfg is not None or watchdog:
            print(f"[train] robustness: faults_injected={injected_total:.0f} "
                  f"demoted={demoted_total:.0f} rollbacks={rollbacks} "
                  f"eta_scale={eta_scale:g}")
    finally:
        # telemetry teardown runs on the crash path too: every flushed span
        # and JSONL row survives, and the summary row records the totals up
        # to the failure (the sinks are exactly for post-mortems)
        if prof is not None:
            prof.close()
        if registry is not None:
            registry.gauge("eta_scale").set(eta_scale)
        if sink is not None:
            sink.write({"kind": "summary", **registry.summary_row()})
            sink.close()
        if tel_on:
            print(f"[train] telemetry: "
                  f"{json.dumps(registry.summary_row(), default=float)}",
                  flush=True)
        if trace_out:
            trace_path = tracer.close()
            if trace_path:
                print(f"[train] trace written to {trace_path} "
                      f"(load in https://ui.perfetto.dev)", flush=True)
            tracer.configure(enabled=was_tracing)
    if expect_demotions and demoted_total < expect_demotions:
        raise RuntimeError(
            f"expected >= {expect_demotions} screened demotions, "
            f"saw {demoted_total:.0f}")
    if expect_rollbacks and rollbacks < expect_rollbacks:
        raise RuntimeError(
            f"expected >= {expect_rollbacks} watchdog rollbacks, "
            f"saw {rollbacks}")
    return history


def _eta_arg(s: str):
    """``--eta`` accepts a float or the literal ``auto``."""
    return "auto" if s == "auto" else float(s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--algorithm", default="gpdmm",
                    choices=["gpdmm", "agpdmm", "scaffold", "fedavg", "fedsplit"])
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--eta", type=_eta_arg, default=0.3,
                    help="client stepsize, or 'auto' to derive per-client "
                         "eta_i = safety / L_i from a power-iteration "
                         "curvature probe (see docs/autotune.md)")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="relative fixed-point residual tolerance: terminate "
                         "once ||x - x_prev|| / ||x|| < tol for --patience "
                         "consecutive rounds (0 = fixed round budget)")
    ap.add_argument("--patience", type=int, default=1,
                    help="consecutive sub-tol rounds required before the "
                         "early exit fires")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest full-state checkpoint from "
                         "--ckpt-dir and continue the same trajectory")
    ap.add_argument("--uplink-bits", type=int, default=None,
                    help="EF21 delta-quantised uplink (beyond paper)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients active per round (async PDMM; "
                         "< 1 runs the cohort-sampled round engine)")
    ap.add_argument("--popstore", default="auto", choices=["auto", "on", "off"],
                    help="host-resident population store: O(cohort) device "
                         "memory with prefetch-overlapped staging (auto = on "
                         "for cohort runs at >= popstore_min_clients)")
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="rounds per jitted dispatch (lax.scan round batching)")
    ap.add_argument("--log-every", type=int, default=5,
                    help="rounds between logged rows (the watchdog and the "
                         "periodic anchors act at logged rows)")
    ap.add_argument("--faults", default=None,
                    help="deterministic fault schedule, e.g. "
                         "'dropout=0.1,corrupt=0.05,seed=7' -- pure in "
                         "(seed, round, client), so the trace replays exactly")
    ap.add_argument("--screen", default="auto", choices=["auto", "on", "off"],
                    help="fused uplink screening (auto = on iff faults active)")
    ap.add_argument("--deadline", type=float, default=math.inf,
                    help="straggler deadline in rounds: a drawn lateness past "
                         "it demotes the client to silence for the round")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="admit stale uplinks up to this age (0 = the "
                         "synchronous point: delayed uplinks never land)")
    ap.add_argument("--stale-gamma", type=float, default=0.5,
                    help="admission weight gamma**age for arriving stale rows")
    ap.add_argument("--async", dest="async_rounds", default="auto",
                    choices=["auto", "on", "off"],
                    help="bounded-staleness round engine (auto = on iff the "
                         "staleness knobs deviate from the synchronous point)")
    ap.add_argument("--watchdog", action="store_true",
                    help="divergence watchdog: roll back to the newest healthy "
                         "checkpoint with eta backoff (needs --ckpt-dir)")
    ap.add_argument("--watchdog-factor", type=float, default=10.0,
                    help="a logged loss above factor x best counts as bad")
    ap.add_argument("--watchdog-patience", type=int, default=2,
                    help="consecutive bad logged rows before rollback")
    ap.add_argument("--eta-backoff", type=float, default=0.5,
                    help="eta multiplier applied on each rollback")
    ap.add_argument("--max-rollbacks", type=int, default=3)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save a rollback anchor every N logged rounds "
                         "(0 = final checkpoint only)")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="retain only the newest N anchors")
    ap.add_argument("--expect-demotions", type=int, default=0,
                    help="fail unless >= N uplinks were demoted (chaos CI gate)")
    ap.add_argument("--expect-rollbacks", type=int, default=0,
                    help="fail unless >= N rollbacks happened (chaos CI gate)")
    ap.add_argument("--telemetry", action="store_true",
                    help="metrics registry + structured end-of-run summary "
                         "(implied by any of the output flags below)")
    ap.add_argument("--trace-out", default=None,
                    help="write round-phase spans as Chrome trace-event JSON "
                         "(open in Perfetto); enables the span tracer")
    ap.add_argument("--metrics-out", default=None,
                    help="stream every logged history row + an end-of-run "
                         "summary to this JSONL file (crash-safe, one flush "
                         "per row)")
    ap.add_argument("--profile-rounds", default=None,
                    help="capture a jax.profiler device trace for exactly "
                         "rounds A:B (e.g. '3:5'; see docs/telemetry.md)")
    ap.add_argument("--profile-dir", default=None,
                    help="jax.profiler output dir (default: next to "
                         "--trace-out, else ./telemetry/jaxprof)")
    args = ap.parse_args()
    run(
        args.arch, reduced=args.reduced, steps=args.steps, algorithm=args.algorithm,
        k=args.k, eta=args.eta, tol=args.tol, patience=args.patience,
        m=args.clients, per_client_batch=args.batch,
        seq_len=args.seq, seed=args.seed, ckpt_dir=args.ckpt_dir, resume=args.resume,
        uplink_bits=args.uplink_bits, participation=args.participation,
        popstore_mode={"auto": "auto", "on": True, "off": False}[args.popstore],
        rounds_per_call=args.rounds_per_call, log_every=args.log_every,
        faults=args.faults,
        screen={"auto": "auto", "on": True, "off": False}[args.screen],
        deadline=args.deadline, max_staleness=args.max_staleness,
        stale_gamma=args.stale_gamma,
        async_rounds={"auto": "auto", "on": True, "off": False}[args.async_rounds],
        watchdog=args.watchdog, watchdog_factor=args.watchdog_factor,
        watchdog_patience=args.watchdog_patience, eta_backoff=args.eta_backoff,
        max_rollbacks=args.max_rollbacks, ckpt_every=args.ckpt_every,
        ckpt_keep=args.ckpt_keep, expect_demotions=args.expect_demotions,
        expect_rollbacks=args.expect_rollbacks,
        telemetry=args.telemetry, trace_out=args.trace_out,
        metrics_out=args.metrics_out, profile_rounds=args.profile_rounds,
        profile_dir=args.profile_dir,
    )


if __name__ == "__main__":
    main()
