"""Train-while-serve demo (ISSUE 7): checkpoint hot-swap end to end.

A trainer subprocess writes full-state checkpoint anchors every round while
THIS process serves query batches from the same directory via the hot-swap
watcher (``launch.serve.run_watch``): the server picks up each new anchor
between query batches, and a deliberately truncated checkpoint file is
REJECTED loudly while serving continues from the last good step.

    PYTHONPATH=src python examples/serve_demo.py

Phases:
  1. train rounds 0..3 and stop (anchors step_1..3 on disk);
  2. plant a truncated file at a far-future step -- the newest file in the
     directory is now garbage, which is exactly the case ``latest_step``
     alone cannot survive;
  3. start the hot-swap server in a thread: it must reject the planted file
     and serve step 3;
  4. resume the trainer to round 6 while the server keeps answering queries
     -- the served step must advance as new anchors land.

The batched static-serving demo (prefill + per-arch decode cache) stays at
the end.
"""
import os
import pathlib
import subprocess
import sys
import tempfile
import threading
import time

from repro import checkpoint as ckpt
from repro.launch.serve import run as serve_once
from repro.launch.serve import run_watch

REPO_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
ENV = {**os.environ, "PYTHONPATH": REPO_SRC}


def train(ckpt_dir: str, steps: int, *, resume: bool = False):
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "olmo-1b",
           "--steps", str(steps), "--k", "1", "--eta", "0.05",
           "--clients", "2", "--batch", "2", "--seq", "32",
           "--log-every", "1", "--ckpt-dir", ckpt_dir, "--ckpt-every", "1"]
    if resume:
        cmd.append("--resume")
    subprocess.run(cmd, check=True, env=ENV)


with tempfile.TemporaryDirectory() as d:
    print("=== phase 1: train rounds 0..3 ===", flush=True)
    train(d, 3)
    assert ckpt.steps(d), "trainer wrote no anchors"

    print("=== phase 2: plant a truncated checkpoint at the newest step ===",
          flush=True)
    fake = pathlib.Path(d) / "step_99999999.msgpack"
    fake.write_bytes(b"\x00" * 37)  # unreadable msgpack, newest by name

    print("=== phase 3+4: serve while the trainer resumes to round 6 ===",
          flush=True)
    rows: list = []
    stop = threading.Event()
    out: dict = {}

    def serve_loop():
        out["history"], out["watcher"] = run_watch(
            "olmo-1b", ckpt_dir=d, batch=2, prompt_len=16, new_tokens=2,
            poll_interval=0.2, duration=600.0, wait_first=30.0,
            stop_when=stop.is_set, history=rows)

    th = threading.Thread(target=serve_loop)
    th.start()
    try:
        t0 = time.time()
        while not rows:  # server up and answering before the trainer resumes
            assert th.is_alive(), "serve thread died before the first query"
            assert time.time() - t0 < 120, "server never answered a query"
            time.sleep(0.2)
        first_step = rows[0]["step"]

        train(d, 6, resume=True)
        t0 = time.time()
        while rows[-1]["step"] < 6 and time.time() - t0 < 30:
            time.sleep(0.2)  # grace: let the watcher poll the final anchor
    finally:
        stop.set()
        th.join(timeout=120)
    assert not th.is_alive(), "serve thread failed to stop"

    history, watcher = out["history"], out["watcher"]
    served = sorted({row["step"] for row in history})
    rounds = sorted({row["round"] for row in history})
    print(f"[demo] served steps {served}, rounds {rounds}, "
          f"swaps={watcher.swaps} rejected={watcher.failures}")
    assert watcher.failures >= 1, "truncated checkpoint was never rejected"
    assert 99999999 in watcher.bad, "the planted file was not the reject"
    assert first_step <= 3, f"first served step {first_step} not from phase 1"
    assert len(served) >= 2, f"served step never advanced: {served}"
    assert max(rounds) > min(rounds), f"served round never advanced: {rounds}"
    steps_seq = [row["step"] for row in history]
    assert steps_seq == sorted(steps_seq), "served step went backwards"
    print("[demo] hot-swap serving OK: truncated anchor rejected, "
          "served round advanced with training")

print("\n=== static batched serving (per-arch decode caches) ===")
for arch in ["olmo-1b", "rwkv6-1.6b"]:
    print(f"\n=== {arch} (reduced config) ===")
    serve_once(arch, reduced=True, batch=4, prompt_len=32, new_tokens=8)
