"""Flat client-state arena: one contiguous ``(m, width)`` buffer per stacked
client pytree.

The GPDMM/AGPDMM round is memory-bound elementwise math over the stacked
``(m, params)`` client state; running it as per-leaf ``tree.map`` chains costs
one kernel launch *per leaf per op* and re-reads every leaf from HBM each
time.  The arena packs all leaves of one client into a single row so that

  * every round-tail op is ONE fused pass over ONE buffer;
  * Pallas kernels tile the row as ``(width // 128, 128)`` without ever
    straddling a leaf boundary (each leaf is padded to a multiple of the
    128-lane TPU register width, so leaf edges always fall on row edges of
    the tiled view);
  * the server aggregation stays a single ``mean(axis=0)`` -- one all-reduce
    when dim 0 is sharded over the client mesh axis.

Layout (per client row, ``LANES = 128``)::

    [ leaf0 ......  | 0-pad ][ leaf1 | 0-pad ] ... [ leafL | 0-pad ]
      size0           to 128x  size1   to 128x

Padding is ZERO-FILLED and every arena op used by the round maps 0 -> 0
(linear updates, quantise-dequantise, masked selects, client means), so the
padding stays identically zero across rounds -- norms and sums over arena
buffers need no masking.  ``docs/arena.md`` documents the layout and the
donation contract.

The spec is pure static metadata (shapes/dtypes only), so ``from_tree`` can
be called on tracers inside a jitted round at zero runtime cost.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# the canonical TPU lane width the kernels tile against; every leaf slice
# is padded to a multiple of it
from repro.kernels.fused_update import LANES, ceil_to as _ceil_to


@dataclasses.dataclass(frozen=True)
class LeafSlice:
    """Static slice-table entry for one pytree leaf inside the arena row."""

    path: str  # human-readable key path (debug / docs)
    shape: Tuple[int, ...]  # per-client leaf shape (no client dim)
    dtype: Any  # original leaf dtype (restored by unpack)
    offset: int  # start column in the arena row; multiple of LANES
    size: int  # prod(shape)
    padded: int  # size rounded up to a multiple of LANES

    @property
    def rows(self) -> int:
        """Rows this leaf occupies in the ``(width // LANES, LANES)`` view."""
        return self.padded // LANES


@dataclasses.dataclass(frozen=True)
class ArenaSpec:
    """Static pack/unpack metadata for one parameter pytree.

    Built from the *per-client* (unstacked) tree structure; the stacked
    ``(m, ...)`` variants reuse the same slice table with a leading row dim.
    """

    treedef: Any  # jax PyTreeDef
    leaves: Tuple[LeafSlice, ...]
    width: int  # row length; multiple of LANES
    dtype: Any  # common arena dtype (result_type of all leaves)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_tree(cls, tree, *, stacked: bool = False) -> "ArenaSpec":
        """Spec for ``tree``; with ``stacked=True`` leaves carry a leading
        client dim that is excluded from the slice table."""
        paths_leaves = jax.tree_util.tree_leaves_with_path(tree)
        treedef = jax.tree.structure(tree)
        entries = []
        off = 0
        for path, leaf in paths_leaves:
            shape = tuple(leaf.shape[1:] if stacked else leaf.shape)
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            padded = _ceil_to(size, LANES)
            entries.append(
                LeafSlice(
                    path=jax.tree_util.keystr(path),
                    shape=shape,
                    dtype=leaf.dtype,
                    offset=off,
                    size=size,
                    padded=padded,
                )
            )
            off += padded
        dtype = jnp.result_type(*(e.dtype for e in entries))
        return cls(treedef=treedef, leaves=tuple(entries), width=off, dtype=dtype)

    # -- derived static tables ---------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.width // LANES

    def leaf_rows(self) -> Tuple[int, ...]:
        """Per-leaf row counts of the ``(n_rows, LANES)`` tiled view -- the
        static segment table the fused EF21 reduction uses."""
        return tuple(e.rows for e in self.leaves)

    # -- pack / unpack ------------------------------------------------------
    def _pack_leaves(self, leaves, lead: Tuple[int, ...]):
        parts = []
        for e, leaf in zip(self.leaves, leaves):
            flat = jnp.reshape(leaf, lead + (e.size,)).astype(self.dtype)
            if e.padded != e.size:
                pad = [(0, 0)] * len(lead) + [(0, e.padded - e.size)]
                flat = jnp.pad(flat, pad)
            parts.append(flat)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)

    def pack(self, tree):
        """Server pytree -> ``(width,)`` arena row (zero-filled padding)."""
        return self._pack_leaves(jax.tree.leaves(tree), ())

    def pack_stacked(self, tree):
        """Stacked ``(m, ...)`` pytree -> ``(m, width)`` arena buffer."""
        leaves = jax.tree.leaves(tree)
        m = leaves[0].shape[0]
        return self._pack_leaves(leaves, (m,))

    def _unpack_row(self, arr, lead: Tuple[int, ...]):
        leaves = []
        for e in self.leaves:
            flat = jax.lax.slice_in_dim(arr, e.offset, e.offset + e.size, axis=len(lead))
            leaves.append(jnp.reshape(flat, lead + e.shape).astype(e.dtype))
        return jax.tree.unflatten(self.treedef, leaves)

    def unpack(self, row):
        """``(width,)`` arena row -> server pytree (original dtypes)."""
        assert row.shape == (self.width,), (row.shape, self.width)
        return self._unpack_row(row, ())

    def unpack_stacked(self, arr):
        """``(m, width)`` arena buffer -> stacked ``(m, ...)`` pytree."""
        assert arr.ndim == 2 and arr.shape[1] == self.width, (arr.shape, self.width)
        return self._unpack_row(arr, (arr.shape[0],))

    # -- views --------------------------------------------------------------
    def leaf_view(self, arr, index: int):
        """Reshaped view of one leaf inside an arena buffer (no copy under
        jit; stacked or unstacked inferred from rank)."""
        e = self.leaves[index]
        lead = () if arr.ndim == 1 else (arr.shape[0],)
        flat = jax.lax.slice_in_dim(arr, e.offset, e.offset + e.size, axis=len(lead))
        return jnp.reshape(flat, lead + e.shape)


def zeros(spec: ArenaSpec, m: int | None = None):
    """Fresh zero arena: ``(width,)`` or ``(m, width)``."""
    shape = (spec.width,) if m is None else (m, spec.width)
    return jnp.zeros(shape, spec.dtype)
