"""Static graph topologies for decentralized PDMM (the general-network
setting the paper specializes away from).

The source paper notes PDMM "was originally designed for solving a
decomposable optimisation problem over a general network" and then works out
the centralised/star case; this module restores the general case.  A
``Topology`` describes an undirected connected graph of ``n`` nodes compiled
into STATIC (numpy, trace-time-free) tables:

  * a CSR-style neighbor table: directed slot ``t`` holds the directed dual
    ``z_{i|j}`` (owner ``src[t] = i``, neighbor ``nbr[t] = j``); node ``i``'s
    outgoing slots are the contiguous range ``indptr[i]:indptr[i+1]`` (its
    slice of the edge-dual arena), so the per-node dual sum is a static
    segment reduction;
  * the consensus constraint signs ``sgn[t] = A_{ij}`` (+1 if i < j else -1,
    so every edge enforces x_i - x_j = 0);
  * the reverse permutation ``rev`` with ``rev[slot(i|j)] = slot(j|i)`` --
    the static route of PDMM's directed dual exchange;
  * a greedy proper coloring (``colors``), the sequential-firing schedule:
    updating color classes in order generalises the centralised
    clients-then-server round (on a star the coloring is exactly
    {clients}, {server}, which is why star graph-PDMM reproduces
    ``core.pdmm``/``core.gpdmm`` round for round -- see
    ``tests/test_topology.py``).

The **edge-dual arena** is the ``(2|E|, width)`` counterpart of the client
arena (``core.arena``): row ``t`` holds ``z_{src[t]|nbr[t]}`` packed to the
same 128-lane-padded ``ArenaSpec`` width, zero-filled padding, donated in
place round over round.  ``docs/topology.md`` documents the layout.

Star graphs carry one AUX node (the center, index ``n - 1``) with f = 0 --
the decentralized picture of the paper's server.  ``n_data`` counts the
nodes that own an objective term f_i (and hence a batch row); aux nodes
update by the closed-form f = 0 prox.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """Compiled static graph (all arrays numpy; safe to close over in jit)."""

    name: str
    n: int  # total nodes (incl. aux)
    n_data: int  # nodes carrying an f_i / a batch row; aux nodes have f = 0
    src: np.ndarray  # (2E,) int32 owner i of directed slot z_{i|j}
    nbr: np.ndarray  # (2E,) int32 neighbor j
    sgn: np.ndarray  # (2E,) int32 A_{ij} in {+1, -1} (+1 iff i < j)
    indptr: np.ndarray  # (n+1,) int32: node i's slots = indptr[i]:indptr[i+1]
    rev: np.ndarray  # (2E,) int32: rev[slot(i|j)] = slot(j|i)
    colors: Tuple[np.ndarray, ...]  # proper coloring; class arrays of node ids

    # -- derived -----------------------------------------------------------
    @property
    def n_slots(self) -> int:
        """Directed dual count = rows of the edge-dual arena (2|E|)."""
        return int(self.src.shape[0])

    @property
    def n_edges(self) -> int:
        return self.n_slots // 2

    @property
    def deg(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.deg.max())

    @property
    def n_aux(self) -> int:
        return self.n - self.n_data

    def data_degree_constant(self) -> bool:
        """Do all data nodes share one degree?  Gates the scalar-rho fused
        arena kernels (per-node degrees need the vector XLA path)."""
        d = self.deg[: self.n_data]
        return bool((d == d[0]).all())

    def first_flags(self) -> np.ndarray:
        """(2E,) int32: 1 at each node's first slot -- the segment-start
        marker the fused neighbor-reduce kernel zero-initialises on."""
        f = np.zeros(self.n_slots, np.int32)
        starts = self.indptr[:-1][self.deg > 0]
        f[starts] = 1
        return f

    def slot(self, i: int, j: int) -> int:
        """Directed slot of z_{i|j} (python-side; tests/debug)."""
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        for t in range(lo, hi):
            if self.nbr[t] == j:
                return t
        raise KeyError(f"no edge {i} -- {j} in {self.name}")

    def edges(self) -> Tuple[Tuple[int, int], ...]:
        """Undirected edge list (i < j), sorted."""
        return tuple(
            (int(self.src[t]), int(self.nbr[t]))
            for t in range(self.n_slots)
            if self.src[t] < self.nbr[t]
        )


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def _check_connected(n: int, edges) -> None:
    parent = list(range(n))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for i, j in edges:
        parent[find(i)] = find(j)
    roots = {find(i) for i in range(n)}
    if len(roots) != 1:
        raise ValueError(f"graph is disconnected ({len(roots)} components)")


def _greedy_coloring(n: int, adj) -> Tuple[np.ndarray, ...]:
    """Greedy proper coloring by node index; at most max_degree + 1 classes.
    Clients-before-server node orderings (star) get the 2-class
    {clients}, {server} schedule that reproduces the centralised round."""
    color = np.full(n, -1, np.int32)
    for i in range(n):
        used = {int(color[j]) for j in adj[i] if color[j] >= 0}
        c = 0
        while c in used:
            c += 1
        color[i] = c
    k = int(color.max()) + 1
    return tuple(np.nonzero(color == c)[0].astype(np.int32) for c in range(k))


def compile_edges(name: str, n: int, edges: Iterable[Tuple[int, int]],
                  *, n_data: int | None = None) -> Topology:
    """Compile an undirected edge list into the static CSR tables."""
    uniq = sorted({(min(i, j), max(i, j)) for i, j in edges})
    if n < 2:
        raise ValueError(f"need at least 2 nodes, got {n}")
    for i, j in uniq:
        if i == j:
            raise ValueError(f"self-loop at node {i}")
        if not (0 <= i < n and 0 <= j < n):
            raise ValueError(f"edge ({i}, {j}) out of range for n={n}")
    _check_connected(n, uniq)

    adj = [[] for _ in range(n)]
    for i, j in uniq:
        adj[i].append(j)
        adj[j].append(i)
    for lst in adj:
        lst.sort()

    src, nbr, sgn, indptr = [], [], [], [0]
    slot_of = {}
    for i in range(n):
        for j in adj[i]:
            slot_of[(i, j)] = len(src)
            src.append(i)
            nbr.append(j)
            sgn.append(1 if i < j else -1)
        indptr.append(len(src))
    rev = np.array([slot_of[(j, i)] for i, j in zip(src, nbr)], np.int32)

    return Topology(
        name=name,
        n=n,
        n_data=n if n_data is None else n_data,
        src=np.array(src, np.int32),
        nbr=np.array(nbr, np.int32),
        sgn=np.array(sgn, np.int32),
        indptr=np.array(indptr, np.int32),
        rev=rev,
        colors=_greedy_coloring(n, adj),
    )


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------

def star(m: int) -> Topology:
    """m data nodes + one AUX center (index m, f = 0): the decentralized
    picture of the paper's centralised network."""
    if m < 1:
        raise ValueError("star needs at least 1 client")
    return compile_edges("star", m + 1, [(i, m) for i in range(m)], n_data=m)


def ring(n: int) -> Topology:
    if n < 3:
        raise ValueError("ring needs at least 3 nodes")
    return compile_edges("ring", n, [(i, (i + 1) % n) for i in range(n)])


def complete(n: int) -> Topology:
    return compile_edges(
        "complete", n, [(i, j) for i in range(n) for j in range(i + 1, n)]
    )


def torus2d(rows: int, cols: int) -> Topology:
    """2D torus (wrap-around grid).  rows * cols nodes, degree 4 (degenerate
    2-wide sides dedupe to degree 3)."""
    if rows < 2 or cols < 2:
        raise ValueError("torus2d needs rows, cols >= 2")
    edges = []
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            edges.append((i, r * cols + (c + 1) % cols))
            edges.append((i, ((r + 1) % rows) * cols + c))
    return compile_edges("torus2d", rows * cols, edges)


def erdos_renyi(n: int, p: float = 0.4, seed: int = 0) -> Topology:
    """G(n, p) made connected: components are chained together by an extra
    edge between their smallest nodes (deterministic in the seed)."""
    rng = np.random.RandomState(seed)
    edges = [
        (i, j) for i in range(n) for j in range(i + 1, n) if rng.rand() < p
    ]
    # connect components deterministically
    parent = list(range(n))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for i, j in edges:
        parent[find(i)] = find(j)
    reps = sorted({find(i) for i in range(n)})
    for a, b in zip(reps, reps[1:]):
        edges.append((a, b))
        parent[find(a)] = find(b)
    return compile_edges("er", n, edges)


def _torus_shape(m: int) -> Tuple[int, int]:
    """Largest divisor pair (r, c) with r <= c, r maximal (nearest square)."""
    r = int(np.floor(np.sqrt(m)))
    while r >= 2 and m % r:
        r -= 1
    if r < 2:
        raise ValueError(f"torus needs a composite node count, got {m}")
    return r, m // r


def make(spec: str, m: int, *, seed: int = 0) -> Topology:
    """Parse a ``FederatedConfig.topology`` string for ``m`` data nodes.

    ``"star"`` | ``"ring"`` | ``"complete"`` | ``"torus"`` |
    ``"er"`` / ``"er:<p>"``.  Star adds the aux center (n = m + 1); every
    other family uses the m data nodes directly.
    """
    kind, _, arg = spec.partition(":")
    if arg and kind != "er":
        raise ValueError(
            f"topology {spec!r}: only 'er' takes a ':<arg>' suffix (er:<p>)")
    if kind == "star":
        return star(m)
    if kind == "ring":
        return ring(m)
    if kind == "complete":
        return complete(m)
    if kind == "torus":
        return torus2d(*_torus_shape(m))
    if kind == "er":
        return erdos_renyi(m, float(arg) if arg else 0.4, seed)
    raise ValueError(
        f"unknown topology {spec!r} (star | ring | complete | torus | er[:p])"
    )
