"""Beyond-paper table: communication-efficiency variants of (A)GPDMM on the
paper's least-squares problem.

Rows: exact | 8-bit EF21 uplink | 4-bit EF21 uplink | participation 0.5 |
participation 0.5 + 8-bit.  Columns: rounds to ||x-x*|| <= 1e-3 and
uplink bytes/client/round -- the product is the total wire cost to target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.base import FederatedConfig
from repro.core import make, quadratic

TARGET = 1e-3
MAX_ROUNDS = 1200


def rounds_to_target(prob, algo, **kw):
    opt = make(FederatedConfig(algorithm=algo, inner_steps=5, eta=0.5 / prob.L, **kw))
    s = opt.init(jnp.zeros((prob.d,)), prob.m)

    @jax.jit
    def rf(s):
        s, _ = opt.round(s, prob.grad, prob.batch())
        return s

    for r in range(1, MAX_ROUNDS + 1):
        s = rf(s)
        if r % 10 == 0 and float(prob.dist(opt.server_params(s))) <= TARGET:
            return r
    return MAX_ROUNDS + 1


def run():
    prob = quadratic.generate(jax.random.key(0), m=8, n=400, d=64)
    f32 = prob.d * 4
    variants = [
        ("exact", {}, f32),
        ("uplink8", {"uplink_bits": 8}, prob.d + 4),
        ("uplink4", {"uplink_bits": 4}, prob.d // 2 + 4),
        ("part0.5", {"participation": 0.5}, f32 // 2),  # half the clients
        ("part0.5_uplink8", {"participation": 0.5, "uplink_bits": 8}, (prob.d + 4) // 2),
    ]
    results = {}
    for algo in ("gpdmm", "agpdmm"):
        for name, kw, bpr in variants:
            r = rounds_to_target(prob, algo, **kw)
            total_kb = r * bpr / 1024
            results[(algo, name)] = (r, total_kb)
            emit(f"beyond_{algo}_{name}", 0.0,
                 f"rounds_to_1e-3={r} wire_B_per_round={bpr} total_KiB={total_kb:.1f}")
    # wire-efficiency claims: every compressed variant reaches target, and
    # 8-bit EF21 costs less total wire than exact for both algorithms
    for algo in ("gpdmm", "agpdmm"):
        assert results[(algo, "uplink8")][0] <= MAX_ROUNDS
        assert results[(algo, "uplink4")][0] <= MAX_ROUNDS
        assert results[(algo, "part0.5")][0] <= MAX_ROUNDS
        assert results[(algo, "uplink8")][1] < results[(algo, "exact")][1]
    return results


if __name__ == "__main__":
    run()
