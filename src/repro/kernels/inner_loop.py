"""Pallas TPU kernel: the WHOLE K-step eq. (20) inner loop for affine
gradient oracles, one client per grid step.

For the quadratic testbed (least squares / ridge) the per-client gradient is
affine in arena coordinates:

    grad_i(x) = H_i x - c_i        (H_i = A_i^T A_i + reg I, c_i = A_i^T b_i)

so the K inexact-PDMM steps

    x <- x - step * ((H x - c) + rho * (x - x_s) + lam)        (eq. 20)

form a closed recurrence over VMEM-resident data: the kernel loads one
client's row block (x0, c, lam, the shared server row x_s) and its H matrix
once, runs all K steps with a ``fori_loop`` carrying (x, sum_k x), and writes
x_K and x_bar back.  That is ONE HBM read + ONE write of the client state for
the whole inner loop, versus K round trips for the step-at-a-time path (and
the matvec hits the MXU instead of re-streaming the state through the VPU K
times).

Optional operands (both VMEM-resident per client, loaded once for all K
steps):

  * ``off`` -- a per-client offset row ADDED to the affine constant:
    grad_i(x) = H_i x - (c_i + off_i).  This is the SCAFFOLD control-variate
    hook: the client correction ``- c_i`` rides as ``off = c_i`` (sign folded
    by the caller into c, see ``docs/inner_loop.md``) with ZERO extra HBM
    materialisation -- the arena-resident control-variate buffer is read
    directly.
  * ``lam=None`` drops the dual operand entirely (SCAFFOLD/FedAvg run with
    rho = 0 and no dual): one fewer row-sized HBM read per client.

VMEM budget (``vmem_bytes``): the f32 working set of one grid step is the
(W, W) H block plus ~10 row-sized (W,) buffers (x0/c/xs/lam/off in, x_K/x_bar
out, 2 loop-carry rows), which must fit the shared ``VMEM_CAP_BYTES`` (8 MiB
= half the ~16 MiB/core, leaving room for Pallas' double-buffered pipeline).
That caps W at ~1400 lanes; ``fits_vmem`` is the static gate the round uses
to fall back to the step-at-a-time scan for wider problems.

Layout contract (``core.arena``): W % 128 == 0; H rows/cols and c/off entries
beyond each leaf's true size are ZERO so the padding invariant survives
(padded coordinates see g = 0 - 0 and rho * (0 - 0) + 0, staying 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_update import LANES, VMEM_CAP_BYTES, eq20


def vmem_bytes(width: int) -> int:
    """f32 working set of one client grid step: H (W x W) + ~10 rows."""
    return 4 * (width * width + 10 * width)


def fits_vmem(width: int) -> bool:
    """Static gate: can the fused K-step kernel hold one client in VMEM?"""
    return width % LANES == 0 and vmem_bytes(width) <= VMEM_CAP_BYTES


def _kernel(*refs, K: int, step, rho: float, has_lam: bool, has_off: bool,
            has_step: bool = False):
    it = iter(refs)
    x_ref, h_ref, c_ref, xs_ref = next(it), next(it), next(it), next(it)
    lam_ref = next(it) if has_lam else None
    off_ref = next(it) if has_off else None
    step_ref = next(it) if has_step else None
    xk_ref, xb_ref = next(it), next(it)

    f32 = jnp.float32
    H = h_ref[0].astype(f32)  # (W, W), resident for all K steps
    c = c_ref[...].astype(f32)  # (1, W)
    if off_ref is not None:  # per-client affine offset: g = H x - (c + off)
        c = c + off_ref[...].astype(f32)
    xs = xs_ref[...].astype(f32)
    lam = lam_ref[...].astype(f32) if lam_ref is not None else None
    x0 = x_ref[...].astype(f32)
    if step_ref is not None:  # per-client stepsize operand (core.autotune)
        step = step_ref[0, 0]

    def body(_, carry):
        x, xsum = carry
        # g_j = sum_e H[j, e] x[e]: contract x's lane dim with H's col dim
        g = jax.lax.dot_general(
            x, H, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=f32,
        ) - c
        x = eq20(x, g, xs, lam, step, rho)
        return x, xsum + x

    x_K, xsum = jax.lax.fori_loop(0, K, body, (x0, jnp.zeros_like(x0)))
    xk_ref[...] = x_K.astype(xk_ref.dtype)
    xb_ref[...] = (xsum * (1.0 / K)).astype(xb_ref.dtype)


def inner_loop_affine_pallas(x0, H, c, x_s, lam, step, rho, K: int, *,
                             off=None, interpret: bool = False):
    """x0, c: (m, W); H: (m, W, W); x_s: (W,) server row (broadcast
    in-kernel); lam: (m, W) or None (dual term dropped); off: (m, W) or None
    (per-client affine offset, g = H x - (c + off)); step: scalar (baked as
    a compile-time constant -- the pre-auto-eta path, bitwise unchanged) or
    (m,) per-client stepsizes loaded as a (1, LANES) row operand per grid
    step (core.autotune).  Returns (x_K, x_bar), both (m, W)."""
    m, w = x0.shape
    assert w % LANES == 0, f"arena width {w} not a multiple of {LANES}"
    assert H.shape == (m, w, w) and c.shape == (m, w), (H.shape, c.shape)
    assert lam is None or lam.shape == (m, w), lam.shape
    assert off is None or off.shape == (m, w), off.shape
    assert fits_vmem(w), (
        f"width={w}: fused K-step working set {vmem_bytes(w)} B exceeds the "
        f"{VMEM_CAP_BYTES} B VMEM budget -- use the step-at-a-time path")
    row_bs = pl.BlockSpec((1, w), lambda i: (i, 0))
    out_sds = jax.ShapeDtypeStruct((m, w), x0.dtype)
    args = [x0, H, c, x_s.reshape(1, w)]
    in_specs = [
        row_bs,
        pl.BlockSpec((1, w, w), lambda i: (i, 0, 0)),
        row_bs,
        pl.BlockSpec((1, w), lambda i: (0, 0)),  # server row: every client
    ]
    if lam is not None:
        args.append(lam)
        in_specs.append(row_bs)
    if off is not None:
        args.append(off)
        in_specs.append(row_bs)
    has_step = jnp.ndim(step) > 0
    if has_step:
        assert step.shape == (m,), step.shape
        args.append(jnp.broadcast_to(
            step.astype(jnp.float32)[:, None], (m, LANES)))
        in_specs.append(pl.BlockSpec((1, LANES), lambda i: (i, 0)))
    x_K, x_bar = pl.pallas_call(
        functools.partial(_kernel, K=int(K),
                          step=None if has_step else float(step),
                          rho=float(rho),
                          has_lam=lam is not None, has_off=off is not None,
                          has_step=has_step),
        grid=(m,),
        in_specs=in_specs,
        out_specs=(row_bs, row_bs),
        out_shape=(out_sds, out_sds),
        interpret=interpret,
    )(*args)
    return x_K, x_bar
