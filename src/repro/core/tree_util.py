"""Pytree arithmetic used by the federated core.  Per-client state is stored
*stacked*: every leaf gains a leading client dim of size m.  On the production
mesh that dim is sharded over the client axis ("data", or ("pod","data")), so
``tree_client_mean`` lowers to exactly one all-reduce over the client axis --
the server aggregation of the paper's star graph.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def tmap(fn, *trees):
    return jax.tree.map(fn, *trees)


def tree_add(a, b):
    return tmap(jnp.add, a, b)


def tree_sub(a, b):
    return tmap(jnp.subtract, a, b)


def tree_scale(a, s):
    return tmap(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y"""
    return tmap(lambda a, b: alpha * a + b, x, y)


def tree_zeros_like(a):
    return tmap(jnp.zeros_like, a)


def tree_client_mean(stacked):
    """Mean over the leading client dim -> server aggregation (one all-reduce
    over the client mesh axis when dim 0 is sharded over it)."""
    return tmap(lambda x: jnp.mean(x, axis=0), stacked)


def tree_client_sum(stacked):
    return tmap(lambda x: jnp.sum(x, axis=0), stacked)


def tree_broadcast(tree, m: int):
    """Replicate a server pytree to the stacked (m, ...) client layout."""
    return tmap(lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), tree)


def tree_stack(trees):
    return tmap(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_index(stacked, i):
    return tmap(lambda x: x[i], stacked)


def tree_vdot(a, b):
    # NB: jnp.vdot ravels its inputs -- a reshape that merges a sharded dim
    # forces GSPMD to all-gather the full tensor (observed GiB-scale
    # collectives from metrics alone).  jnp.sum(a*b) reduces in place.
    leaves = jax.tree.leaves(
        tmap(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    )
    return sum(leaves, start=jnp.zeros((), jnp.float32))


def tree_sqnorm(a):
    return tree_vdot(a, a)


def tree_norm(a):
    return jnp.sqrt(tree_sqnorm(a))


def tree_client_sqnorms(stacked):
    """Per-client squared norms: (m,) array summed over all leaves."""
    leaves = jax.tree.leaves(
        tmap(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32)), axis=tuple(range(1, x.ndim))), stacked)
    )
    return sum(leaves)


def tree_cast(a, dtype):
    return tmap(lambda x: x.astype(dtype), a)


# ---------------------------------------------------------------------------
# quantized uplink (beyond-paper extension, EXPERIMENTS.md SSPerf H3)
# ---------------------------------------------------------------------------

def _qdq(x, bits: int):
    """Symmetric per-(client, leaf) fake-quantise: returns dequantised value.

    The scale is max-abs over each client's slice (axis 0 is the client dim),
    mirroring what each client would compute locally before transmitting
    int<bits> + one f32 scale."""
    lo = float(2 ** (bits - 1) - 1)
    red = tuple(range(1, x.ndim))
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=red, keepdims=True) / lo
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -lo, lo)
    return (q * scale).astype(x.dtype)


def tree_select(mask, a, b):
    """Per-client select over stacked (m, ...) pytrees: leaf[i] = a[i] if
    mask[i] else b[i]; mask (m,) bool broadcast to each leaf's rank."""
    def one(x, y):
        mk = mask.reshape((mask.shape[0],) + (1,) * (x.ndim - 1))
        return jnp.where(mk, x, y)
    return tmap(one, a, b)


def cohort_count(m: int, frac: float) -> int:
    """Static active-cohort size: ceil(frac * m), at least 1.  The single
    source of truth shared by ``participation_mask`` and the cohort engine's
    gather tables -- the two MUST agree or gathered rounds drift from masked
    ones.

    The ceil is representation-tolerant: ``0.07 * 100`` is
    ``7.000000000000001`` in binary floating point, and a naive float ceil
    turns the documented "exactly ceil(frac*m)" into an overcount of one
    (8 at m=100, 701 at m=10^4).  We round to the nearest integer first and
    keep that integer whenever the product is within a few ulps of it."""
    prod = frac * m
    nearest = round(prod)
    if abs(prod - nearest) <= 1e-9 * max(1.0, abs(prod)):
        n = int(nearest)
    else:
        n = int(math.ceil(prod))
    return max(1, n)


def participation_mask(key, m: int, frac: float):
    """Deterministic participation mask: exactly ceil(frac*m) active clients,
    chosen by a seeded permutation (jit-safe, static count)."""
    order = jax.random.permutation(key, m)
    return order < cohort_count(m, frac)


def cohort_indices(key, m: int, frac: float):
    """The round's active cohort as (idx, mask): ``mask`` is EXACTLY
    ``participation_mask(key, m, frac)`` and ``idx`` (static size
    ``cohort_count``) lists the active client ids in ascending order --
    sorted so externally produced cohort-sized batch streams
    (``data.synthetic.cohort_lm_batches``) can line their rows up with the
    engine's gather by client id alone."""
    n_active = cohort_count(m, frac)
    mask = participation_mask(key, m, frac)
    idx = jnp.nonzero(mask, size=n_active)[0]
    return idx, mask


def masked_client_mean(vals, mask):
    """Mean of a per-client ``(m,)`` metric over the ACTIVE clients only
    (``mask=None`` = all).  Used by the drift metrics: silent clients' x_K is
    computed-then-discarded on the masked path (carry kept), so averaging it
    in reported movement that never entered the state."""
    if mask is None:
        return jnp.mean(vals)
    mk = mask.astype(vals.dtype)
    return jnp.sum(vals * mk) / jnp.maximum(jnp.sum(mk), 1.0)


def tree_quantize_delta(tree, u_hat, bits: int):
    """EF21-style difference compression of a stacked (m, ...) uplink pytree.

    Each client transmits q(u_i - u_hat_i); both sides integrate
    u_hat_i += q(.), so the server's view converges to u_i: the transmitted
    *delta* (and with it the max-abs quantisation scale) shrinks as the
    optimiser converges, driving the compression error to zero.  Directly
    quantising u_i instead stalls at the quantisation floor because PDMM's
    dual variables integrate the per-round rounding error (shown in
    tests/test_core.py).

    Returns the new server view u_hat'.
    """
    delta = tree_sub(tree, u_hat)
    sent = tmap(lambda p: _qdq(p, bits), delta)
    return tree_add(u_hat, sent)
