"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all three backends (ref oracle, chunked XLA, Pallas interpret) allclose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_update import fused_update_pallas
from repro.kernels.wkv6 import wkv6_pallas


def rnd(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# fused_update (paper eq. 20)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64,), (33, 7), (2, 130, 19), (512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_update_sweep(shape, dtype):
    k = jax.random.key(0)
    x, g, xs, lam = (rnd(jax.random.fold_in(k, i), shape, dtype) for i in range(4))
    out_p = fused_update_pallas(x, g, xs, lam, 0.05, 3.0, interpret=True)
    out_r = ref.fused_update_ref(x, g, xs, lam, 0.05, 3.0)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out_p, np.float32), np.asarray(out_r, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 400),
    step=st.floats(1e-4, 1.0),
    rho=st.floats(0.0, 50.0),
)
def test_fused_update_property(n, step, rho):
    k = jax.random.key(n)
    x, g, xs, lam = (rnd(jax.random.fold_in(k, i), (n,)) for i in range(4))
    out = fused_update_pallas(x, g, xs, lam, step, rho, interpret=True)
    expect = x - step * (g + rho * (x - xs) + lam)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5, rtol=1e-5)


def test_fused_update_fixed_point():
    """x* with grad = -rho(x*-xs) - lam is a fixed point."""
    k = jax.random.key(1)
    x = rnd(k, (128,))
    xs = rnd(jax.random.fold_in(k, 1), (128,))
    lam = rnd(jax.random.fold_in(k, 2), (128,))
    rho = 2.0
    g = -(rho * (x - xs) + lam)
    out = fused_update_pallas(x, g, xs, lam, 0.1, rho, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,K,V,chunk", [
    (1, 32, 1, 8, 8, 8),
    (2, 64, 3, 16, 16, 16),
    (2, 128, 2, 32, 32, 32),
    (1, 96, 2, 32, 16, 32),  # K != V
])
def test_wkv6_sweep(B, S, H, K, V, chunk):
    key = jax.random.key(0)
    r, k_, w_ = (rnd(jax.random.fold_in(key, i), (B, S, H, K), scale=0.5) for i in range(3))
    v = rnd(jax.random.fold_in(key, 3), (B, S, H, V), scale=0.5)
    w = jnp.exp(-jnp.exp(w_))
    u = rnd(jax.random.fold_in(key, 4), (H, K), scale=0.1)
    s0 = rnd(jax.random.fold_in(key, 5), (B, H, K, V), scale=0.1)
    y_ref, s_ref = ref.wkv6_ref(r, k_, v, w, u, s0)
    y_x, s_x = ops.wkv6(r, k_, v, w, u, s0, chunk=chunk, impl="xla")
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_ref), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_x), np.asarray(s_ref), atol=2e-4, rtol=1e-3)
    y_p, s_p = wkv6_pallas(r, k_, v, w, u, s0, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_ref), atol=2e-4, rtol=1e-3)


def test_wkv6_extreme_decay_stable():
    """Near-zero decay (w -> 0) must not produce inf/nan in the chunked forms."""
    B, S, H, K, V = 1, 64, 1, 16, 16
    key = jax.random.key(2)
    r = rnd(key, (B, S, H, K))
    k_ = rnd(jax.random.fold_in(key, 1), (B, S, H, K))
    v = rnd(jax.random.fold_in(key, 2), (B, S, H, V))
    w = jnp.full((B, S, H, K), 1e-30)  # extreme decay
    u = rnd(jax.random.fold_in(key, 3), (H, K))
    s0 = jnp.zeros((B, H, K, V))
    y_ref, _ = ref.wkv6_ref(r, k_, v, w, u, s0)
    for impl_out in [ops.wkv6(r, k_, v, w, u, s0, chunk=16, impl="xla")[0],
                     wkv6_pallas(r, k_, v, w, u, s0, chunk=16, interpret=True)[0]]:
        assert np.isfinite(np.asarray(impl_out)).all()
        # outputs reach O(20) under extreme decay; f32 chunked vs sequential
        # accumulation differs at ~1e-4 relative
        np.testing.assert_allclose(
            np.asarray(impl_out), np.asarray(y_ref), rtol=2e-4, atol=1e-3
        )


def test_wkv6_step_matches_scan():
    """Decode step telescopes to the sequential reference."""
    B, S, H, K, V = 2, 16, 2, 8, 8
    key = jax.random.key(4)
    r, k_, w_ = (rnd(jax.random.fold_in(key, i), (B, S, H, K), scale=0.5) for i in range(3))
    v = rnd(jax.random.fold_in(key, 3), (B, S, H, V), scale=0.5)
    w = jnp.exp(-jnp.exp(w_))
    u = rnd(jax.random.fold_in(key, 5), (H, K), scale=0.1)
    s = jnp.zeros((B, H, K, V))
    ys = []
    for t in range(S):
        y, s = ops.wkv6_step(r[:, t], k_[:, t], v[:, t], w[:, t], u, s)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    y_ref, s_ref = ref.wkv6_ref(r, k_, v, w, u, jnp.zeros((B, H, K, V)))
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), chunk=st.sampled_from([4, 8, 16]))
def test_wkv6_chunk_invariance(seed, chunk):
    """Output must not depend on the chunk size."""
    B, S, H, K, V = 1, 32, 1, 8, 8
    key = jax.random.key(seed)
    r, k_, w_ = (rnd(jax.random.fold_in(key, i), (B, S, H, K), scale=0.5) for i in range(3))
    v = rnd(jax.random.fold_in(key, 3), (B, S, H, V), scale=0.5)
    w = jnp.exp(-jnp.exp(w_))
    u = rnd(jax.random.fold_in(key, 4), (H, K), scale=0.1)
    s0 = jnp.zeros((B, H, K, V))
    y_ref, _ = ref.wkv6_ref(r, k_, v, w, u, s0)
    y_c, _ = ops.wkv6(r, k_, v, w, u, s0, chunk=chunk, impl="xla")
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref), atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Hkv,hd,window", [
    (1, 128, 2, 2, 16, None),
    (2, 256, 4, 2, 32, None),
    (2, 256, 4, 1, 32, 64),   # MQA + sliding window
    (1, 128, 8, 2, 16, 50),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(B, S, H, Hkv, hd, window, dtype):
    key = jax.random.key(0)
    q = rnd(key, (B, S, H, hd), dtype)
    k = rnd(jax.random.fold_in(key, 1), (B, S, Hkv, hd), dtype)
    v = rnd(jax.random.fold_in(key, 2), (B, S, Hkv, hd), dtype)
    pos = jnp.arange(S)
    o_ref = ref.attention_ref(q, k, v, pos, pos, causal=True, window=window)
    o_x = ops.flash_attention(q, k, v, pos, pos, causal=True, window=window,
                              q_chunk=64, k_chunk=64)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o_x, np.float32), np.asarray(o_ref, np.float32),
                               atol=tol, rtol=tol)
    o_p = flash_attention_pallas(q, k, v, pos, pos, causal=True, window=window,
                                 q_block=64, k_block=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_p, np.float32), np.asarray(o_ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_causal_skip_identical():
    """The FLOP-saving causal_skip path must be numerically identical."""
    key = jax.random.key(5)
    q = rnd(key, (1, 256, 2, 16))
    k = rnd(jax.random.fold_in(key, 1), (1, 256, 2, 16))
    v = rnd(jax.random.fold_in(key, 2), (1, 256, 2, 16))
    pos = jnp.arange(256)
    a = ops.flash_attention(q, k, v, pos, pos, q_chunk=64, k_chunk=64, causal_skip=True)
    b = ops.flash_attention(q, k, v, pos, pos, q_chunk=64, k_chunk=64, causal_skip=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_attend_cache_matches_full():
    """Single-token decode attention == last row of full attention."""
    key = jax.random.key(6)
    B, S, H, Hkv, hd = 2, 64, 4, 2, 16
    q = rnd(key, (B, S, H, hd))
    k = rnd(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = rnd(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    pos = jnp.arange(S)
    full = ref.attention_ref(q, k, v, pos, pos, causal=True)
    dec = ops.attend_cache(q[:, -1:], k, v, S - 1, pos)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), window=st.sampled_from([None, 16, 40]))
def test_flash_property(seed, window):
    key = jax.random.key(seed)
    B, S, H, hd = 1, 64, 2, 8
    q = rnd(key, (B, S, H, hd))
    k = rnd(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = rnd(jax.random.fold_in(key, 2), (B, S, H, hd))
    pos = jnp.arange(S)
    o_ref = ref.attention_ref(q, k, v, pos, pos, causal=True, window=window)
    o_x = ops.flash_attention(q, k, v, pos, pos, window=window, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_ref), atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# lru scan
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), chunk=st.sampled_from([4, 16, 64]))
def test_lru_chunk_invariance(seed, chunk):
    key = jax.random.key(seed)
    B, S, D = 2, 64, 8
    a = jax.nn.sigmoid(rnd(key, (B, S, D)))
    b = rnd(jax.random.fold_in(key, 1), (B, S, D))
    h0 = rnd(jax.random.fold_in(key, 2), (B, D))
    y_ref, h_ref = ref.lru_ref(a, b, h0)
    y, h = ops.lru_scan(a, b, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-5, rtol=1e-5)
