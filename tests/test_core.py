"""Core federated-optimiser tests: every analytical claim of the paper that
can be checked numerically on small problems."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederatedConfig
from repro.core import fedsplit, make, pdmm, quadratic
from repro.core import tree_util as T


@pytest.fixture(scope="module")
def prob():
    return quadratic.generate(jax.random.key(0), m=8, n=120, d=24)


@pytest.fixture(scope="module")
def x0(prob):
    return jnp.zeros((prob.d,))


def jit_round(opt, oracle, batch, **kw):
    @jax.jit
    def f(s):
        return opt.round(s, oracle, batch, **kw)
    return f


# ---------------------------------------------------------------------------
# SSIII-B: PDMM == FedSplit on the star graph (exact, prox oracle)
# ---------------------------------------------------------------------------

def test_pdmm_equals_fedsplit_exact(prob, x0):
    cfg = FederatedConfig(rho=200.0)
    prox = prob.make_client_prox()
    p = pdmm.make_exact(cfg)
    f = fedsplit.make_exact(cfg)
    sp, sf = p.init(x0, prob.m), f.init(x0, prob.m)
    for r in range(15):
        sp, _ = p.round(sp, prox)
        sf, _ = f.round(sf, prox)
        np.testing.assert_allclose(
            np.asarray(sp["x_s"]), np.asarray(sf["x_s"]), rtol=0, atol=1e-5,
            err_msg=f"trajectories diverge at round {r}",
        )
    # and both converge to the optimum
    assert float(prob.gap(sp["x_s"])) < 1e-2


# ---------------------------------------------------------------------------
# eqs. (27)/(31): K=1 AGPDMM == SCAFFOLD == FedAvg == vanilla GD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["agpdmm", "scaffold", "fedavg"])
def test_k1_reduces_to_gd(prob, x0, algo):
    eta = 0.5 / prob.L
    opt = make(FederatedConfig(algorithm=algo, inner_steps=1, eta=eta))
    s = opt.init(x0, prob.m)
    batch = prob.batch()
    for _ in range(8):
        s, _ = opt.round(s, prob.grad, batch)
    xg = x0
    for _ in range(8):
        g = (jnp.einsum("mde,e->d", prob.AtA, xg) - prob.Atb.sum(0)) / prob.m
        xg = xg - eta * g
    np.testing.assert_allclose(np.asarray(opt.server_params(s)), np.asarray(xg), atol=5e-4)


# ---------------------------------------------------------------------------
# Fig. 1: Inexact FedSplit with the improper z-init stalls; x_s-init converges
# ---------------------------------------------------------------------------

def test_inexact_fedsplit_bad_init_stalls(prob, x0):
    eta = 1.0 / prob.L
    gaps = {}
    for init in ["z", "xs"]:
        opt = make(FederatedConfig(algorithm="fedsplit", inner_steps=3, eta=eta,
                                   fedsplit_init=init, rho=prob.L / 10))
        s = opt.init(x0, prob.m)
        rf = jit_round(opt, prob.grad, prob.batch())
        for _ in range(200):
            s, _ = rf(s)
        gaps[init] = float(prob.gap(s["x_s"]))
    # xs-init reaches the f32 gap floor; z-init stalls an order of magnitude
    # (or more) above it -- the paper's Fig. 1 contrast
    assert gaps["xs"] < 1e-2, gaps
    assert gaps["z"] > 10 * max(gaps["xs"], 1e-6), f"bad init should stall: {gaps}"


# ---------------------------------------------------------------------------
# GPDMM / AGPDMM converge where FedAvg drifts (K > 1, heterogeneous clients)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm", "scaffold"])
def test_k5_converges(prob, x0, algo):
    opt = make(FederatedConfig(algorithm=algo, inner_steps=5, eta=0.5 / prob.L))
    s = opt.init(x0, prob.m)
    rf = jit_round(opt, prob.grad, prob.batch())
    for _ in range(150):
        s, metrics = rf(s)
    assert float(prob.gap(opt.server_params(s))) < 1e-2


def test_k5_fedavg_drifts(prob, x0):
    opt = make(FederatedConfig(algorithm="fedavg", inner_steps=5, eta=0.5 / prob.L))
    s = opt.init(x0, prob.m)
    rf = jit_round(opt, prob.grad, prob.batch())
    for _ in range(150):
        s, _ = rf(s)
    # FedAvg stalls at a heterogeneity-dependent plateau
    assert float(prob.gap(opt.server_params(s))) > 1e-2


# ---------------------------------------------------------------------------
# eq. (25): sum_i lam_{s|i} == 0 invariant, every round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm"])
def test_dual_sum_invariant(prob, x0, algo):
    opt = make(FederatedConfig(algorithm=algo, inner_steps=3, eta=0.5 / prob.L))
    s = opt.init(x0, prob.m)
    for _ in range(20):
        s, metrics = opt.round(s, prob.grad, prob.batch())
        assert float(metrics["lam_sum_norm"]) < 1e-3


# ---------------------------------------------------------------------------
# Remark 1: last-iterate dual update (eq. 24) converges at least as fast
# ---------------------------------------------------------------------------

def test_gpdmm_last_iterate_variant(prob, x0):
    """Both the eq.-(23) average and eq.-(24) last-iterate dual updates
    converge; at a mid-trajectory checkpoint the last-iterate variant is at
    least comparable (Remark 1).  Distances, not f32 functional gaps."""
    dist = {}
    for use_avg in [True, False]:
        opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=5,
                                   eta=0.5 / prob.L, use_avg=use_avg))
        s = opt.init(x0, prob.m)
        rf = jit_round(opt, prob.grad, prob.batch())
        for _ in range(15):
            s, _ = rf(s)
        dist[use_avg] = float(jnp.linalg.norm(opt.server_params(s) - prob.x_star))
    assert dist[False] <= dist[True] * 1.5, dist
    assert dist[False] < 1.0 and dist[True] < 1.0, dist


# ---------------------------------------------------------------------------
# AGPDMM beats GPDMM for K > 1 (the paper's headline experiment ordering)
# ---------------------------------------------------------------------------

def test_agpdmm_faster_than_gpdmm(prob, x0):
    gaps = {}
    for algo in ["gpdmm", "agpdmm"]:
        opt = make(FederatedConfig(algorithm=algo, inner_steps=5, eta=0.5 / prob.L))
        s = opt.init(x0, prob.m)
        rf = jit_round(opt, prob.grad, prob.batch())
        for _ in range(40):
            s, _ = rf(s)
        gaps[algo] = float(prob.gap(opt.server_params(s)))
    assert gaps["agpdmm"] <= gaps["gpdmm"], gaps


# ---------------------------------------------------------------------------
# per-step minibatches path (paper's softmax-regression regime)
# ---------------------------------------------------------------------------

def test_per_step_batches(prob, x0):
    K = 3
    opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.5 / prob.L))
    s = opt.init(x0, prob.m)
    batch = prob.batch()
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), batch)
    rf = jit_round(opt, prob.grad, stacked, per_step_batches=True)
    for _ in range(30):
        s, _ = rf(s)
    # identical batches per step == shared-batch behaviour
    opt2 = make(FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.5 / prob.L))
    s2 = opt2.init(x0, prob.m)
    rf2 = jit_round(opt2, prob.grad, batch)
    for _ in range(30):
        s2, _ = rf2(s2)
    np.testing.assert_allclose(np.asarray(s["x_s"]), np.asarray(s2["x_s"]), atol=1e-5)


# ---------------------------------------------------------------------------
# beyond-paper: EF21 delta-quantised uplink (SSPerf H3) still converges
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm"])
@pytest.mark.parametrize("bits", [8, 4])
def test_quantized_uplink_converges(prob, x0, algo, bits):
    """EF21-style delta-compressed uplink: each client transmits
    q(u_i - u_hat_i) and both sides integrate, so the quantisation scale
    shrinks with the residual and convergence matches the exact method --
    extending the paper's one-variable-per-direction claim from 16 to as few
    as 4 bits/param on the wire."""
    opt = make(FederatedConfig(algorithm=algo, inner_steps=5, eta=0.5 / prob.L,
                               uplink_bits=bits))
    s = opt.init(x0, prob.m)
    assert "u_hat" in s
    rf = jit_round(opt, prob.grad, prob.batch())
    for _ in range(150):
        s, metrics = rf(s)
    assert float(metrics["lam_sum_norm"]) < 1e-3  # eq. (25) survives quantisation
    assert float(prob.gap(opt.server_params(s))) < 1e-2


def test_quantized_uplink_delta_encoding_matters(prob, x0):
    """Directly quantising the uplink (no delta integrator) stalls at the
    quantisation floor: PDMM's duals integrate the per-round rounding error.
    Emulated by resetting u_hat to the round-0 view each round."""
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=5, eta=0.5 / prob.L,
                          uplink_bits=8)
    opt = make(cfg)

    s_d = opt.init(x0, prob.m)
    rf = jit_round(opt, prob.grad, prob.batch())
    for _ in range(200):
        s_d, _ = rf(s_d)

    s_no = opt.init(x0, prob.m)
    u0 = s_no["u_hat"]

    @jax.jit
    def rf_no(s):
        s, m = opt.round(s, prob.grad, prob.batch())
        s["u_hat"] = u0  # kill the integrator -> direct quantisation of u - u0
        return s, m

    for _ in range(200):
        s_no, _ = rf_no(s_no)

    gap_d = float(prob.gap(opt.server_params(s_d)))
    gap_no = float(prob.gap(opt.server_params(s_no)))
    # delta-encoded converges below tolerance; direct quantisation stalls
    # above it (f32 functional gaps quantise to ~2e-3 steps, so compare
    # against the tolerance rather than a ratio)
    assert gap_d < 1e-2 <= gap_no, (gap_d, gap_no)


# ---------------------------------------------------------------------------
# property: the optimisers are structure-preserving pytree transformations
# ---------------------------------------------------------------------------

from _hyp import given, settings, st  # noqa: E402  (skips cleanly w/o hypothesis)


@st.composite
def _pytrees(draw):
    """Random nested-dict pytrees of small float arrays."""
    n_leaves = draw(st.integers(1, 4))
    tree = {}
    for i in range(n_leaves):
        shape = tuple(draw(st.lists(st.integers(1, 5), min_size=1, max_size=3)))
        tree[f"w{i}"] = jnp.full(shape, float(i + 1))
    if draw(st.booleans()):
        tree = {"nested": tree, "bias": jnp.zeros((3,))}
    return tree


@settings(max_examples=10, deadline=None)
@given(params=_pytrees(), algo=st.sampled_from(["gpdmm", "agpdmm", "scaffold", "fedavg"]),
       m=st.integers(2, 4), k=st.integers(1, 3))
def test_round_preserves_structure_and_invariants(params, algo, m, k):
    """For ANY parameter pytree: one round preserves the state structure,
    keeps every leaf finite, and (for the PDMM family) keeps sum_i lam = 0."""
    opt = make(FederatedConfig(algorithm=algo, inner_steps=k, eta=0.1))

    def grad_fn(p, _b):
        return jax.tree.map(lambda x: 0.3 * x, p)  # grad of 0.15||x||^2

    batch = {"dummy": jnp.zeros((m, 1))}
    s = opt.init(params, m)
    s2, metrics = opt.round(s, grad_fn, batch)
    assert jax.tree.structure(s2) == jax.tree.structure(s)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s2)):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert bool(jnp.isfinite(jnp.asarray(b, jnp.float32)).all())
    if algo in ("gpdmm", "agpdmm"):
        assert float(metrics["lam_sum_norm"]) < 1e-4
    # server params move toward 0 for this strongly-convex objective
    before = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(params))
    after = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(opt.server_params(s2)))
    assert after <= before + 1e-6


# ---------------------------------------------------------------------------
# beyond-paper: partial client participation (async PDMM)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm"])
def test_partial_participation_converges(prob, x0, algo):
    """With only half the clients active per round (async PDMM: the server
    reuses its cached uplink view of silent clients), the method still
    converges, and the KKT invariant (25) survives partial rounds exactly
    because lam_{s|i} is recomputed server-side for ALL i."""
    opt = make(FederatedConfig(algorithm=algo, inner_steps=5, eta=0.5 / prob.L,
                               participation=0.5))
    s = opt.init(x0, prob.m)
    assert "u_hat" in s
    rf = jit_round(opt, prob.grad, prob.batch())
    for _ in range(400):  # ~2x the full-participation rounds
        s, metrics = rf(s)
    assert float(metrics["lam_sum_norm"]) < 1e-3
    assert float(prob.dist(opt.server_params(s))) < 1e-2


def test_partial_participation_composes_with_quantization(prob, x0):
    """participation=0.5 + 8-bit EF21 uplink together still converge."""
    opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=5,
                               eta=0.5 / prob.L,
                               participation=0.5, uplink_bits=8))
    s = opt.init(x0, prob.m)
    rf = jit_round(opt, prob.grad, prob.batch())
    for _ in range(400):
        s, metrics = rf(s)
    assert float(metrics["lam_sum_norm"]) < 1e-3
    assert float(prob.dist(opt.server_params(s))) < 5e-2


# ---------------------------------------------------------------------------
# beyond-paper: SVRG variance reduction (the paper's SSVII future work)
# ---------------------------------------------------------------------------

def test_svrg_beats_plain_stochastic_gpdmm():
    """With noisy per-step minibatch gradients, plain GPDMM stalls at a noise
    ball; the SVRG-corrected variant keeps contracting toward x*."""
    key = jax.random.key(5)
    m, n, d, K = 8, 128, 24, 4
    A = jax.random.normal(key, (m, n, d))
    y0 = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    b = jnp.einsum("mnd,d->mn", A, y0) + 0.5 * jax.random.normal(
        jax.random.fold_in(key, 2), (m, n))
    AtA = jnp.einsum("mnd,mne->mde", A, A)
    Atb = jnp.einsum("mnd,mn->md", A, b)
    x_star = jnp.linalg.solve(AtA.sum(0), Atb.sum(0))
    L = float(jnp.linalg.eigvalsh(AtA).max())

    # K row-chunks per client; x K so each chunk gradient is an unbiased
    # estimate of the client's full gradient
    Ac = A.reshape(m, K, n // K, d).swapaxes(0, 1)  # (K, m, n/K, d)
    bc = b.reshape(m, K, n // K).swapaxes(0, 1)
    batch = {
        "AtA": jnp.einsum("kmnd,kmne->kmde", Ac, Ac) * K,
        "Atb": jnp.einsum("kmnd,kmn->kmd", Ac, bc) * K,
    }

    def grad_fn(x, bt):
        return bt["AtA"] @ x - bt["Atb"]

    def run(vr):
        opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=K,
                                   eta=0.5 / L, variance_reduction=vr))
        s = opt.init(jnp.zeros((d,)), m)

        @jax.jit
        def rf(s):
            s, _ = opt.round(s, grad_fn, batch, per_step_batches=True)
            return s

        for _ in range(200):
            s = rf(s)
        return float(jnp.linalg.norm(opt.server_params(s) - x_star))

    d_plain = run(None)
    d_svrg = run("svrg")
    # chunk gradients differ from the full gradient (row noise), so plain
    # per-step GPDMM stalls above the svrg variant by a clear margin
    assert d_svrg < d_plain / 3, (d_svrg, d_plain)
    assert d_svrg < 1e-3, d_svrg
