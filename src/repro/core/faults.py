"""Deterministic fault injection + screening-to-silence (the robustness
layer shared by every round engine, ISSUE 6).

The design maps client faults onto the silence contract the round engines
already implement for partial participation (PR 5 pinned the
``(sum_active uplink + sum_silent u_hat) / m`` identity bit-identically):

* A **silent** client (dropout / straggler -- it never returns this round)
  simply contributes its cached ``u_hat`` row, exactly as a
  participation-masked client.  Stochastic/asynchronous PDMM with randomly
  inactive nodes converges (Sherson et al., arXiv:1706.02654; Zhang &
  Heusdens, arXiv:1702.00841), so silence is the one graceful degradation
  with theory attached.
* A **delayed** client (the soft class, ISSUE 7) finished its inner steps
  but its uplink is in flight for a drawn lateness of ``s`` rounds.  With
  the bounded-staleness engine on (``async_on``) the round routes the row
  through the stale buffer (``core.staleness``): it is stored this round,
  arrives ``s`` rounds later, and is admitted into the server mean with
  weight ``stale_gamma**s`` iff ``s <= max_staleness`` -- the stale-update
  regime of the same asynchronous-PDMM theory.  A lateness beyond
  ``deadline`` is demoted to plain silence AT PLAN TIME.  With the engine
  off (the default, and always on non-star topologies) ``delay`` IS a
  silence class, bit-identical to the pre-async behaviour.
* A **corrupt** client transmits, but the wire mangles the packet (NaN row,
  Inf row, sign flip, or a ``blowup``-scaled magnitude).  Uplink screening
  (``ops.screen_uplink``) detects the row in one fused pass -- per-client
  finite flags plus the squared deviation from the downlink reference
  (deviation, not plain norm: a sign-flipped uplink is norm-INVARIANT, but
  its deviation from x_s is ~ ||2 x_s||) -- and the server DEMOTES it to
  silent for the round.  Demotion means silent, full stop: the carry keeps
  its previous row, the cache keeps its previous uplink, the mean uses
  ``u_hat``.  A screened round is therefore bit-identical to a
  participation-masked round with the same effective mask
  (tests/test_faults.py pins this across all four algorithms).

Every draw is a pure function of ``(FaultConfig.seed, round, client)`` --
the round counter is folded into the seed -- so a fault trace replays
exactly across reruns, ``--resume``, and watchdog rollbacks
(``launch/train.py``).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FaultConfig, FederatedConfig
from repro.kernels import ops

# corruption classes, indexed by FaultPlan.kind
KINDS = ("nan", "inf", "sign", "blowup")
# fault-RNG fold ids are POSITIONAL in this tuple (0=dropout, 1=straggler,
# 2=delay); corrupt folds at 3, kind at 4, lateness at 5.  The delay draw
# keeps fold id 2 whether it lands in `silent` (engine off) or `delayed`
# (engine on), so silent|delayed is the same client set either way and the
# synchronous collapse is bitwise.
_SILENCE_CLASSES = ("dropout", "straggler", "delay")


class FaultPlan(NamedTuple):
    """The round's fault draw over the client population.

    silent:   (m,) bool -- client never returns this round (hard silence)
    corrupt:  (m,) bool -- client transmits a wire-mangled uplink (disjoint
              from silent and delayed: a client that does not return this
              round transmits nothing to corrupt)
    kind:     (m,) int32 -- corruption class index into ``KINDS``
    delayed:  (m,) bool -- uplink in flight through the stale buffer
              (all-False unless ``async_on``; disjoint from silent)
    lateness: (m,) int32 -- drawn rounds-late of each delayed client
              (0 on non-delayed rows)
    """

    silent: jax.Array
    corrupt: jax.Array
    kind: jax.Array
    delayed: jax.Array
    lateness: jax.Array


def async_on(cfg: FederatedConfig) -> bool:
    """Static policy: does this config run the bounded-staleness engine?

    Requires a ``delay`` schedule on the centralised star topology (graph
    rounds keep the silence contract -- there is no per-edge stale buffer).
    ``async_rounds="auto"`` engages exactly when the knobs deviate from the
    synchronous point (``max_staleness > 0`` or a finite ``deadline``);
    True forces the engine, False keeps delay = silence."""
    fc = cfg.faults
    if fc is None or fc.delay <= 0.0 or cfg.topology != "star":
        return False
    if cfg.async_rounds == "auto":
        return cfg.max_staleness > 0 or math.isfinite(cfg.deadline)
    return bool(cfg.async_rounds)


def fault_key(fc: FaultConfig, round_idx) -> jax.Array:
    """Fault RNG for one round: the round counter folded into the fault seed
    (independent of the data and participation seeds)."""
    return jax.random.fold_in(jax.random.key(fc.seed), round_idx)


def plan(cfg: FederatedConfig, round_idx, m: int) -> Optional[FaultPlan]:
    """Draw the round's fault plan; None when no fault schedule is active.

    Pure in ``(cfg.faults.seed, round_idx, client)``: calling it twice -- or
    from a replayed round after a rollback -- yields the identical plan.
    """
    fc = cfg.faults
    if fc is None or not fc.any:
        return None
    key = fault_key(fc, round_idx)

    def draw(cls_id: int, rate: float) -> jax.Array:
        if rate <= 0.0:
            return jnp.zeros((m,), bool)
        if rate >= 1.0:
            return jnp.ones((m,), bool)
        return jax.random.bernoulli(
            jax.random.fold_in(key, cls_id), rate, (m,))

    a_on = async_on(cfg)
    silent = jnp.zeros((m,), bool)
    delayed = jnp.zeros((m,), bool)
    for cls_id, name in enumerate(_SILENCE_CLASSES):
        hit = draw(cls_id, getattr(fc, name))
        if name == "delay" and a_on:
            # same fold id whether delay means silence or staleness, so
            # silent|delayed is the identical client set either way
            delayed = hit & ~silent
        else:
            silent = silent | hit
    lateness = jnp.zeros((m,), jnp.int32)
    if a_on:
        lateness = jax.random.randint(
            jax.random.fold_in(key, 5), (m,), 1, fc.delay_max + 1, jnp.int32)
        lateness = jnp.where(delayed, lateness, 0)
        if math.isfinite(cfg.deadline):
            # past the deadline -> demoted to the silence contract at plan
            # time: the uplink never enters the stale buffer
            late = delayed & (lateness.astype(jnp.float32)
                              > jnp.float32(cfg.deadline))
            silent = silent | late
            delayed = delayed & ~late
            lateness = jnp.where(delayed, lateness, 0)
    corrupt = draw(3, fc.corrupt) & ~silent & ~delayed
    kind = jax.random.randint(
        jax.random.fold_in(key, 4), (m,), 0, len(KINDS), jnp.int32)
    return FaultPlan(silent=silent, corrupt=corrupt, kind=kind,
                     delayed=delayed, lateness=lateness)


def take(plan_: Optional[FaultPlan], idx) -> Optional[FaultPlan]:
    """Restrict a population plan to a row subset (cohort indices / the
    static data-node list of a graph phase)."""
    if plan_ is None:
        return None
    idx = jnp.asarray(idx)
    return FaultPlan(silent=plan_.silent[idx], corrupt=plan_.corrupt[idx],
                     kind=plan_.kind[idx], delayed=plan_.delayed[idx],
                     lateness=plan_.lateness[idx])


def inject(fc: Optional[FaultConfig], plan_: Optional[FaultPlan], uplink):
    """Apply wire corruption to the transmitted (rows, width) uplink buffer.

    Corrupt rows become, by drawn class: all-NaN, all-Inf, sign-flipped, or
    ``blowup`` x the honest row.  No-op without a plan or a corrupt rate.
    """
    if plan_ is None or fc is None or fc.corrupt <= 0.0:
        return uplink
    u = uplink.astype(jnp.float32)
    k = plan_.kind[:, None]
    bad = jnp.where(
        k == 0, jnp.nan,
        jnp.where(k == 1, jnp.inf,
                  jnp.where(k == 2, -u, jnp.float32(fc.blowup) * u)))
    return jnp.where(plan_.corrupt[:, None], bad, u).astype(uplink.dtype)


def inject_tree(fc: Optional[FaultConfig], plan_: Optional[FaultPlan], uplink):
    """``inject`` over a stacked client pytree (leading dim m on every leaf)."""
    if plan_ is None or fc is None or fc.corrupt <= 0.0:
        return uplink

    def one(u):
        shape = (-1,) + (1,) * (u.ndim - 1)
        uf = u.astype(jnp.float32)
        k = plan_.kind.reshape(shape)
        bad = jnp.where(
            k == 0, jnp.nan,
            jnp.where(k == 1, jnp.inf,
                      jnp.where(k == 2, -uf, jnp.float32(fc.blowup) * uf)))
        return jnp.where(plan_.corrupt.reshape(shape), bad, uf).astype(u.dtype)

    return jax.tree.map(one, uplink)


def screening_on(cfg: FederatedConfig) -> bool:
    """"auto" screens exactly when a fault schedule is configured; True/False
    force it on/off."""
    if cfg.screen == "auto":
        return cfg.faults is not None and cfg.faults.any
    return bool(cfg.screen)


def needs_cache(cfg: FederatedConfig) -> bool:
    """Whether the server must hold the u_hat uplink cache for fault
    tolerance: any fault schedule (silent clients fall back to the cache) or
    any screening (demoted clients do)."""
    return screening_on(cfg) or (cfg.faults is not None and cfg.faults.any)


def _keep_from(cfg: FederatedConfig, finite, sq):
    keep = finite
    if cfg.screen_mult > 0.0:
        # median over the rows that screened finite; all-NaN median (no
        # finite row at all) propagates NaN -> comparison False -> every row
        # already demoted by the finite flag, consistently
        med = jnp.nanmedian(jnp.where(finite, sq, jnp.nan))
        keep = keep & (sq <= jnp.float32(cfg.screen_mult)
                       * jnp.maximum(med, jnp.float32(1e-12)))
    return keep


def screen_keep(cfg: FederatedConfig, uplink, ref):
    """Screen a (rows, width) uplink buffer against the downlink reference
    ``ref`` ((width,) broadcast row, or (rows, width) per-row).  Returns the
    (rows,) bool KEEP mask: finite and not a norm outlier
    (> screen_mult x the round median squared deviation)."""
    finite, sq = ops.screen_uplink(uplink, ref)
    return _keep_from(cfg, finite, sq)


def screen_keep_tree(cfg: FederatedConfig, uplink, ref_tree):
    """``screen_keep`` over a stacked client pytree vs the server pytree:
    the flags/deviations reduce over ALL leaves, so the rule matches the
    packed-arena screen on the same state."""
    leaves_u = jax.tree.leaves(uplink)
    leaves_r = jax.tree.leaves(ref_tree)
    m = leaves_u[0].shape[0]
    finite = jnp.ones((m,), bool)
    sq = jnp.zeros((m,), jnp.float32)
    for u, r in zip(leaves_u, leaves_r):
        uf = u.astype(jnp.float32).reshape(m, -1)
        rf = r.astype(jnp.float32).reshape(1, -1)
        fin_e = jnp.isfinite(uf)
        d = jnp.where(fin_e, uf - rf, 0.0)
        finite = finite & jnp.all(fin_e, axis=1)
        sq = sq + jnp.sum(d * d, axis=1)
    return _keep_from(cfg, finite, sq)


def combine_mask(mask, plan_: Optional[FaultPlan], keep):
    """AND the participation mask, the plan's silence, and the screening keep
    mask into the round's effective active mask (None = everyone active)."""
    out = mask
    if plan_ is not None:
        alive = ~plan_.silent
        out = alive if out is None else out & alive
    if keep is not None:
        out = keep if out is None else out & keep
    return out


def fault_metrics(plan_: Optional[FaultPlan], transmitters, keep) -> dict:
    """Round fault counters (f32 scalars, scan-stackable):

    ``faults_injected`` -- clients hit by the schedule this round (silent,
    corrupt, or delayed, over the population the plan was drawn for);
    ``faults_demoted`` -- transmitting clients the screen silenced.
    """
    f32 = jnp.float32
    injected = (jnp.zeros((), f32) if plan_ is None
                else jnp.sum((plan_.silent | plan_.corrupt
                              | plan_.delayed).astype(f32)))
    if keep is None:
        demoted = jnp.zeros((), f32)
    else:
        t = jnp.ones_like(keep) if transmitters is None else transmitters
        demoted = jnp.sum((t & ~keep).astype(f32))
    return {"faults_injected": injected, "faults_demoted": demoted}
