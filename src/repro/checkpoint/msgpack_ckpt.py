"""Checkpointing: pytrees -> msgpack files with dtype/shape-preserving codecs.

Layout: <dir>/step_<N>.msgpack, atomic writes via tmp+fsync+rename (the file
is durable BEFORE it becomes visible, so a crash mid-save never leaves a
half-written step under the canonical name), ``latest_step`` for resumption,
optional keep-last-N retention so watchdog rollback anchors don't accumulate
unboundedly.  ``load`` rejects truncated or corrupt files loudly, naming the
file, instead of returning a garbage tree.  Handles nested dict/list/tuple
pytrees of jax/numpy arrays and python scalars; bfloat16 round-trips via
ml_dtypes.

Large arrays (anything over ``CHUNK_BYTES``, notably the host-resident
population store's (m, width) buffers at m=10^6) are STREAMED: the tree is
written as a small skeleton object with per-array placeholders, followed by
the arrays' bytes in bounded chunks appended to the same msgpack stream.
Peak transient memory during save/load is therefore O(CHUNK_BYTES), not
O(state) -- the old single-``packb`` path briefly held a full second copy
of the state while building the output buffer, which at a 10^6-row store
doubles the job's host memory exactly when it is largest.  Streamed arrays
load back as HOST numpy arrays (they are written only for host-resident
state; pushing 10^6 rows to device on load would defeat the store).
"""
from __future__ import annotations

import math
import os
import pathlib
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)
import msgpack
import numpy as np

_ARR = "__arr__"
_TUP = "__tup__"
_CHUNKED = "__chunked__"

# Arrays above this size stream in chunks of this many bytes.  16 MiB keeps
# the per-chunk copy negligible while the msgpack framing overhead (a few
# bytes per chunk) stays irrelevant.
CHUNK_BYTES = 16 << 20

# Pending-data bound for the streaming reader: must admit the largest single
# msgpack object -- legacy (pre-streaming) files inline whole arrays as one
# bin, so keep this effectively unlimited.
_MAX_BUFFER = 2**31 - 1


def _encode(obj):
    if isinstance(obj, (jax.Array, np.ndarray)):
        arr = np.asarray(obj)
        return {
            _ARR: True,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return obj


def _pack(tree):
    def rec(t):
        if isinstance(t, dict):
            return {k: rec(v) for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            return {_TUP: isinstance(t, tuple), "items": [rec(v) for v in t]}
        return _encode(t)

    return rec(tree)


def _unpack(obj):
    if isinstance(obj, dict):
        if obj.get(_ARR):
            arr = np.frombuffer(obj["data"], dtype=np.dtype(obj["dtype"])).reshape(obj["shape"])
            if arr.dtype == np.float64:
                # f64 is host-only state (the popstore's running sums):
                # jnp.asarray would SILENTLY downcast to f32 with x64 off
                return arr.copy()  # writable, frombuffer views are read-only
            return jnp.asarray(arr)
        if _TUP in obj:
            items = [_unpack(v) for v in obj["items"]]
            return tuple(items) if obj[_TUP] else items
        return {k: _unpack(v) for k, v in obj.items()}
    return obj


def _split_large(tree):
    """Replace every array larger than ``CHUNK_BYTES`` with a placeholder
    dict; returns ``(skeleton, ordered list of the extracted host arrays)``.
    The skeleton packs small (placeholders carry dtype/shape/id only), so
    ``packb`` of it never holds a second copy of the big buffers."""
    big: list[np.ndarray] = []

    def rec(t):
        if isinstance(t, dict):
            return {k: rec(v) for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            vals = [rec(v) for v in t]
            return tuple(vals) if isinstance(t, tuple) else vals
        if isinstance(t, (jax.Array, np.ndarray)):
            arr = np.asarray(t)
            if arr.nbytes > CHUNK_BYTES:
                big.append(arr)
                return {_CHUNKED: True, "dtype": str(arr.dtype),
                        "shape": list(arr.shape), "id": len(big) - 1}
        return t

    return rec(tree), big


def _graft(obj, slots):
    """Swap restored chunked arrays back into their placeholder positions."""
    if isinstance(obj, dict):
        if obj.get(_CHUNKED):
            return slots[obj["id"]]
        return {k: _graft(v, slots) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        vals = [_graft(v, slots) for v in obj]
        return tuple(vals) if isinstance(obj, tuple) else vals
    return obj


def save(path: str | os.PathLike, step: int, tree: Any, *,
         keep: Optional[int] = None) -> str:
    """Write ``step`` atomically; with ``keep``, prune all but the newest
    ``keep`` checkpoints afterwards (zero-padded names sort numerically).
    Arrays over ``CHUNK_BYTES`` stream to the file in bounded chunks."""
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    final = path / f"step_{step:08d}.msgpack"
    tmp = final.with_suffix(".tmp")
    tree = jax.tree.map(lambda x: jax.device_get(x) if isinstance(x, jax.Array) else x, tree)
    skeleton, big = _split_large(tree)
    packer = msgpack.Packer(use_bin_type=True)
    with open(tmp, "wb") as f:
        f.write(packer.pack(_pack(skeleton)))
        for k, arr in enumerate(big):
            arr = np.ascontiguousarray(arr)
            flat = arr.reshape(-1).view(np.uint8)
            n_chunks = max(1, math.ceil(arr.nbytes / CHUNK_BYTES))
            f.write(packer.pack({"id": k, "n_chunks": n_chunks}))
            for c in range(n_chunks):
                f.write(packer.pack(
                    flat[c * CHUNK_BYTES:(c + 1) * CHUNK_BYTES].tobytes()))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    if keep is not None and keep > 0:
        # prune by PARSED step number, not raw glob order: a stray
        # non-numeric step_*.msgpack must neither survive at a real
        # anchor's expense nor crash the prune
        for n in steps(path)[:-keep]:
            (path / f"step_{n:08d}.msgpack").unlink(missing_ok=True)
    return str(final)


def _parse_step(p: pathlib.Path) -> Optional[int]:
    stem = p.stem
    suffix = stem.split("_", 1)[1] if "_" in stem else ""
    if suffix.isdigit():
        return int(suffix)
    return None


def steps(path: str | os.PathLike) -> list[int]:
    """All on-disk checkpoint steps, ascending.  Consumers that must survive
    a bad newest file (the hot-swap serving watcher) walk this list from the
    tail instead of trusting ``latest_step`` alone.  Files matching the glob
    but with a non-numeric suffix (step_tmp.msgpack from some other writer,
    editor droppings) are SKIPPED with a warning instead of raising -- one
    stray file must not take down --resume, the watchdog rollback walk, or
    the serve watcher."""
    path = pathlib.Path(path)
    if not path.exists():
        return []
    out = []
    for p in path.glob("step_*.msgpack"):
        n = _parse_step(p)
        if n is None:
            warnings.warn(
                f"[ckpt] ignoring non-checkpoint file {p} (suffix is not a "
                f"step number)", RuntimeWarning, stacklevel=2)
            continue
        out.append(n)
    return sorted(out)


def latest_step(path: str | os.PathLike) -> Optional[int]:
    all_steps = steps(path)
    return all_steps[-1] if all_steps else None


def load(path: str | os.PathLike, step: Optional[int] = None) -> Any:
    path = pathlib.Path(path)
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    fp = path / f"step_{step:08d}.msgpack"
    if not fp.exists():
        raise FileNotFoundError(f"no checkpoint file {fp}")
    try:
        with open(fp, "rb") as f:
            unp = msgpack.Unpacker(f, raw=False, strict_map_key=False,
                                   max_buffer_size=_MAX_BUFFER)
            payload = _unpack(unp.unpack())
            slots_meta = {}
            _index_chunked(payload, slots_meta)
            if not slots_meta:
                _expect_eof(unp)
                return payload
            # streamed tail: per-array header + bounded chunks, in the order
            # the writer extracted them; reassembled into preallocated HOST
            # buffers so peak transient memory stays O(CHUNK_BYTES)
            slots = {}
            for _ in range(len(slots_meta)):
                hdr = unp.unpack()
                ph = slots_meta[int(hdr["id"])]
                arr = np.empty([int(s) for s in ph["shape"]],
                               dtype=np.dtype(ph["dtype"]))
                flat = arr.reshape(-1).view(np.uint8)
                off = 0
                for _c in range(int(hdr["n_chunks"])):
                    chunk = unp.unpack()
                    flat[off:off + len(chunk)] = np.frombuffer(chunk, np.uint8)
                    off += len(chunk)
                if off != arr.nbytes:
                    raise _Corrupt(
                        f"chunked array id={hdr['id']} has {off} bytes, "
                        f"expected {arr.nbytes}")
                slots[int(hdr["id"])] = arr
            _expect_eof(unp)
            return _graft(payload, slots)
    except (Exception,) as e:
        raise ValueError(
            f"checkpoint {fp} is truncated or corrupt "
            f"({fp.stat().st_size} bytes): {e}; delete it and resume from "
            f"an earlier step"
        ) from e


class _Corrupt(Exception):
    pass


def _expect_eof(unp):
    """The file must contain exactly the checkpoint stream: trailing bytes
    mean a corrupt or foreign file (the pre-streaming reader rejected them
    via ``unpackb``'s ExtraData; the streaming reader must too)."""
    try:
        unp.unpack()
    except msgpack.OutOfData:
        return
    raise _Corrupt("trailing data after checkpoint payload")


def _index_chunked(obj, out: dict):
    """Collect chunked-array placeholders by id into ``out``."""
    if isinstance(obj, dict):
        if obj.get(_CHUNKED):
            out[int(obj["id"])] = obj
            return
        for v in obj.values():
            _index_chunked(v, out)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            _index_chunked(v, out)
