"""Decentralized PDMM over a general graph topology (Zhang & Heusdens,
*Distributed Optimization Using the Primal-Dual Method of Multipliers*;
Sherson et al., *Derivation and Analysis of PDMM Based on Monotone Operator
Theory*) -- the setting the source paper specializes to a star.

Consensus problem over a connected graph G = (V, E):

    min sum_i f_i(x_i)   s.t.  A_{ij} x_i + A_{ji} x_j = 0  for (i,j) in E

with A_{ij} = +I if i < j else -I (so every edge enforces x_i = x_j).  Each
DIRECTED edge carries a dual z_{i|j} held by node i; one node update reads
only the node's own duals:

    x_i   = argmin_x f_i(x) + s_i^T x + (c d_i / 2) ||x||^2,
            s_i = sum_{j in N(i)} A_{ij} z_{i|j}                (prox step)
    z_{j|i}' = z_{i|j} + 2 c A_{ij} x_i   for j in N(i)         (dual flip)

Firing schedules (``FederatedConfig.graph_schedule``):

  * ``"color"`` (default) -- color classes of the greedy coloring fire
    sequentially within a round, each phase re-reducing the freshly flipped
    duals.  On a star ({clients}, {server}) this IS the centralised
    algorithm: with z_{i|s} = lam_{s|i} - rho x_s the rounds reproduce
    ``core.pdmm`` / ``core.gpdmm`` iterate-for-iterate (the conformance
    oracle in ``tests/test_topology.py``).
  * ``"sync"`` -- all nodes fire at once from the round-start duals
    (Jacobi / synchronous PDMM).

Stochastic firing (``cfg.participation < 1``): each round a random subset of
DATA nodes fires, drawn from the shared ``FederatedConfig.seed`` mask
contract (``gpdmm.participation_key``), the decentralized analogue of
partial participation; silent nodes keep their primal carry and their
neighbors keep the stale duals -- exactly the centralised ``u_hat`` cache
semantics on a star.  Aux nodes (star's f = 0 center) always fire.

Two objective interfaces, mirroring the centralised pair:

  * ``make_exact`` (algorithm ``"pdmm_graph"``) -- ``round(state, prox_fn,
    batch)`` with ``prox_fn(v_stacked, rho)`` where rho may be a PER-NODE
    ``(k,)`` array (c * degree varies across nodes).  A prox accepting the
    optional STATIC ``idx`` kwarg (``quadratic.LeastSquares
    .make_client_prox`` does) is evaluated only on each phase's firing
    subset; plain 2-arg proxes are evaluated at the full stacking with the
    firing rows selected.
  * ``make`` (algorithm ``"gpdmm_graph"``) -- the gradient-based inner loop
    (K inexact steps, stepsize 1/(1/eta + c d_i)), resolved through the
    ``core.api`` oracle protocol: affine oracles fold the neighbor-dual sum
    s_i into the affine offset row and run the WHOLE K-step loop as the one
    fused kernel in ``kernels/inner_loop.py`` (per-node stepsizes and the
    c d_i I curvature shift are folded into (H, c) outside the kernel);
    ``grad_arena`` oracles (softmax regression) scan the fused arena update
    with zero boundary passes.

State is arena-native: ``x`` is the ``(n, width)`` node-primal arena (the
gradient carry), ``z`` the ``(2|E|, width)`` edge-dual arena
(``core.topology`` slot layout), both donated in place; ``x_s`` is the
server-sized consensus estimate pytree (the aux node's row on a star, the
node mean otherwise) kept for the ``server_params`` contract.
"""
from __future__ import annotations

import functools
import inspect
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig
from repro.core import arena, faults, topology
from repro.core import tree_util as T
from repro.core.api import FedOpt, affine_case, arena_grad, resolved_rho
from repro.core.gpdmm import participation_key
from repro.kernels import ops


def _prox_takes_idx(fn) -> bool:
    """Does the prox oracle accept the static firing-subset ``idx`` kwarg
    (``make_client_prox`` does)?  Plain 2-arg proxes fall back to the
    full-stacking evaluation."""
    try:
        return "idx" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


@functools.lru_cache(maxsize=None)
def _compiled(spec_str: str, m: int, seed: int) -> topology.Topology:
    """Topology compilation cache: pure in (spec, m, seed), so the round can
    rebuild the static tables from the state shape alone."""
    return topology.make(spec_str, m, seed=seed)


def topo_for(cfg: FederatedConfig, m: int) -> topology.Topology:
    return _compiled(cfg.topology, m, cfg.seed)


def _n_data_of(cfg: FederatedConfig, n_nodes: int) -> int:
    """Data-node count from the node-primal arena's row count (star carries
    one aux center)."""
    return n_nodes - 1 if cfg.topology.partition(":")[0] == "star" else n_nodes


def edge_duals_init(topo: topology.Topology, row, c: float):
    """Round-0 edge duals z_{i|j} = c A_{ji} x_j^0 = -c sgn * row: on a star
    this is exactly the centralised zero-lam init (z_{i|s} = -rho x_s^0 and
    z_{s|i} = rho u_i^0)."""
    sgnf = jnp.asarray(topo.sgn, jnp.float32)
    return ((-c) * sgnf[:, None] * row[None].astype(jnp.float32)).astype(row.dtype)


# ---------------------------------------------------------------------------
# the K-step gradient inner loop for one firing set of data nodes
# ---------------------------------------------------------------------------

def inner_steps_graph(spec, grad_fn, x0, s, batch, *, K, eta, c, deg, per_step):
    """K inexact-PDMM steps at stepsize 1/(1/eta + c d_i) for the stacked
    data nodes: x <- x - step_i (grad f_i(x) + c d_i x + s_i).

    On a star (d_i = 1, s_i = lam_{s|i} - rho x_s) this is the centralised
    eq. (20) verbatim.  Resolution, fastest first (core.api protocol):

      1. affine oracle + width fits VMEM: the whole loop is ONE
         ``kernels/inner_loop.py`` kernel.  Per-node stepsizes fold into the
         affine pair (H' = step_i (H + c d_i I), c' = step_i c) and the
         neighbor-dual sum rides the dual operand (lam = step_i s_i), so the
         kernel runs with unit step and zero rho -- no kernel change needed.
      2. constant data-node degree (star/ring/torus/complete): a scan of the
         fused arena update with rho = c d, the server row pinned to zero.
      3. irregular degrees (er): a plain jnp scan with per-node step/degree
         columns (still zero boundary passes with an arena-native oracle).

    deg: STATIC numpy per-node degrees.  ``eta`` may be a scalar or a
    STATIC per-node array (the firing subset of the auto-eta tuple, see
    ``_phase``); either way ``step`` stays a static numpy array, so per-node
    stepsizes cost nothing at trace time.  Returns (x_K, x_bar).
    """
    step = 1.0 / (1.0 / np.asarray(eta, np.float64) + c * deg.astype(np.float64))

    affine = affine_case(grad_fn, spec, per_step=per_step)
    if affine is not None:
        H, cc = affine(spec, batch)
        f32 = jnp.float32
        stepc = jnp.asarray(step, f32)[:, None]
        cd = jnp.asarray(c * deg, f32)[:, None, None]
        # + c d_i I touches padded diagonal entries too -- harmless, padded
        # coordinates update as x - step * c d_i * 0 and stay identically 0
        Hs = (H.astype(f32) + cd * jnp.eye(spec.width, dtype=f32)) * stepc[..., None]
        cs = cc.astype(f32) * stepc
        lam = s.astype(f32) * stepc
        zero_row = jnp.zeros((spec.width,), x0.dtype)
        return ops.inner_loop_affine(x0, Hs, cs, zero_row, lam, 1.0, 0.0, int(K))

    grad_a, _native = arena_grad(grad_fn, spec)
    # the scalar-collapse scan needs BOTH a constant effective rho (c d) and
    # a constant step: per-node eta falls through to the column branch
    const_deg = bool((deg == deg[0]).all() and (step == step[0]).all())
    if const_deg:
        rho_eff = float(c * deg[0])
        stp = float(step[0])
        zero_row = jnp.zeros((spec.width,), x0.dtype)

        def one_step(carry, xs_k):
            x, xsum = carry
            b = xs_k if per_step else batch
            g = grad_a(x, b)
            x_new = ops.fused_update_arena(x, g, zero_row, s, stp, rho_eff)
            return (x_new, xsum + x_new), None
    else:
        f32 = jnp.float32
        stp = jnp.asarray(step, f32)[:, None]
        cd = jnp.asarray(c * deg, f32)[:, None]

        def one_step(carry, xs_k):
            x, xsum = carry
            b = xs_k if per_step else batch
            g = grad_a(x, b).astype(f32)
            xf = x.astype(f32)
            x_new = (xf - stp * (g + cd * xf + s.astype(f32))).astype(x0.dtype)
            return (x_new, xsum + x_new), None

    init = (x0, jnp.zeros_like(x0))
    if per_step:
        (x_K, xsum), _ = jax.lax.scan(one_step, init, batch)
    else:
        (x_K, xsum), _ = jax.lax.scan(one_step, init, None, length=K)
    return x_K, xsum * (1.0 / K)


# ---------------------------------------------------------------------------
# one firing phase (a color class, or all nodes under the sync schedule)
# ---------------------------------------------------------------------------

def _phase(cfg, topo, spec, x, z, fn, batch, per_step, pmask, fplan, c,
           exact, members):
    """Nodes in ``members`` (static) fire: re-reduce the duals, update their
    primal rows, flip the duals on their incident edges.  ``pmask`` (dynamic
    (n_data,) bool or None) silences data nodes for stochastic firing AND for
    the round's fault silence (folded in by ``_round``).  ``fplan`` corrupts
    the TRANSMITTED ``x_ref`` of firing data nodes; screening compares each
    node's transmission against its own round-start carry (the per-row
    reference variant of ``ops.screen_uplink``) and demotes outliers --
    their carry reverts and their incident dual flips are masked, exactly a
    silent node.  Returns ``(x, z, demoted_count)``."""
    s = ops.neighbor_reduce(
        z, seg=topo.src, first=topo.first_flags(), sgn=topo.sgn, n=topo.n
    )
    dm = members[members < topo.n_data]  # static firing data nodes
    am = members[members >= topo.n_data]  # static firing aux (f = 0) nodes
    x_flip = x
    keep = None
    demoted = jnp.zeros((), jnp.float32)

    if dm.size:
        deg_dm = topo.deg[dm]
        x0 = x[dm]
        s_dm = s[dm]
        take = (lambda a: a[:, dm]) if per_step else (lambda a: a[dm])
        b_dm = jax.tree.map(take, batch)
        if exact:
            # x_i = argmin f_i + s^T x + (c d_i/2)||x||^2
            #     = prox_{f_i, c d_i}(-s_i / (c d_i)); per-node rho array.
            rho_dm = jnp.asarray(c * deg_dm, jnp.float32)
            if _prox_takes_idx(fn):
                # idx-aware prox (make_client_prox): evaluate ONLY the
                # firing subset's data -- on multi-color topologies the
                # full-stacking alternative would redo the whole prox once
                # per color class and discard all but these rows
                v_rows = -s_dm.astype(jnp.float32) / rho_dm[:, None]
                x_cand = spec.pack_stacked(
                    fn(spec.unpack_stacked(v_rows.astype(x.dtype)), rho_dm,
                       idx=dm)
                )
            else:
                # plain 2-arg prox closes over data stacked for ALL n_data
                # clients: evaluate at the full stacking and select the
                # firing rows (a star's data nodes share one color, so
                # nothing is discarded there)
                nd = topo.n_data
                rho_all = jnp.asarray(c * topo.deg[:nd], jnp.float32)
                v_rows = -s[:nd].astype(jnp.float32) / rho_all[:, None]
                x_all = spec.pack_stacked(
                    fn(spec.unpack_stacked(v_rows.astype(x.dtype)), rho_all)
                )
                x_cand = x_all[dm]
            x_ref = x_cand
        else:
            # per-node auto-eta: subset the host-resolved tuple by the
            # phase's STATIC firing members (eta stays trace-constant)
            eta_dm = (np.asarray(cfg.eta, np.float64)[dm]
                      if isinstance(cfg.eta, tuple) else cfg.eta)
            x_K, x_bar = inner_steps_graph(
                spec, fn, x0, s_dm, b_dm, K=cfg.inner_steps, eta=eta_dm,
                c=c, deg=deg_dm, per_step=per_step,
            )
            x_cand = x_K  # the primal carry (GPDMM: x_i^{r,0} = x_i^{r-1,K})
            x_ref = x_bar if cfg.use_avg else x_K  # what the dual flip sees
        # the wire corrupts the TRANSMITTED x_ref; the node's local carry
        # x_cand stays honest (a neighbor cannot corrupt local state)
        plan_dm = faults.take(fplan, dm)
        x_ref = faults.inject(cfg.faults, plan_dm, x_ref)
        if faults.screening_on(cfg):
            # receivers screen each node's transmission against that node's
            # own previous carry (the per-row reference)
            keep = faults.screen_keep(cfg, x_ref, x0)
            sub_alive = (jnp.ones(dm.size, bool) if pmask is None
                         else pmask[jnp.asarray(dm)])
            demoted = jnp.sum((sub_alive & ~keep).astype(jnp.float32))
        sub = None if pmask is None else pmask[jnp.asarray(dm)]
        sub = faults.combine_mask(sub, None, keep)
        if sub is not None:
            # demoted == silent, full stop: the carry reverts too
            x_cand = jnp.where(sub[:, None], x_cand, x0)
            x_ref = jnp.where(sub[:, None], x_ref, x0)
        x = x.at[dm].set(x_cand)
        x_flip = x.at[dm].set(x_ref)

    if am.size:
        # f = 0 nodes (star's center): exact closed form x = -s / (c d)
        x_aux = (-s[am].astype(jnp.float32)
                 / jnp.asarray(c * topo.deg[am], jnp.float32)[:, None]
                 ).astype(x.dtype)
        x = x.at[am].set(x_aux)
        x_flip = x_flip.at[am].set(x_aux)

    fired_static = np.zeros(topo.n, bool)
    fired_static[members] = True
    dyn = pmask
    if keep is not None:
        # scatter this phase's keep over the data nodes; non-firing rows stay
        # True (they are masked out by fired_static anyway)
        keep_full = jnp.ones((topo.n_data,), bool).at[jnp.asarray(dm)].set(keep)
        dyn = keep_full if dyn is None else dyn & keep_full
    if dyn is None:
        slot_static = fired_static[topo.nbr]
        mask = None if slot_static.all() else jnp.asarray(slot_static, jnp.int32)
    else:
        fire_nodes = jnp.concatenate(
            [jnp.asarray(fired_static[: topo.n_data]) & dyn,
             jnp.asarray(fired_static[topo.n_data:])]
        )
        mask = fire_nodes[jnp.asarray(topo.nbr)].astype(jnp.int32)
    z = ops.edge_flip(z, x_flip, c, rev=topo.rev, nbr=topo.nbr, sgn=topo.sgn,
                      mask=mask)
    return x, z, demoted


# ---------------------------------------------------------------------------
# the round
# ---------------------------------------------------------------------------

def _round(cfg: FederatedConfig, state, fn, batch, per_step_batches=False, *,
           exact: bool):
    c = resolved_rho(cfg)
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    x, z = state["x"], state["z"]
    topo = topo_for(cfg, _n_data_of(cfg, x.shape[0]))

    pmask = None
    if cfg.participation < 1.0:
        pmask = T.participation_mask(
            participation_key(cfg, state["round"]), topo.n_data, cfg.participation
        )
    # the round's fault plan over the DATA nodes; silence folds into the
    # firing mask (a silent node neither updates nor flips -- the neighbors
    # keep their stale duals, the centralised u_hat cache semantics)
    fplan = faults.plan(cfg, state["round"], topo.n_data)
    if fplan is not None:
        alive = ~fplan.silent
        pmask = alive if pmask is None else pmask & alive

    if cfg.graph_schedule == "color":
        phases = topo.colors
    elif cfg.graph_schedule == "sync":
        phases = (np.arange(topo.n, dtype=np.int32),)
    else:
        raise ValueError(
            f"unknown graph_schedule {cfg.graph_schedule!r} (color | sync)")

    demoted = jnp.zeros((), jnp.float32)
    for members in phases:
        x, z, dem = _phase(cfg, topo, spec, x, z, fn, batch, per_step_batches,
                           pmask, fplan, c, exact, members)
        demoted = demoted + dem

    # consensus estimate: the aux center's row on a star (== the centralised
    # x_s), the node mean otherwise
    est_row = x[topo.n_data] if topo.n_aux else jnp.mean(x, axis=0)
    f32 = jnp.float32
    xf = x.astype(f32)
    consensus = jnp.mean(
        jnp.sum(jnp.square(xf[jnp.asarray(topo.src)] - xf[jnp.asarray(topo.nbr)]),
                axis=1)
    ) * 0.5  # each undirected edge appears in two directed slots
    new_state = {
        "x_s": spec.unpack(est_row),
        "x": x,
        "z": z,
        "round": state["round"] + 1,
    }
    metrics = {
        "consensus_err": consensus,
        "used_arena": jnp.ones((), f32),
    }
    if fplan is not None or faults.screening_on(cfg):
        metrics["faults_injected"] = (
            jnp.zeros((), f32) if fplan is None
            else jnp.sum((fplan.silent | fplan.corrupt).astype(f32)))
        metrics["faults_demoted"] = demoted
    return new_state, metrics


def _make(cfg: FederatedConfig, *, exact: bool, name: str) -> FedOpt:
    if cfg.uplink_bits is not None:
        raise NotImplementedError(
            "EF21 uplink quantisation integrates ONE cached server view per "
            "client; graph-PDMM exchanges one directed dual per edge, so a "
            "per-client integrator does not apply (a per-EDGE integrator is "
            "future work)"
        )
    if not exact and cfg.variance_reduction is not None:
        raise NotImplementedError(
            "variance reduction is not wired for graph-PDMM yet "
            "(snapshot gradients need a per-node consensus reference)"
        )

    def init(params, m):
        topo = topo_for(cfg, m)
        spec = arena.ArenaSpec.from_tree(params)
        row = spec.pack(params)
        c = resolved_rho(cfg)
        return {
            "x_s": params,
            "x": jnp.broadcast_to(row[None], (topo.n, spec.width)),
            "z": edge_duals_init(topo, row, c),
            "round": jnp.zeros((), jnp.int32),
        }

    return FedOpt(
        name=name,
        init=init,
        round=partial(_round, cfg, exact=exact),
        server_params=lambda s: s["x_s"],
    )


def make(cfg: FederatedConfig) -> FedOpt:
    """Gradient-based graph-PDMM (the decentralized GPDMM analogue)."""
    return _make(cfg, exact=False, name="gpdmm_graph")


def make_exact(cfg: FederatedConfig) -> FedOpt:
    """Exact (prox-oracle) graph-PDMM; ``round(state, prox_fn, batch)``."""
    return _make(cfg, exact=True, name="pdmm_graph")
