"""Deterministic synthetic data generators.

The container has no datasets, so the paper's MNIST / Fashion-MNIST softmax
regression is reproduced on a deterministic 10-class Gaussian-mixture image
problem with the same geometry (28x28 inputs, 10 classes, one class per
client -- the paper's heterogeneous split).  This substitution is recorded in
EXPERIMENTS.md.  The LM pipeline generates Zipf-distributed token streams with
per-client topic skew so federated heterogeneity is real.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# 10-class image mixture (MNIST stand-in)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ImageDataset:
    x_train: jax.Array  # (N, 784)
    y_train: jax.Array  # (N,)
    x_val: jax.Array
    y_val: jax.Array
    n_classes: int = 10


def gaussian_mixture_images(
    key, n_train_per_class: int = 1000, n_val_per_class: int = 200, d: int = 784,
    n_classes: int = 10, sep: float = 1.2, noise: float = 1.0,
) -> ImageDataset:
    kc, kt, kv = jax.random.split(key, 3)
    # class means: smooth random "digit templates"
    means = jax.random.normal(kc, (n_classes, d)) * sep
    # low-rank structure so classes overlap like real digits
    basis = jax.random.normal(jax.random.fold_in(kc, 1), (d, 32)) / np.sqrt(d)

    def sample(k, n_per):
        ks = jax.random.split(k, n_classes)
        xs, ys = [], []
        for c in range(n_classes):
            z = jax.random.normal(ks[c], (n_per, 32))
            eps = jax.random.normal(jax.random.fold_in(ks[c], 7), (n_per, d))
            x = means[c][None] + z @ basis.T * 2.0 + eps * noise
            xs.append(x)
            ys.append(jnp.full((n_per,), c, jnp.int32))
        return jnp.concatenate(xs), jnp.concatenate(ys)

    xt, yt = sample(kt, n_train_per_class)
    xv, yv = sample(kv, n_val_per_class)
    return ImageDataset(xt, yt, xv, yv, n_classes)


# ---------------------------------------------------------------------------
# synthetic LM token streams
# ---------------------------------------------------------------------------

def lm_token_stream(key, n_tokens: int, vocab: int, topic: int = 0, n_topics: int = 8):
    """Zipf-ish unigram stream with a topic-dependent permutation, so
    different clients (topics) have genuinely different distributions."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    perm = jax.random.permutation(jax.random.fold_in(jax.random.key(1234), topic), vocab)
    toks = jax.random.choice(key, vocab, (n_tokens,), p=jnp.asarray(probs, jnp.float32))
    return perm[toks].astype(jnp.int32)


def _lm_batch_for(key, step: int, clients, m_all: int, per_client_batch: int,
                  seq_len: int, vocab: int):
    """One {tokens, targets} batch for the given client ids at round
    ``step``: the key always splits ``m_all`` ways and client i draws from
    split i / topic i, so any subset of clients sees exactly the data it
    would see in the full stacking (the cohort-stream ==
    gathered-full-stream contract)."""
    ks = jax.random.split(jax.random.fold_in(key, step), m_all)
    toks = jnp.stack(
        [
            lm_token_stream(ks[i], per_client_batch * (seq_len + 1), vocab, topic=i).reshape(
                per_client_batch, seq_len + 1
            )
            for i in (int(c) for c in clients)
        ]
    )
    return {"tokens": toks[..., :-1], "targets": toks[..., 1:]}


def lm_batches(key, n_steps: int, m: int, per_client_batch: int, seq_len: int,
               vocab: int, start: int = 0):
    """Yields {tokens, targets} with leading client dim m (heterogeneous:
    client i draws from topic i).  ``start`` offsets the per-round key fold,
    so a resumed run sees EXACTLY the batches the uninterrupted run would
    have seen from that round on (the checkpoint-resume contract)."""
    for step in range(start, start + n_steps):
        yield _lm_batch_for(key, step, range(m), m, per_client_batch, seq_len, vocab)


def cohort_lm_batches(key, n_steps: int, m: int, per_client_batch: int,
                      seq_len: int, vocab: int, *, participation: float,
                      fed_seed: int, start: int = 0):
    """Cohort-sized LM batch stream (ISSUE 5): round r yields batches ONLY
    for that round's active cohort -- ``ceil(participation * m)`` rows,
    sorted by client id -- drawn from the SAME mask contract the round
    engine uses (``fold_in(key(fed_seed), r)``, ``tree_util.cohort_indices``).
    At population scale nobody materialises data for silent clients; each
    active row is identical to the corresponding row of ``lm_batches``, so
    the engine's pass-through path (``core.api.cohort_batch``) sees exactly
    the rows its own gather would have produced."""
    from repro.core.tree_util import cohort_indices

    for step in range(start, start + n_steps):
        idx, _ = cohort_indices(
            jax.random.fold_in(jax.random.key(fed_seed), step), m, participation
        )
        yield _lm_batch_for(key, step, np.asarray(idx), m, per_client_batch,
                            seq_len, vocab)
