"""Production mesh construction.

The target is TPU v5e: one pod = 16 x 16 = 256 chips with axes
("data", "model"); the multi-pod configuration stacks 2 pods = 512 chips with
axes ("pod", "data", "model").  Everything is a function -- importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; older jax only has Auto semantics
    from jax.sharding import AxisType

    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
except ImportError:
    _AXIS_KW = lambda n: {}  # noqa: E731


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_smoke_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many (CPU) devices a test process has."""
    return jax.make_mesh((n_data, n_model), ("data", "model"), **_AXIS_KW(2))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` on jax >= 0.6; on 0.4.x the Mesh itself is the
    (legacy thread-local) context manager the sharding constraints read."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


# TPU v5e hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (~3 links usable per axis-neighbour topology)
