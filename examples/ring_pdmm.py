"""Decentralized PDMM over a ring -- no server at all, in ~25 lines.

Each node talks only to its two ring neighbors, exchanging one directed dual
per edge per round (the general-network PDMM the paper specializes to a
star); every node still converges to the GLOBAL least-squares optimum.

    PYTHONPATH=src python examples/ring_pdmm.py [rounds]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import make, quadratic

rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 300

# The same federated least-squares problem as quickstart.py -- but solved
# over a ring of 8 peers instead of a client-server star.
prob = quadratic.generate(jax.random.key(0), m=8, n=400, d=64)

cfg = FederatedConfig(algorithm="gpdmm", topology="ring",
                      inner_steps=5, eta=0.5 / prob.L)
opt = make(cfg)  # topology != "star" routes gpdmm to graph-PDMM
assert opt.name == "gpdmm_graph"
state = opt.init(jnp.zeros((prob.d,)), prob.m)


@jax.jit
def round_fn(s):
    return opt.round(s, prob.oracle(), prob.batch())


for r in range(rounds):
    state, metrics = round_fn(state)
    if r % max(1, rounds // 5) == 0 or r == rounds - 1:
        dist = float(prob.dist(opt.server_params(state)))
        print(f"round {r:3d}  ||x - x*|| {dist:.3e}  "
              f"consensus {float(metrics['consensus_err']):.2e}")

# every node individually (not just the mean) reaches the global optimum
worst = float(jnp.max(jnp.linalg.norm(
    state["x"][:, : prob.d] - prob.x_star[None], axis=1)))
print(f"worst per-node distance to x*: {worst:.3e}")
assert worst < 1e-2, worst
print("converged -- decentralized PDMM solves the global problem on a ring.")
