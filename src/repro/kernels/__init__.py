"""Pallas TPU kernels for the compute hot-spots, with XLA fallbacks.

Layout (per the repo convention):
  * ``<name>.py`` -- the Pallas kernel (``pl.pallas_call`` + ``BlockSpec``)
  * ``ops.py``    -- jit'd dispatch wrappers (xla | pallas | pallas_interpret)
  * ``ref.py``    -- pure-jnp oracles the kernels are validated against

Kernels:
  * ``fused_update``    -- the paper's GPDMM/AGPDMM client inner step (eq. 20),
                           a memory-bound 4-read/1-write elementwise fusion.
  * ``round_tail``      -- fused GPDMM/AGPDMM round tail over the flat
                           client-state arena (core.arena): lam_is + uplink
                           one-pass, 2-pass EF21 quantise-delta, dual refresh,
                           and the arena-wide eq.-(20) step with in-kernel
                           server-row broadcast (see docs/arena.md).
  * ``wkv6``            -- RWKV-6 chunked recurrence (data-dependent decay).
  * ``flash_attention`` -- causal / sliding-window GQA attention.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
