"""Quickstart: solve the paper's least-squares problem with GPDMM in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import make, quadratic

# A federated least-squares problem: 8 clients, heterogeneous data.
prob = quadratic.generate(jax.random.key(0), m=8, n=400, d=64)

# GPDMM (paper Alg. 1): K=5 local prox-gradient steps per round,
# rho = 1/(K*eta) -- the paper's default coupling.
cfg = FederatedConfig(algorithm="gpdmm", inner_steps=5, eta=0.5 / prob.L)
opt = make(cfg)
state = opt.init(jnp.zeros((prob.d,)), prob.m)


@jax.jit
def round_fn(s):
    s, metrics = opt.round(s, prob.grad, prob.batch())
    return s, metrics


for r in range(100):
    state, metrics = round_fn(state)
    if r % 20 == 0 or r == 99:
        dist = float(prob.dist(opt.server_params(state)))
        print(f"round {r:3d}  ||x - x*|| {dist:.3e}  "
              f"dual-sum invariant {float(metrics['lam_sum_norm']):.2e}")

# iterate distance, not the f32 functional gap (F ~ 1e5: F - F* is pure
# rounding noise once converged)
assert float(prob.dist(opt.server_params(state))) < 1e-3
print("converged -- GPDMM solves the centralised-network problem.")
