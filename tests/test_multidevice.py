"""Multi-device smoke test (ISSUE 3): one arena SCAFFOLD + GPDMM round with
the client dim sharded over 8 (forced host) devices must produce the SAME
states as the single-device run.

Runs only under::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_multidevice.py

(the dedicated ``multidevice`` CI job); with fewer devices the module skips
cleanly, so the tier-1 suite stays single-process.

The interesting property: the stacked ``(m, width)`` arena buffers shard
over the ``data`` mesh axis, turning the server means into real cross-device
all-reduces -- this asserts the arena layout's collectives land on the same
numbers as the local reduction.  Bitwise equality is NOT the contract: an
8-way AllReduce tree-sums in a different order than the single-device
row-major sum, so the comparison is allclose at f32 resolution (observed
max deviation ~3e-5 on the rho-amplified duals, ~1e-7 on x_s).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import FederatedConfig
from repro.core import make, quadratic
from repro.launch.mesh import make_smoke_mesh

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

M = 8  # one client per device


@pytest.fixture(scope="module")
def prob():
    return quadratic.generate(jax.random.key(0), m=M, n=80, d=130)


def _place(mesh, tree):
    """Client-stacked (m, ...) arrays over the data axis; everything else
    (server pytrees, scalars) replicated -- the launch/steps.py contract."""
    def put(x):
        stacked = x.ndim >= 1 and x.shape[0] == M
        spec = P("data", *([None] * (x.ndim - 1))) if stacked else P(*([None] * x.ndim))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree)


@pytest.mark.parametrize("algo", ["scaffold", "gpdmm"])
def test_sharded_round_matches_single_device(prob, algo):
    cfg = FederatedConfig(algorithm=algo, inner_steps=2, eta=0.5 / prob.L,
                          use_arena=True)
    opt = make(cfg)
    grad = prob.oracle()
    batch = prob.batch()
    state = opt.init(jnp.zeros((prob.d,)), M)

    # reference: everything on ONE device
    dev0 = jax.devices()[0]
    ref_state, ref_metrics = jax.jit(lambda s, b: opt.round(s, grad, b))(
        jax.device_put(state, dev0), jax.device_put(batch, dev0))

    # sharded: client dim over the 8-device data axis of the smoke mesh
    mesh = make_smoke_mesh(8, 1)
    sh_state, sh_metrics = jax.jit(lambda s, b: opt.round(s, grad, b))(
        _place(mesh, state), _place(mesh, batch))

    assert set(ref_state) == set(sh_state)
    for k in sorted(ref_state):
        for i, (gl, wl) in enumerate(zip(jax.tree.leaves(sh_state[k]),
                                         jax.tree.leaves(ref_state[k]))):
            np.testing.assert_allclose(
                np.asarray(gl), np.asarray(wl), atol=1e-4, rtol=1e-4,
                err_msg=f"{algo}: state[{k}] leaf {i}")
    for k in sorted(ref_metrics):
        np.testing.assert_allclose(
            np.asarray(sh_metrics[k]), np.asarray(ref_metrics[k]),
            atol=1e-4, rtol=1e-3, err_msg=f"{algo}: metrics[{k}]")


def test_sharded_ring_topology_round_matches_single_device(prob):
    """ISSUE 4: one gradient graph-PDMM round on a RING with the node-primal
    (m, width) and edge-dual (2m, width) arenas sharded over the 8-device
    data axis must match the single-device round at f32 resolution.  The
    neighbor reduce and the dual flip gather across shard boundaries (every
    node's neighbors live on other devices), so this exercises the
    collectives XLA inserts around the edge-dual arena."""
    cfg = FederatedConfig(algorithm="gpdmm_graph", topology="ring",
                          inner_steps=2, eta=0.5 / prob.L, use_arena=True)
    opt = make(cfg)
    grad = prob.oracle()
    batch = prob.batch()
    state = opt.init(jnp.zeros((prob.d,)), M)

    dev0 = jax.devices()[0]
    ref_state, ref_metrics = jax.jit(lambda s, b: opt.round(s, grad, b))(
        jax.device_put(state, dev0), jax.device_put(batch, dev0))

    mesh = make_smoke_mesh(8, 1)

    def put(x):
        # rows over the data axis whenever they divide the 8-way axis: the
        # m node rows AND the 2m directed-dual rows (steps.py rows_shard)
        rows = x.ndim >= 1 and x.shape[0] >= M and x.shape[0] % M == 0
        spec = P("data", *([None] * (x.ndim - 1))) if rows else P(*([None] * x.ndim))
        return jax.device_put(x, NamedSharding(mesh, spec))

    sh_state, sh_metrics = jax.jit(lambda s, b: opt.round(s, grad, b))(
        jax.tree.map(put, state), jax.tree.map(put, batch))

    assert set(ref_state) == set(sh_state)
    for k in sorted(ref_state):
        for i, (gl, wl) in enumerate(zip(jax.tree.leaves(sh_state[k]),
                                         jax.tree.leaves(ref_state[k]))):
            np.testing.assert_allclose(
                np.asarray(gl), np.asarray(wl), atol=1e-4, rtol=1e-4,
                err_msg=f"ring: state[{k}] leaf {i}")
    np.testing.assert_allclose(
        np.asarray(sh_metrics["consensus_err"]),
        np.asarray(ref_metrics["consensus_err"]), atol=1e-4, rtol=1e-3)
