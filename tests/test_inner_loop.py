"""ISSUE 2: arena-native gradient oracles, the fused K-step inner-loop
kernel, and the round-batched scan driver.

Covers: interpret-mode parity of the fused affine K-step kernel against both
``inner_steps`` (pytree) and the step-at-a-time arena scan over K in {1, 4}
and odd (non-multiple-of-128) widths; the SVRG variant through the
arena-native oracle; round-batched-scan vs loop-of-rounds state equality;
the closed-form softmax oracle vs jax.grad; ridge-regularised quadratics;
the participation-seed contract; and the use_arena="auto" width dispatch
(recorded in round metrics).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederatedConfig
from repro.core import arena, make, make_scan_rounds, quadratic
from repro.core import tree_util as T
from repro.core.gpdmm import inner_steps, inner_steps_arena, participation_key
from repro.core.softmax import SoftmaxRegression
from repro.kernels import ops
from repro.kernels.fused_update import VMEM_CAP_BYTES
from repro.kernels.inner_loop import fits_vmem, vmem_bytes

IMPLS = ["xla", "pallas_interpret"]


@pytest.fixture(scope="module", params=[24, 130], ids=["d24", "d130_odd"])
def prob(request):
    # d=24 -> width 128; d=130 -> width 256 with 126 zero-padded columns
    return quadratic.generate(jax.random.key(0), m=6, n=80, d=request.param)


# ---------------------------------------------------------------------------
# fused K-step kernel parity: pallas_interpret == xla == inner_steps(_arena)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("K", [1, 4])
def test_inner_loop_affine_parity(prob, impl, K):
    """The single-kernel K-step loop reproduces the step-at-a-time references
    on both the pytree and arena paths, padding included."""
    m, d = prob.m, prob.d
    eta = 0.5 / prob.L
    rho = 1.0 / (K * eta)
    step_c = 1.0 / (1.0 / eta + rho)
    spec = arena.ArenaSpec.from_tree(jnp.zeros((d,)))
    key = jax.random.key(1)
    x0_t = jax.random.normal(jax.random.fold_in(key, 0), (m, d))
    lam_t = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (m, d))
    xs_t = jax.random.normal(jax.random.fold_in(key, 2), (d,))

    # reference 1: pytree inner loop (vmapped per-client grad)
    x_K_ref, x_bar_ref = inner_steps(
        prob.grad, x0_t, T.tree_broadcast(xs_t, m), lam_t, prob.batch(),
        K=K, eta=eta, rho=rho, per_step=False)

    # reference 2: step-at-a-time arena scan with the plain (wrapped) grad
    x0a, lama = spec.pack_stacked(x0_t), spec.pack_stacked(lam_t)
    xsa = spec.pack(xs_t)
    x_K_scan, x_bar_scan = inner_steps_arena(
        spec, prob.grad, x0a, xsa, lama, prob.batch(),
        K=K, eta=eta, rho=rho, per_step=False)

    # the fused kernel under test
    oracle = prob.oracle()
    H, c = oracle.affine_arena(spec, prob.batch())
    x_K, x_bar = ops.inner_loop_affine(x0a, H, c, xsa, lama, step_c, rho, K, impl=impl)

    for got, want, name in [(x_K, x_K_scan, "x_K"), (x_bar, x_bar_scan, "x_bar")]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4, err_msg=name)
        # padding columns stay identically zero (arena invariant)
        assert np.all(np.asarray(got)[:, d:] == 0.0), name
    np.testing.assert_allclose(np.asarray(x_K[:, :d]), np.asarray(x_K_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(x_bar[:, :d]), np.asarray(x_bar_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm"])
@pytest.mark.parametrize("K", [1, 4])
def test_round_parity_with_oracle(prob, algo, K):
    """Whole rounds driven by the annotated oracle (affine fused path on the
    arena) match the plain-grad pytree path."""
    kw = dict(algorithm=algo, inner_steps=K, eta=0.5 / prob.L)
    x0 = jnp.zeros((prob.d,))
    outs = {}
    for use_arena, grad in [(True, prob.oracle()), (False, prob.grad)]:
        opt = make(FederatedConfig(use_arena=use_arena, **kw))
        s = opt.init(x0, prob.m)
        for _ in range(4):
            s, metrics = opt.round(s, grad, prob.batch())
        outs[use_arena] = np.asarray(s["x_s"])
        assert float(metrics["used_arena"]) == float(use_arena)
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-5, rtol=1e-5)


def test_vmem_gate():
    """The fused K-step kernel is gated on its documented VMEM budget; a
    width past the cap must refuse (the round then takes the scan path)."""
    assert fits_vmem(256)
    too_wide = 2048
    assert vmem_bytes(too_wide) > VMEM_CAP_BYTES and not fits_vmem(too_wide)
    with pytest.raises(AssertionError, match="VMEM"):
        from repro.kernels.inner_loop import inner_loop_affine_pallas
        z = jnp.zeros((2, too_wide))
        inner_loop_affine_pallas(z, jnp.zeros((2, too_wide, too_wide)), z,
                                 jnp.zeros((too_wide,)), z, 0.1, 1.0, 2,
                                 interpret=True)


def test_svrg_uses_scan_path_with_native_oracle():
    """SVRG (per-step batches) cannot use the fused affine kernel but still
    runs the arena-native oracle in the scan -- parity vs the pytree path."""
    key = jax.random.key(5)
    m, d, K = 4, 16, 3
    params = jnp.zeros((d,))
    batch = {"w": jax.random.normal(key, (K, m, d))}

    def plain(x, b):
        return 0.3 * x + 0.01 * b["w"]

    from repro.core.api import make_oracle
    native = make_oracle(plain, grad_arena=lambda spec: (
        lambda xa, b: 0.3 * xa + jnp.pad(0.01 * b["w"], ((0, 0), (0, spec.width - d)))
        if spec.width != d else 0.3 * xa + 0.01 * b["w"]))

    outs = {}
    for use_arena, grad in [(True, native), (False, plain)]:
        opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.1,
                                   variance_reduction="svrg", use_arena=use_arena))
        s = opt.init(params, m)
        for _ in range(3):
            s, _ = opt.round(s, grad, batch, per_step_batches=True)
        outs[use_arena] = np.asarray(s["x_s"])
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-6)


# ---------------------------------------------------------------------------
# round-batched scan driver == loop of rounds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", [{}, {"participation": 0.5},
                                     {"uplink_bits": 8}],
                         ids=["plain", "partial", "ef21"])
def test_scan_rounds_equals_loop(prob, variant):
    """R rounds inside one lax.scan land on the SAME state as R separate
    round calls (incl. the round-counter-folded participation RNG)."""
    R = 4
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=2, eta=0.5 / prob.L,
                          use_arena=True, **variant)
    opt = make(cfg)
    grad = prob.oracle()
    batch = prob.batch()

    s_loop = opt.init(jnp.zeros((prob.d,)), prob.m)
    per_round_metrics = []
    for _ in range(R):
        s_loop, mets = opt.round(s_loop, grad, batch)
        per_round_metrics.append(mets)

    scan = make_scan_rounds(opt, grad)
    batches = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), batch)
    s_scan, stacked = scan(opt.init(jnp.zeros((prob.d,)), prob.m), batches)

    # eager loop vs one traced scan: XLA fusion reorders the f32 math, so
    # exact bitwise equality is not the contract -- tight allclose is
    for k in s_loop:
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(s_loop[k])[0]),
            np.asarray(jax.tree.leaves(s_scan[k])[0]),
            atol=1e-4, rtol=1e-4, err_msg=f"state[{k}]")
    # metrics come back stacked (R,), matching the per-round values
    for k in stacked:
        got = np.asarray(stacked[k])
        assert got.shape[0] == R
        if k == "lam_sum_norm":  # KKT invariant: exactly-0 up to f32 noise,
            assert np.all(got < 1e-3)  # noise-vs-noise closeness is meaningless
            continue
        want = np.asarray([float(mm[k]) for mm in per_round_metrics])
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3,
                                   err_msg=f"metrics[{k}]")


# ---------------------------------------------------------------------------
# built-in oracles: softmax closed form, ridge quadratics
# ---------------------------------------------------------------------------

def test_softmax_grad_matches_autodiff():
    sm = SoftmaxRegression(n_features=12, n_classes=3)
    w = jax.random.normal(jax.random.key(1), (sm.dim,))
    b = {"x": jax.random.normal(jax.random.key(2), (20, 12)),
         "y": jax.random.randint(jax.random.key(3), (20,), 0, 3)}
    np.testing.assert_allclose(np.asarray(sm.grad(w, b)),
                               np.asarray(jax.grad(sm.loss)(w, b)), atol=1e-5)


def test_softmax_arena_round_parity():
    """The arena-native softmax gradient drives rounds identical to the
    pytree path (the Table I experiment's hot path)."""
    sm = SoftmaxRegression(n_features=12, n_classes=3)
    m = 4
    key = jax.random.key(7)
    batch = {"x": jax.random.normal(jax.random.fold_in(key, 0), (m, 20, 12)),
             "y": jax.random.randint(jax.random.fold_in(key, 1), (m, 20), 0, 3)}
    outs = {}
    for use_arena, grad in [(True, sm.oracle()), (False, sm.grad)]:
        opt = make(FederatedConfig(algorithm="agpdmm", inner_steps=3, eta=0.1,
                                   use_arena=use_arena))
        s = opt.init(sm.init_params(), m)
        for _ in range(3):
            s, _ = opt.round(s, grad, batch)
        outs[use_arena] = np.asarray(s["x_s"])
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-5, rtol=1e-5)


def test_ridge_quadratic():
    """with_ridge: optimum/constants are recomputed and the affine oracle
    carries the reg term -- grad of F at x* vanishes, rounds still agree."""
    base = quadratic.generate(jax.random.key(2), m=4, n=40, d=10)
    pr = base.with_ridge(0.7)
    assert pr.L == pytest.approx(base.L + 0.7) and pr.mu == pytest.approx(base.mu + 0.7)
    total_grad = (jnp.einsum("mde,e->d", pr.AtA, pr.x_star) - pr.Atb.sum(0)
                  + pr.m * pr.reg * pr.x_star)
    assert float(jnp.linalg.norm(total_grad)) < 1e-2
    assert float(pr.gap(pr.x_star)) == pytest.approx(0.0, abs=1e-2)
    outs = {}
    for use_arena, grad in [(True, pr.oracle()), (False, pr.grad)]:
        opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=3, eta=0.5 / pr.L,
                                   use_arena=use_arena))
        s = opt.init(jnp.zeros((pr.d,)), pr.m)
        for _ in range(6):
            s, _ = opt.round(s, grad, pr.batch())
        outs[use_arena] = np.asarray(s["x_s"])
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-5, rtol=1e-5)
    # the rounds actually make progress toward the ridge optimum
    assert np.linalg.norm(outs[True] - np.asarray(pr.x_star)) < np.linalg.norm(
        np.asarray(pr.x_star))


# ---------------------------------------------------------------------------
# satellite contracts: participation seed, auto arena dispatch
# ---------------------------------------------------------------------------

def test_participation_seed_contract():
    """Identical seeds -> identical masks ACROSS algorithms (a contract now,
    not an accident of a duplicated constant); different seeds differ."""
    m = 8
    g = FederatedConfig(algorithm="gpdmm", participation=0.5, seed=3)
    a = FederatedConfig(algorithm="agpdmm", participation=0.5, seed=3)
    other = FederatedConfig(algorithm="gpdmm", participation=0.5, seed=4)
    rounds = jnp.arange(6)
    masks = {
        name: np.asarray([T.participation_mask(participation_key(c, r), m, 0.5)
                          for r in rounds])
        for name, c in [("g", g), ("a", a), ("other", other)]
    }
    np.testing.assert_array_equal(masks["g"], masks["a"])
    assert not np.array_equal(masks["g"], masks["other"])


def test_seed_changes_partial_rounds(prob):
    cfgs = [FederatedConfig(algorithm="gpdmm", inner_steps=2, eta=0.5 / prob.L,
                            participation=0.5, seed=s) for s in (3, 3, 9)]
    finals = []
    for cfg in cfgs:
        opt = make(cfg)
        s = opt.init(jnp.zeros((prob.d,)), prob.m)
        for _ in range(3):
            s, _ = opt.round(s, prob.oracle(), prob.batch())
        finals.append(np.asarray(s["x_s"]))
    np.testing.assert_array_equal(finals[0], finals[1])  # same seed: bitwise
    assert not np.allclose(finals[0], finals[2])  # different seed: different rounds


def test_auto_arena_dispatch():
    """use_arena="auto": tiny widths keep the pytree layout, wide ones pack;
    the decision is visible in round metrics (used_arena)."""
    def grad(p, _b):
        return jax.tree.map(lambda x: 0.3 * x, p)

    m, batch = 4, {"d": jnp.zeros((4, 1))}
    for params, expect_arena in [
        ({"w": jnp.ones((24,))}, False),  # width 128 < arena_min_width
        ({"w": jnp.ones((4000,))}, True),  # width 4096
    ]:
        cfg = FederatedConfig(algorithm="gpdmm", inner_steps=2, eta=0.1)
        assert cfg.use_arena == "auto"
        opt = make(cfg)
        s = opt.init(params, m)
        # arena keeps clients packed as ONE (m, width) buffer; the pytree
        # path preserves the dict structure
        assert isinstance(s["lam_s"], jax.Array) == expect_arena
        s, metrics = opt.round(s, grad, batch)
        assert float(metrics["used_arena"]) == float(expect_arena)
