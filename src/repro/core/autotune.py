"""Auto-tuned stepsizes and residual-based early termination.

Every run used to hand-tune one global ``eta`` and burn a fixed round
budget even after the iterates converged.  This module ports pfb-clean's
``power_method.py`` / ``primal_dual.py`` recipe (SNIPPETS.md) to the
federated arena:

  * **Per-client smoothness L_i** -- a batched power iteration over the
    per-client Hessian blocks, run as ONE jitted ``lax.fori_loop`` on the
    stacked ``(m, ...)`` operands (no per-client Python loop).  Affine
    oracles (``affine_arena``: grad_i(x) = H_i x - c_i) power-iterate their
    H blocks directly; non-affine oracles fall back to a Hessian-vector
    power iteration through ``jax.jvp(grad)`` (the Hutchinson-style
    curvature probe -- exact for quadratics, a local estimate elsewhere).
    Oracles may override either path with an explicit ``curvature_arena``
    hook (``core.api`` protocol).

  * **Derived stepsizes** -- ``eta_i = safety / L_i`` (safety < 1 keeps
    ``1/eta_i > L_i``, the contraction condition ``core.theory.gpdmm_beta``
    asserts).  ``resolve`` turns ``eta="auto"`` in a ``FederatedConfig``
    into the hashable tuple form; the kernels consume the values as a
    per-client stepsize OPERAND (``kernels/ops`` ``_step_arr``), so the
    config stays jit-static and the scalar path stays bitwise untouched.

  * **Residual-based stopping** -- pfb-clean's relative fixed-point
    residual ``eps = ||x - x_prev|| / ||x||``: ``state_residual`` folds one
    fused ``ops.residual_norm`` pass per 2-D state buffer into two scalar
    round metrics (``res_dx2``/``res_x2``), and the HOST driver
    (``EarlyExit``) terminates the round loop once eps stays below ``tol``
    for ``patience`` consecutive rounds.  ``tol=0`` disables both the
    metric and the check (a static Python gate), so the fixed-budget graph
    is compiled unchanged.

See ``docs/autotune.md`` for the full contract.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig

# Power-iteration budget: the Rayleigh-quotient estimate converges as
# (lambda_2/lambda_1)^(2k), so ~tens of matvecs pin L to float precision on
# anything but a pathologically flat spectrum.
POWER_ITERS = 96

# 1/eta_i = L_i / safety must exceed L_i (the theory contraction condition);
# 0.5 doubles the margin, matching the hand-tuned settings' headroom.
SAFETY = 0.5


def _normalize(v):
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-30)


def _v0(m: int, w: int):
    """Deterministic start vector with a generic spectral footprint: a
    constant plus a ramp, so it is never orthogonal to a top eigenvector
    that a pure ones-vector could miss.  Padded coordinates are annihilated
    by the first H multiply (H is zero there by the arena invariant)."""
    ramp = jnp.linspace(0.0, 0.5, w, dtype=jnp.float32)
    return jnp.broadcast_to(1.0 + ramp, (m, w))


def power_iter_arena(H, iters: int = POWER_ITERS):
    """Largest eigenvalue of each PSD block of ``H (m, W, W)`` by batched
    power iteration: one jitted ``fori_loop`` over the stacked blocks, no
    per-client Python loop.  Returns ``L (m,)`` f32 (Rayleigh quotients of
    the final normalised iterates)."""
    m, w, _ = H.shape
    Hf = H.astype(jnp.float32)

    def body(_, v):
        return _normalize(jnp.einsum("mij,mj->mi", Hf, v))

    v = jax.lax.fori_loop(0, iters, body, _normalize(_v0(m, w)))
    return jnp.einsum("mi,mij,mj->m", v, Hf, v)


def power_iter_hvp(hvp, m: int, w: int, iters: int = POWER_ITERS):
    """Power iteration through a stacked Hessian-vector product
    ``hvp(v (m, w)) -> (m, w)`` (each client row independent).  Returns
    ``L (m,)``.  Curvature can be sign-indefinite away from a minimum, so
    the Rayleigh quotient is returned in absolute value."""

    def body(_, v):
        return _normalize(hvp(v))

    v = jax.lax.fori_loop(0, iters, body, _normalize(_v0(m, w)))
    return jnp.abs(jnp.einsum("mi,mi->m", v, hvp(v)))


def estimate_L(grad_fn, params, m: int, batch, *, spec=None,
               iters: int = POWER_ITERS):
    """Per-client smoothness estimates ``L (m,) np.float64``.

    Resolution order (the ``core.api`` oracle protocol):
      1. ``grad_fn.curvature_arena(spec)`` -- the oracle's own estimator;
      2. ``grad_fn.affine_arena``          -- power iteration on the H blocks;
      3. ``grad_fn.grad_arena``            -- HVP power iteration via
                                              ``jax.jvp`` of the arena grad;
      4. plain ``grad_fn``                 -- HVP power iteration via a
                                              vmapped pytree ``jax.jvp``.
    All four run as one jitted batched loop; the estimate is taken at the
    CURRENT ``params`` (exact for affine/quadratic oracles, a local probe
    for nonconvex ones).
    """
    if spec is None:
        from repro.core import arena

        spec = arena.ArenaSpec.from_tree(params)
    w = spec.width

    curv = getattr(grad_fn, "curvature_arena", None)
    if curv is not None:
        x0 = jnp.broadcast_to(spec.pack(params)[None], (m, w))
        L = jax.jit(curv(spec))(x0, batch)
        return np.asarray(L, np.float64)

    affine = getattr(grad_fn, "affine_arena", None)
    if affine is not None:
        def run(b):
            H, _ = affine(spec, b)
            return power_iter_arena(H, iters)

        return np.asarray(jax.jit(run)(batch), np.float64)

    ga_factory = getattr(grad_fn, "grad_arena", None)
    if ga_factory is not None:
        ga = ga_factory(spec)
        x0 = jnp.broadcast_to(spec.pack(params)[None], (m, w))

        def run(b):
            def hvp(v):
                return jax.jvp(lambda xa: ga(xa, b), (x0,), (v,))[1]

            return power_iter_hvp(hvp, m, w, iters)

        return np.asarray(jax.jit(run)(batch), np.float64)

    # plain pytree oracle: vmapped per-client jvp through grad_fn, with the
    # probe vector carried in arena coordinates so the batched power loop
    # stays a single fori_loop
    def run(b):
        def hvp(v):
            def one(bi, vi):
                tangent = spec.unpack(vi)
                return spec.pack(jax.jvp(
                    lambda p: grad_fn(p, bi), (params,), (tangent,))[1])

            return jax.vmap(one)(b, v)

        return power_iter_hvp(hvp, m, w, iters)

    return np.asarray(jax.jit(run)(batch), np.float64)


def derive_eta(L, safety: float = SAFETY):
    """``eta_i = safety / L_i`` (positive-clamped against degenerate zero
    curvature, where any stepsize is stable)."""
    L = np.maximum(np.asarray(L, np.float64), 1e-12)
    return safety / L


def resolve(cfg: FederatedConfig, grad_fn, params, m: int, batch, *,
            iters: int = POWER_ITERS, safety: float = SAFETY) -> FederatedConfig:
    """Host-side ``eta="auto"`` resolution: estimate per-client L_i, derive
    ``eta_i = safety / L_i``, and return the config with ``eta`` replaced by
    the hashable per-client tuple.  A no-op for scalar/tuple eta.  MUST run
    before the round is built -- the derived values are trace-static (the
    kernels take them as a per-client operand, but the config itself stays
    hashable), and ``core.make`` rejects an unresolved "auto" loudly."""
    if cfg.eta != "auto":
        return cfg
    L = estimate_L(grad_fn, params, m, batch, iters=iters)
    eta = derive_eta(L, safety)
    return dataclasses.replace(cfg, eta=tuple(float(e) for e in eta))


def client_eta(cfg: FederatedConfig, m: Optional[int] = None):
    """The round's eta in kernel-ready form: a Python float (the baked
    scalar path, bitwise the pre-autotune graphs) or an ``(m,) np.float32``
    array (per-client auto-eta, fed to the kernels as a stepsize operand).
    Raises on unresolved ``eta="auto"``."""
    if isinstance(cfg.eta, str):
        raise ValueError(
            "eta='auto' must be resolved host-side (core.autotune.resolve) "
            "before the round is built")
    if isinstance(cfg.eta, tuple):
        eta = np.asarray(cfg.eta, np.float32)
        if m is not None and eta.shape != (m,):
            raise ValueError(
                f"per-client eta has {eta.shape[0]} entries for {m} clients")
        return eta
    return float(cfg.eta)


def mean_eta(cfg: FederatedConfig) -> float:
    """The scalar eta the shared server-side quantities are derived from:
    the mean over clients under per-client auto-eta (see
    ``core.api.resolved_rho``), the plain value otherwise."""
    if isinstance(cfg.eta, str):
        raise ValueError(
            "eta='auto' must be resolved host-side (core.autotune.resolve) "
            "before the round is built")
    if isinstance(cfg.eta, tuple):
        return float(np.mean(np.asarray(cfg.eta, np.float64)))
    return float(cfg.eta)


def scale_eta(cfg: FederatedConfig, scale: float) -> FederatedConfig:
    """Uniformly rescale eta (the watchdog's rollback backoff): multiplies
    every per-client entry under the tuple form, the scalar otherwise."""
    if scale == 1.0:
        return cfg
    if isinstance(cfg.eta, tuple):
        return dataclasses.replace(
            cfg, eta=tuple(float(e) * scale for e in cfg.eta))
    return dataclasses.replace(cfg, eta=cfg.eta * scale)


# ---------------------------------------------------------------------------
# residual-based early termination
# ---------------------------------------------------------------------------

# State entries that converge at the PDMM fixed point (the monotone-operator
# stopping rule covers primal AND dual iterates): server/client primals,
# duals, control variates, and the integrated server view.  Matches the key
# sets of all round engines (see launch/steps.state_shardings); entries a
# given algorithm lacks are skipped, non-float leaves (round counters, rng
# keys, masks) never contribute.
RESIDUAL_KEYS = ("x_s", "x_c", "lam_s", "u_hat", "c_i", "c", "z_s", "x", "z")


def state_residual(prev, new):
    """Squared fixed-point residual of one round, as two scalar metrics:

        res_dx2 = sum over state buffers of ||s_new - s_prev||^2
        res_x2  = sum over state buffers of ||s_new||^2

    2-D ``(rows, width)`` buffers ride the fused ``ops.residual_norm``
    kernel (one pass over each arena instead of separate sub/square/sum
    chains); other float leaves take plain f32 jnp reductions.  The host
    combines the two into pfb-clean's relative criterion
    ``sqrt(res_dx2 / res_x2) < tol`` (``EarlyExit``)."""
    from repro.kernels import ops

    if not (isinstance(prev, dict) and isinstance(new, dict)):
        raise TypeError("state_residual expects dict round states")
    dx2 = jnp.float32(0.0)
    x2 = jnp.float32(0.0)
    for k in RESIDUAL_KEYS:
        if k not in new or k not in prev:
            continue
        for p, q in zip(jax.tree.leaves(prev[k]), jax.tree.leaves(new[k])):
            if not jnp.issubdtype(q.dtype, jnp.floating):
                continue
            if q.ndim == 2:
                d_rows, n_rows = ops.residual_norm(q, p)
                dx2 = dx2 + jnp.sum(d_rows)
                x2 = x2 + jnp.sum(n_rows)
            else:
                qf = q.astype(jnp.float32)
                d = qf - p.astype(jnp.float32)
                dx2 = dx2 + jnp.sum(d * d)
                x2 = x2 + jnp.sum(qf * qf)
    return {"res_dx2": dx2, "res_x2": x2}


class EarlyExit:
    """Host-side tracker for the relative-residual stopping rule.

    Feed it the stacked ``res_dx2``/``res_x2`` rows of each dispatched
    chunk; it returns the 0-based in-chunk index of the round AFTER which
    the run may stop -- the first round taking the count of CONSECUTIVE
    sub-``tol`` rounds to ``patience`` -- or None to keep going.  With
    ``tol=0`` it never fires (the driver compiles the fixed-budget graph
    and skips the metric entirely, so there is nothing to feed)."""

    def __init__(self, tol: float, patience: int = 1):
        self.tol = float(tol)
        self.patience = int(patience)
        self.hits = 0
        self.last_rel = float("inf")

    def update(self, dx2, x2) -> Optional[int]:
        if self.tol <= 0.0:
            return None
        dx2 = np.atleast_1d(np.asarray(dx2, np.float64))
        x2 = np.atleast_1d(np.asarray(x2, np.float64))
        for j in range(dx2.shape[0]):
            rel = math.sqrt(dx2[j] / max(x2[j], 1e-30))
            self.last_rel = rel
            if rel < self.tol:
                self.hits += 1
                if self.hits >= self.patience:
                    return j
            else:
                self.hits = 0
        return None
