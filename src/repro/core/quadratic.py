"""The paper's least-squares testbed (SSVI-A): f_i(x) = 1/2 ||A_i x - b_i||^2
with A_i ~ N(0,1)^{n x d}, b_i = A_i y0 + v_i, v_i ~ N(0, 0.25 I) -- plus an
optional ridge term reg/2 ||x||^2 per client (the gradient stays affine:
grad f_i(x) = (A_i^T A_i + reg I) x - A_i^T b_i).

Provides the gradient oracle (via precomputed A^T A, A^T b -- O(d^2) per
step), the closed-form prox oracle for exact PDMM/FedSplit (via a per-client
eigendecomposition, so prox is O(d^2) for any rho), the global optimum, and
the smoothness/strong-convexity constants (L, mu) the theory bounds need.

``oracle()`` returns the grad_fn annotated with the arena-native fast paths
(``core.api`` oracle protocol): ``grad_arena`` evaluates on the packed
``(m, width)`` buffer with zero boundary passes, and ``affine_arena``
exposes the (H, c) affine structure the fused K-step inner-loop kernel
(``kernels/inner_loop.py``) consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import make_oracle


@dataclasses.dataclass(frozen=True)
class LeastSquares:
    AtA: jax.Array  # (m, d, d)
    Atb: jax.Array  # (m, d)
    btb: jax.Array  # (m,)
    evals: jax.Array  # (m, d)  eigenvalues of AtA
    evecs: jax.Array  # (m, d, d)
    x_star: jax.Array  # (d,) global optimum
    f_star: jax.Array  # () optimal value of F = sum_i f_i
    L: float  # max_i lambda_max(AtA_i + reg I)
    mu: float  # min_i lambda_min(AtA_i + reg I)
    reg: float = 0.0  # per-client ridge weight (0 = the paper's least squares)

    @property
    def m(self) -> int:
        return self.AtA.shape[0]

    @property
    def d(self) -> int:
        return self.AtA.shape[1]

    # -- oracles -----------------------------------------------------------
    def grad(self, x, client_batch):
        """grad f_i(x) = (AtA_i + reg I) x - Atb_i; client_batch = {"AtA","Atb"}."""
        return client_batch["AtA"] @ x - client_batch["Atb"] + self.reg * x

    def batch(self):
        """Stacked client batch for the federated round API."""
        return {"AtA": self.AtA, "Atb": self.Atb}

    def oracle(self):
        """``grad`` annotated with the arena-native fast paths (api protocol).

        The parameter tree is a flat ``(d,)`` vector, so the arena row is
        ``[x | 0-pad]`` and both fast paths are exact on the padding: the
        affine H is zero outside the leading d x d block and c is
        zero-padded, so padded coordinates stay identically zero.
        """
        reg = self.reg

        def grad_arena(spec):
            (e,) = spec.leaves  # single flat leaf at offset 0
            d, w = e.size, spec.width

            def ga(xa, cb):
                x = xa[:, :d]
                g = jnp.einsum("mde,me->md", cb["AtA"], x) - cb["Atb"] + reg * x
                return jnp.pad(g, ((0, 0), (0, w - d))) if w != d else g

            return ga

        def affine_arena(spec, cb):
            (e,) = spec.leaves
            d, w = e.size, spec.width
            H = cb["AtA"] + reg * jnp.eye(d, dtype=cb["AtA"].dtype)
            c = cb["Atb"]
            if w != d:
                H = jnp.pad(H, ((0, 0), (0, w - d), (0, w - d)))
                c = jnp.pad(c, ((0, 0), (0, w - d)))
            return H, c

        def curvature_arena(spec):
            # per-client smoothness L_i = lambda_max(AtA_i + reg I) by
            # batched power iteration on the same H blocks the fused inner
            # loop consumes (exact here: the gradient is affine, so the
            # Hessian IS H; validated against eigvalsh in tests)
            def curv(xa, cb):
                from repro.core import autotune

                H, _ = affine_arena(spec, cb)
                return autotune.power_iter_arena(H)

            return curv

        return make_oracle(self.grad, grad_arena=grad_arena,
                           affine_arena=affine_arena,
                           curvature_arena=curvature_arena)

    def prox_fn(self, i_free=True):
        """Returns prox(v, rho) usable under vmap over the client dim.

        The closure carries the stacked eigendecompositions; under
        ``jax.vmap`` each client sees its own slice, so we expose a stacked
        variant: ``prox_stacked(v_stacked, rho)`` mapped in the caller.
        """

        reg = self.reg

        def prox_one(evals, evecs, Atb, v, rho):
            # argmin 1/2||Ax-b||^2 + reg/2||x||^2 + rho/2 ||x - v||^2
            # (AtA + reg I shares AtA's eigenvectors: evals shift by reg)
            rhs = Atb + rho * v
            return evecs @ ((evecs.T @ rhs) / (evals + reg + rho))

        return prox_one

    def make_client_prox(self):
        """prox_fn(v_i, rho) for core.pdmm / core.fedsplit / core.pdmm_graph:
        the client index is implicit in vmap position, so we close over
        stacked arrays and let vmap slice them via lexical closure trick (see
        usage in tests).  ``rho`` may be a scalar or a per-client ``(m,)``
        array -- graph-PDMM's prox weight is c * degree, which varies across
        nodes on irregular topologies.  ``idx`` (optional STATIC client
        indices) restricts the evaluation to those clients' data, with
        ``v_stacked``/``rho`` rows in the same order -- graph-PDMM's
        color-sequential schedule proxes only the firing subset instead of
        the full stacking."""
        ev, eV, Atb, reg = self.evals, self.evecs, self.Atb, self.reg

        def stacked_prox(v_stacked, rho, idx=None):
            e, V, B = (ev, eV, Atb) if idx is None else (ev[idx], eV[idx], Atb[idx])
            m = jax.tree.leaves(v_stacked)[0].shape[0]
            rho_b = jnp.broadcast_to(jnp.asarray(rho, jnp.float32), (m,))

            def one(evals, evecs, atb, v, r):
                rhs = atb + r * v
                return evecs @ ((evecs.T @ rhs) / (evals + reg + r))

            return jax.vmap(one)(e, V, B, v_stacked, rho_b)

        return stacked_prox

    # -- objective ---------------------------------------------------------
    def F(self, x):
        """Global objective sum_i f_i(x) (x: (d,))."""
        quad = jnp.einsum("d,mde,e->", x, self.AtA, x)
        lin = jnp.einsum("md,d->", self.Atb, x)
        ridge = 0.5 * self.reg * self.m * jnp.sum(jnp.square(x))
        return 0.5 * quad - lin + 0.5 * jnp.sum(self.btb) + ridge

    def gap(self, x):
        return self.F(x) - self.f_star

    def dist(self, x):
        """||x - x*||: unlike the f32 functional gap (F ~ 1e6, so F - F* is
        +-O(10) noise near the optimum), the iterate distance stays accurate
        through convergence -- use it for method-vs-method claims."""
        return jnp.linalg.norm(x - self.x_star)

    def lam_star(self):
        """Optimal duals: lam*_{i|s} = grad f_i(x*) (KKT (7))."""
        return (jnp.einsum("mde,e->md", self.AtA, self.x_star) - self.Atb
                + self.reg * self.x_star[None])

    # -- variants ----------------------------------------------------------
    def with_ridge(self, reg: float) -> "LeastSquares":
        """Same data, ridge-regularised objective; recomputes the optimum
        and the smoothness/strong-convexity constants for the new problem."""
        H = self.AtA.sum(0) + self.m * reg * jnp.eye(self.d)
        g = self.Atb.sum(0)
        x_star = jnp.linalg.solve(H, g)
        f_star = 0.5 * x_star @ H @ x_star - g @ x_star + 0.5 * self.btb.sum()
        return dataclasses.replace(
            self, reg=reg, x_star=x_star, f_star=f_star,
            L=float(self.evals[:, -1].max()) + reg,
            mu=float(self.evals[:, 0].min()) + reg,
        )


def generate(key, m: int, n: int, d: int, noise_std: float = 0.5) -> LeastSquares:
    k1, k2, k3 = jax.random.split(key, 3)
    A = jax.random.normal(k1, (m, n, d), dtype=jnp.float32)
    y0 = jax.random.normal(k2, (d,), dtype=jnp.float32)
    v = noise_std * jax.random.normal(k3, (m, n), dtype=jnp.float32)
    b = jnp.einsum("mnd,d->mn", A, y0) + v

    AtA = jnp.einsum("mnd,mne->mde", A, A)
    Atb = jnp.einsum("mnd,mn->md", A, b)
    btb = jnp.einsum("mn,mn->m", b, b)
    evals, evecs = jnp.linalg.eigh(AtA)

    H = AtA.sum(0)
    g = Atb.sum(0)
    x_star = jnp.linalg.solve(H, g)
    f_star = 0.5 * x_star @ H @ x_star - g @ x_star + 0.5 * btb.sum()

    return LeastSquares(
        AtA=AtA,
        Atb=Atb,
        btb=btb,
        evals=evals,
        evecs=evecs,
        x_star=x_star,
        f_star=f_star,
        L=float(evals[:, -1].max()),
        mu=float(evals[:, 0].min()),
    )
