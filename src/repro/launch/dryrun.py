import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input shape) this lowers + compiles the appropriate
step on the production mesh(es) with ShapeDtypeStruct inputs (no allocation),
prints ``memory_analysis()`` / ``cost_analysis()``, parses collective traffic
from the partitioned HLO, and writes one JSON report per combination under
``experiments/dryrun/``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # full matrix
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod      # 2x16x16
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_arch, get_shape
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.steps import build_step

REPORT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_one(arch_name: str, shape_name: str, *, multi_pod: bool, verbose: bool = True) -> dict:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    if not cfg.supports_shape(shape):
        return {
            "arch": arch_name, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skipped",
            "reason": "full-attention arch without SW variant; see DESIGN.md",
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    # monotonic clock: wall-clock time.time can step under NTP and skew
    # the lower/compile durations the reports record
    t0 = time.perf_counter()
    bundle = build_step(cfg, shape, mesh)
    with mesh_context(mesh):
        jitted = jax.jit(
            bundle.fn,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums,
        )
        lowered = jitted.lower(*bundle.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    flops, bytes_acc = hlo_stats.flops_and_bytes(compiled)
    mem = hlo_stats.memory_stats(compiled)
    coll = hlo_stats.collective_bytes(compiled.as_text())

    report = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "step": bundle.name,
        "meta": bundle.meta,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # NOTE: per-device numbers; lax.scan bodies are counted once by XLA's
        # cost analysis -- launch.roofline does the depth extrapolation.
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "memory": mem,
        "collectives": coll,
    }
    if verbose:
        gb = mem["peak_bytes_est"] / 2**30
        print(
            f"[dryrun] {arch_name:28s} {shape_name:12s} mesh={report['mesh']:8s} "
            f"{bundle.name:13s} mem/device~{gb:6.2f}GiB flops/dev={flops:.3e} "
            f"coll={coll['total']['count']:3d} ops {coll['total']['bytes']/2**20:9.1f}MiB "
            f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)"
        )
        print(f"         memory_analysis: {compiled.memory_analysis()}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true", help="run 16x16 AND 2x16x16")
    ap.add_argument("--out", default=str(REPORT_DIR))
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for multi_pod in meshes:
        for a in archs:
            for s in shapes:
                tag = f"{a}_{s}_{'2x16x16' if multi_pod else '16x16'}"
                try:
                    rep = run_one(a, s, multi_pod=multi_pod)
                    if rep["status"] == "ok":
                        n_ok += 1
                    else:
                        n_skip += 1
                        print(f"[dryrun] {a:28s} {s:12s} SKIP ({rep['reason']})")
                except Exception as e:  # a failure here is a sharding bug
                    n_fail += 1
                    rep = {
                        "arch": a, "shape": s,
                        "mesh": "2x16x16" if multi_pod else "16x16",
                        "status": "failed", "error": f"{type(e).__name__}: {e}",
                    }
                    print(f"[dryrun] {a:28s} {s:12s} FAIL: {e}")
                    traceback.print_exc(limit=3)
                (outdir / f"{tag}.json").write_text(json.dumps(rep, indent=2))
    print(f"\n[dryrun] ok={n_ok} skipped={n_skip} FAILED={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
