"""Host-resident population store: O(cohort) device memory for cohort rounds.

The cohort engine (``use_cohort``, PR 5) already made COMPUTE scale with the
sampled cohort, but every resident ``(m, width)`` client buffer -- GPDMM's
``lam_s``/``x_c``/``u_hat``, SCAFFOLD's ``c_i``, FedAvg's ``u_hat`` -- still
lived in device memory, and the round tail still paid O(m) device work (the
scattered-buffer mean and the dense dual refresh).  At m = 10^6 LM-width
clients that is hundreds of GB of HBM for state the round never touches:
a cohort round READS AND WRITES only the sampled rows (the u_hat-cache
silence contract -- a silent client's resident state is round-invariant).

This module keeps the population in HOST numpy and stages only the cohort:

  * ``Runner.round`` gathers the sampled rows out of the host store, ships
    them with ``jax.device_put``, runs the algorithm's jitted device body
    (``<algo>.popstore_body`` -- identical per-row math to the device-arena
    cohort round), and scatters the returned rows back into the store.
    Peak device footprint is O(cohort x width) + the server row.

  * The participation draw is PURE in (seed, round) (``participation_key``),
    so round r+1's cohort is known DURING round r: a 2-slot prefetch ring
    host-gathers the next cohort's rows while the device crunches the
    current one, reconciles any rows the current round just updated
    (``np.intersect1d`` on the two index sets), and pre-stages the
    ``device_put`` so the next round starts without a host-side stall.

  * The O(m) server reads become O(cohort): a running ``sum(u_hat)`` is
    maintained incrementally in float64 with Kahan compensation
    (``sum' = sum - sum(old cohort rows) + sum(new cohort rows)``), which
    tracks the dense f32 mean at f32 resolution at any population size; the
    dense dual refresh is LAZY -- lam_{s|i} = rho (u_hat_i - x_s) is an
    elementwise function of the stored uplink cache, so the body
    reconstructs exactly the staged rows it needs (``ops.dual_from_uplink``)
    and no (m, width) dual buffer exists anywhere.

State layout (a plain dict pytree, so checkpointing/watchdog/``--resume``
work unchanged; the big host buffers stream chunk-wise through
``checkpoint.msgpack_ckpt``):

    {"x_s": pytree (device), "round": int,
     "pop": {name: np.ndarray (m, width)}, "pop_sum": np.float64 (width,),
     "pop_sum_comp": np.float64 (width,) [, "c": pytree (scaffold)]}

``Runner.round`` mutates the ``pop`` arrays IN PLACE (the scatter) and
returns a new dict sharing them -- callers must not hold the old state as a
snapshot (checkpoints serialise at save time, so the watchdog contract is
unaffected).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig
from repro.core import agpdmm, arena, fedavg, gpdmm, scaffold
from repro.core import tree_util as T
from repro.core.api import resolved_rho, use_cohort
from repro.core.gpdmm import participation_key
from repro.telemetry import spans as _spans

_BODY_FACTORY = {
    "gpdmm": gpdmm.popstore_body,
    "agpdmm": agpdmm.popstore_body,
    "scaffold": scaffold.popstore_body,
    "fedavg": fedavg.popstore_body,
}

# Which resident (m, width) buffers each algorithm keeps in the host store,
# and which of them the server mean reads (None: the algorithm's server
# update is already O(cohort) on device -- SCAFFOLD -- and only a diagnostic
# reads the population sum).
POP_BUFFERS = {
    "gpdmm": ("u_hat", "x_c"),
    "agpdmm": ("u_hat",),
    "scaffold": ("c_i",),
    "fedavg": ("u_hat",),
}
MEAN_BUFFER = {"gpdmm": "u_hat", "agpdmm": "u_hat", "fedavg": "u_hat",
               "scaffold": None}

# Rows per chunk when (re)computing a full f64 column sum over a host
# buffer: bounds the transient f64 copy to chunk x width.
_SUM_CHUNK_ROWS = 4096


def supported(cfg: FederatedConfig) -> bool:
    return cfg.algorithm in POP_BUFFERS


def _col_sum64(buf: np.ndarray) -> np.ndarray:
    """Chunked float64 column sum: O(chunk x width) transient memory."""
    out = np.zeros(buf.shape[1], np.float64)
    for i in range(0, buf.shape[0], _SUM_CHUNK_ROWS):
        out += buf[i:i + _SUM_CHUNK_ROWS].astype(np.float64).sum(axis=0)
    return out


class _Staged:
    """One prefetch-ring slot: a round's cohort indices + staged rows."""
    __slots__ = ("round", "idx_np", "idx_dev", "host_rows", "dev_rows",
                 "store_ids")

    def __init__(self, round_idx, idx_np, idx_dev, host_rows, store_ids):
        self.round = round_idx
        self.idx_np = idx_np
        self.idx_dev = idx_dev
        self.host_rows = host_rows
        self.dev_rows = None
        self.store_ids = store_ids


class Runner:
    """Host-side driver for popstore rounds.  Mirrors the ``FedOpt``
    surface (``init`` / ``round`` / ``server_params``) but ``round`` is a
    HOST function -- it must NOT be wrapped in an outer ``jax.jit`` (the
    launchers dispatch on ``use_popstore`` and skip the jit)."""

    def __init__(self, cfg: FederatedConfig, grad_fn, *, per_step=False):
        if not supported(cfg):
            raise ValueError(
                f"popstore supports algorithms {sorted(POP_BUFFERS)}, "
                f"got {cfg.algorithm!r}")
        if cfg.algorithm == "scaffold" and cfg.uplink_bits is not None:
            scaffold.make(cfg)  # raises the canonical SCAFFOLD+EF21 error
        self.cfg = cfg
        self.grad_fn = grad_fn
        self.per_step = per_step
        self.algo = cfg.algorithm
        self.buffers = POP_BUFFERS[self.algo]
        self.mean_buffer = MEAN_BUFFER[self.algo]
        self._spec = None
        self._m = None
        self._body = None
        self._idx_fn = None
        self._next: Optional[_Staged] = None
        # prefetch-ring accounting, emitted as trace counter events when the
        # global tracer is on (docs/telemetry.md) -- a miss means the round
        # paid the host gather on the critical path
        self.ring_hits = 0
        self.ring_misses = 0

    # -- build ------------------------------------------------------------

    def _build(self, x_s, m: int):
        if self._body is not None and self._m == m:
            return
        cfg = self.cfg
        if not use_cohort(cfg, m):
            raise ValueError(
                "popstore rides the cohort engine: use_cohort(cfg, m) must "
                f"hold (participation={cfg.participation}, cohort="
                f"{cfg.cohort!r}, algorithm={cfg.algorithm!r}, m={m})")
        self._spec = arena.ArenaSpec.from_tree(x_s)
        self._m = m
        body = _BODY_FACTORY[self.algo](cfg, self._spec, m, self.grad_fn,
                                        self.per_step)
        # staged cohort rows are per-round temporaries: donate them so the
        # device-side footprint stays one cohort buffer per name, not two
        self._body = jax.jit(body, donate_argnums=(1,))
        self._idx_fn = jax.jit(
            lambda r: T.cohort_indices(participation_key(cfg, r), m,
                                       cfg.participation)[0])

    # -- staging / prefetch ring ------------------------------------------

    def _stage_host(self, round_idx: int, store) -> _Staged:
        idx_dev = self._idx_fn(jnp.int32(round_idx))
        idx_np = np.asarray(idx_dev)
        host_rows = {name: store[name][idx_np] for name in self.buffers}
        return _Staged(round_idx, idx_np, idx_dev, host_rows,
                       tuple(id(store[n]) for n in self.buffers))

    def _take_prefetch(self, round_idx: int, store) -> Optional[_Staged]:
        nxt, self._next = self._next, None
        if (nxt is not None and nxt.round == round_idx
                and nxt.store_ids == tuple(id(store[n])
                                           for n in self.buffers)):
            return nxt
        return None  # rollback / resume / fresh state: restage from scratch

    # -- state ------------------------------------------------------------

    def init(self, params, m: int):
        self._build(params, m)
        spec = self._spec
        row = np.asarray(spec.pack(params))
        pop = {}
        for name in self.buffers:
            buf = np.empty((m, spec.width), row.dtype)
            if name == "c_i":
                buf[:] = 0  # SCAFFOLD control variates start at zero
            else:
                buf[:] = row  # u_hat / x_c: round-0 broadcast of the server row
            pop[name] = buf
        sum_name = self.mean_buffer or self.buffers[0]
        if sum_name == "c_i":
            pop_sum = np.zeros(spec.width, np.float64)
        else:
            # m identical rows: m * row is the correctly rounded f64 sum
            pop_sum = row.astype(np.float64) * m
        state = {
            "x_s": params,
            "round": 0,
            "pop": pop,
            "pop_sum": pop_sum,
            "pop_sum_comp": np.zeros(spec.width, np.float64),
        }
        if self.algo == "scaffold":
            state["c"] = T.tree_zeros_like(params)
        self._next = None
        return state

    def _normalize(self, state):
        """Post-``--resume`` repair: checkpoint round-trips can hand back
        read-only numpy (frombuffer views), device arrays (small buffers
        below the streaming threshold load via ``jnp.asarray``), or f32
        sums (jnp would silently downcast f64 with x64 disabled).  The
        store must be writable host numpy and the running sum exact f64."""
        store = state["pop"]
        changed = False
        for name, buf in store.items():
            b = np.asarray(buf)
            if not isinstance(buf, np.ndarray) or not b.flags.writeable:
                b = np.array(b)
                changed = True
            store[name] = b
        s = np.asarray(state["pop_sum"])
        comp = np.asarray(state["pop_sum_comp"])
        sum_name = self.mean_buffer or self.buffers[0]
        if s.dtype != np.float64 or comp.dtype != np.float64:
            s = _col_sum64(store[sum_name])
            comp = np.zeros_like(s)
            changed = True
        state["pop_sum"], state["pop_sum_comp"] = s, comp
        if changed:
            self._next = None  # any prefetch staged off the old arrays is dead
        return state

    # -- the round ---------------------------------------------------------

    def round(self, state, batch):
        self._build(state["x_s"], next(iter(state["pop"].values())).shape[0])
        state = self._normalize(state)
        cfg, spec, m = self.cfg, self._spec, self._m
        r = int(state["round"])
        store = state["pop"]

        # telemetry (docs/telemetry.md): every phase below is a span on the
        # global tracer; all of it is the shared no-op singleton when
        # tracing is off, so the telemetry-off round does no added host work
        tr = _spans.get_tracer()
        staged = self._take_prefetch(r, store)
        if staged is None:
            # ring miss: the host gather lands on the critical path
            self.ring_misses += 1
            with tr.span("popstore/host_gather", {"round": r}):
                staged = self._stage_host(r, store)
        else:
            self.ring_hits += 1
        if tr.enabled:
            tr.counter("popstore/ring",
                       {"hit": self.ring_hits, "miss": self.ring_misses})
        if staged.dev_rows is None:
            with tr.span("popstore/h2d_stage", {"round": r}):
                staged.dev_rows = {k: jax.device_put(v)
                                   for k, v in staged.host_rows.items()}
        server = {"x_s": state["x_s"]}
        if self.algo == "scaffold":
            server["c"] = state["c"]
        # async dispatch: the device crunches while the host prefetches
        with tr.span("popstore/device_round", {"round": r}):
            rows_out, server_rows, dev_metrics = self._body(
                server, staged.dev_rows, staged.idx_dev, jnp.int32(r), batch)

        # prefetch ring: round r+1's cohort is already determined, so gather
        # its rows NOW, overlapping the device compute above.  Rows round r
        # is about to update are reconciled below, after the scatter.
        with tr.span("popstore/prefetch_gather", {"round": r + 1}):
            nxt = self._stage_host(r + 1, store)

        with tr.span("popstore/device_sync", {"round": r}):
            new_rows = {k: np.asarray(v) for k, v in rows_out.items()}  # sync
        idx_np = staged.idx_np

        with tr.span("popstore/scatter_back", {"round": r}):
            # incremental server sum BEFORE the scatter (needs the old rows)
            sum_name = self.mean_buffer or self.buffers[0]
            delta = (new_rows[sum_name].astype(np.float64).sum(axis=0)
                     - store[sum_name][idx_np].astype(np.float64).sum(axis=0))
            # Kahan-compensated accumulation: the per-round delta is tiny next
            # to the population sum at large m, exactly where naive f64 += leaks
            y = delta - state["pop_sum_comp"]
            t = state["pop_sum"] + y
            comp_new = (t - state["pop_sum"]) - y
            sum_new = t

            for name in self.buffers:
                store[name][idx_np] = new_rows[name]

            # reconcile the prefetched slot with the rows just scattered
            common, pos_next, _ = np.intersect1d(nxt.idx_np, idx_np,
                                                 return_indices=True)
            if common.size:
                for name, buf in nxt.host_rows.items():
                    buf[pos_next] = store[name][common]
        with tr.span("popstore/h2d_stage", {"round": r + 1, "prefetch": True}):
            nxt.dev_rows = {k: jax.device_put(v)
                            for k, v in nxt.host_rows.items()}
        self._next = nxt

        new_state = {
            "round": r + 1,
            "pop": store,
            "pop_sum": sum_new,
            "pop_sum_comp": comp_new,
        }
        host_metrics = {"used_popstore": np.float32(1.0)}
        if self.algo == "scaffold":
            new_state["x_s"] = spec.unpack(server_rows["x_s"])
            new_state["c"] = spec.unpack(server_rows["c"])
            c_row64 = np.asarray(server_rows["c"]).astype(np.float64)
            host_metrics["c_sum_norm"] = np.float32(
                np.linalg.norm(sum_new - m * c_row64))
        else:
            # the round's single "all-reduce": the incrementally maintained
            # population sum, read at f32 resolution
            x_row = jnp.asarray((sum_new / m).astype(np.float32))
            new_state["x_s"] = spec.unpack(x_row)
            if self.algo in ("gpdmm", "agpdmm"):
                rho = resolved_rho(cfg)
                # KKT invariant (25) off the lazy dual: sum_i lam_{s|i}
                # = rho (sum_i u_hat_i - m x_s)
                host_metrics["lam_sum_norm"] = np.float32(np.linalg.norm(
                    rho * (sum_new
                           - m * np.asarray(x_row).astype(np.float64))))
        return new_state, dict(dev_metrics) | host_metrics

    def server_params(self, state):
        return state["x_s"]


def device_bytes(cfg: FederatedConfig, width: int, m: int) -> int:
    """Staged-state device footprint bound for one popstore round: the
    2-slot ring of cohort rows per resident buffer, plus the body's own
    cohort-sized temporaries are accounted by callers.  Benchmarks report
    this next to the O(m x width) arena-resident footprint it replaces."""
    mc = T.cohort_count(m, cfg.participation)
    n_buf = len(POP_BUFFERS[cfg.algorithm])
    return 2 * n_buf * mc * width * 4
