"""Batched serving launcher: prefill a batch of prompts, then decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 4 --prompt-len 64 --new-tokens 16

Train-while-serve (ISSUE 7): ``--ckpt-dir <dir> --watch`` turns the launcher
into a hot-swap server.  A ``HotSwapWatcher`` polls the trainer's keep-N
checkpoint anchors between query batches, loads new steps with
retry/exponential-backoff (``load_with_retry``), REJECTS truncated or
corrupt files loudly (the step is remembered as bad and never retried), and
keeps serving the last-good parameters when the newest anchor is unreadable
-- the server degrades, it never crashes or serves garbage.  The model is
built and the prefill/decode functions jitted ONCE; a swap only repoints the
parameter pytree, so steady-state query latency is unchanged.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --ckpt-dir /tmp/fedckpt --watch --duration 20

Telemetry (ISSUE 9): ``--trace-out trace.json`` records poll / swap /
prefill / decode spans per query batch (Perfetto-loadable);
``--metrics-out metrics.jsonl`` streams per-query rows and the end-of-run
summary; ``--prom-out serve.prom`` writes the final counters in the
Prometheus textfile-collector format.  All timing below uses the monotonic
``time.perf_counter`` -- wall-clock ``time.time`` can step under NTP and
produce negative latencies; the only wall-clock stamp kept is the history
rows' ``"t"`` field, which is a timestamp, not a duration.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import telemetry as tel
from repro.configs import get_arch
from repro.models import build as build_model


def load_with_retry(ckpt_dir: str, step: int, *, retries: int = 3,
                    backoff: float = 0.05, factor: float = 2.0):
    """``checkpoint.load`` with exponential backoff.  Saves are atomic
    (tmp+fsync+rename), so a transient failure here is a filesystem race --
    e.g. the trainer's keep-N pruning unlinking the step between listing and
    reading -- not a half-written file; a PERSISTENT failure is a genuinely
    truncated/corrupt file and propagates to the caller after ``retries``
    attempts."""
    delay = backoff
    for attempt in range(retries):
        try:
            return ckpt.load(ckpt_dir, step)
        except (FileNotFoundError, ValueError, OSError):
            if attempt == retries - 1:
                raise
            time.sleep(delay)
            delay *= factor
    raise AssertionError("unreachable")


class HotSwapWatcher:
    """Tracks the newest LOADABLE checkpoint under ``ckpt_dir``.

    ``poll()`` walks the on-disk steps newest-first (``checkpoint.steps``,
    not ``latest_step``: a bad file at the newest step must not pin the
    watcher forever), skips steps already rejected, and returns the payload
    of the first new step that loads -- or ``None`` when there is nothing
    newer than the step currently served.  A step whose load still fails
    after the retry/backoff schedule is rejected LOUDLY and remembered in
    ``self.bad``; the caller keeps serving the last-good parameters."""

    def __init__(self, ckpt_dir: str, *, retries: int = 3,
                 backoff: float = 0.05, factor: float = 2.0):
        self.ckpt_dir = ckpt_dir
        self.retries, self.backoff, self.factor = retries, backoff, factor
        self.step: int | None = None  # currently served step
        self.payload = None
        self.bad: set[int] = set()
        self.swaps = 0
        self.failures = 0

    def poll(self):
        cur = -1 if self.step is None else self.step
        for step in sorted(ckpt.steps(self.ckpt_dir), reverse=True):
            if step <= cur:
                break  # nothing newer than what we serve
            if step in self.bad:
                continue  # already rejected; try the next-newest
            try:
                payload = load_with_retry(
                    self.ckpt_dir, step, retries=self.retries,
                    backoff=self.backoff, factor=self.factor)
            except (FileNotFoundError, ValueError, OSError) as e:
                self.bad.add(step)
                self.failures += 1
                print(f"[serve] REJECTED checkpoint step {step}: {e}",
                      flush=True)
                continue
            self.step = step
            self.payload = payload
            self.swaps += 1
            return payload
        return None


def _tel_setup(telemetry: bool, trace_out, metrics_out):
    """Shared launcher telemetry setup: returns (tel_on, tracer, registry,
    sink, was_tracing).  The tracer is the process-global one so library
    code (model, checkpoint) emits into the same trace."""
    tel_on = telemetry or bool(trace_out) or bool(metrics_out)
    tracer = tel.get_tracer()
    was_tracing = tracer.enabled
    if trace_out:
        tracer.configure(enabled=True, trace_out=trace_out)
    registry = tel.Registry() if tel_on else None
    sink = tel.JsonlSink(metrics_out) if metrics_out else None
    return tel_on, tracer, registry, sink, was_tracing


def _tel_teardown(tracer, sink, trace_out, was_tracing):
    if sink is not None:
        sink.close()
    if trace_out:
        path = tracer.close()
        if path:
            print(f"[telemetry] trace written to {path} "
                  f"(load in https://ui.perfetto.dev)", flush=True)
        tracer.configure(enabled=was_tracing)


def run(arch: str, *, reduced: bool = True, batch: int = 4, prompt_len: int = 64,
        new_tokens: int = 16, seed: int = 0, greedy: bool = True,
        telemetry: bool = False, trace_out: str | None = None,
        metrics_out: str | None = None, prom_out: str | None = None):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.key(seed)
    params = model.init(key)

    if cfg.n_codebooks > 1:
        prompts = jax.random.randint(key, (batch, cfg.n_codebooks, prompt_len), 0, cfg.vocab_size)
    else:
        prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    b = {"tokens": prompts}
    if cfg.frontend == "vision":
        b["patches"] = jax.random.normal(jax.random.fold_in(key, 1),
                                         (batch, cfg.n_prefix_tokens, cfg.frontend_dim))

    prefill = jax.jit(lambda p, bb: model.prefill(p, bb, prompt_len + new_tokens + cfg.n_prefix_tokens))
    decode = jax.jit(model.decode)

    tel_on, tracer, registry, sink, was_tracing = _tel_setup(
        telemetry, trace_out, metrics_out)

    t0 = time.perf_counter()
    with tracer.span("serve/prefill", {"batch": batch, "prompt": prompt_len}):
        logits, cache = prefill(params, b)
        logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    def pick(lg):
        if cfg.n_codebooks > 1:
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)  # (B, K)
            return nxt[:, :, None]
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]

    out_tokens = []
    t0 = time.perf_counter()
    with tracer.span("serve/decode", {"new_tokens": new_tokens}):
        for _ in range(new_tokens):
            nxt = pick(logits)
            logits, cache = decode(params, cache, nxt)
            out_tokens.append(nxt)
        jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=-1)
    n_tok = int(gen.size)
    print(f"[serve] arch={arch} batch={batch} prompt={prompt_len} new={new_tokens}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode {t_decode/new_tokens*1e3:.2f} ms/token")
    print(f"[serve] sample generated ids: {jax.device_get(gen)[0][..., :8]}")
    if tel_on:
        registry.counter("serve/tokens").inc(n_tok)
        registry.histogram("serve/prefill_s").observe(t_prefill)
        registry.histogram("serve/decode_s").observe(t_decode)
        registry.gauge("serve/tokens_per_s").set(
            n_tok / t_decode if t_decode > 0 else 0.0)
        if sink is not None:
            sink.write({"kind": "summary", **registry.summary_row()})
        if prom_out:
            print(f"[telemetry] prometheus textfile -> "
                  f"{tel.write_prometheus(registry, prom_out)}", flush=True)
    _tel_teardown(tracer, sink, trace_out, was_tracing)
    return gen


def run_watch(arch: str, *, ckpt_dir: str, reduced: bool = True,
              batch: int = 2, prompt_len: int = 16, new_tokens: int = 4,
              seed: int = 0, poll_interval: float = 0.25,
              duration: float = 30.0, wait_first: float = 60.0,
              stop_when=None, retries: int = 3, backoff: float = 0.05,
              history: list | None = None,
              telemetry: bool = False, trace_out: str | None = None,
              metrics_out: str | None = None, prom_out: str | None = None):
    """Serve queries continuously while a trainer writes checkpoints.

    Blocks until the FIRST loadable checkpoint appears (``wait_first``
    seconds, then ``TimeoutError``), then alternates poll -> swap-if-newer ->
    serve one greedy query batch until ``duration`` elapses or ``stop_when``
    (an optional zero-arg callable, e.g. "the trainer exited and we served
    its final step") returns True.  Returns the per-query history rows
    ``{"t", "step", "round", "tokens"}`` plus the watcher (swap/failure
    counters) for callers that assert on the trajectory; pass ``history``
    (a caller-owned list, appended in place) to watch progress from another
    thread while the loop runs."""
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.key(seed)

    tel_on, tracer, registry, sink, was_tracing = _tel_setup(
        telemetry, trace_out, metrics_out)
    # swap/rejection counters are kept even with telemetry off -- the
    # end-of-run structured summary always prints them
    registry = registry or tel.Registry()

    watcher = HotSwapWatcher(ckpt_dir, retries=retries, backoff=backoff)
    t_first = time.perf_counter()
    payload = watcher.poll()
    while payload is None:
        if time.perf_counter() - t_first > wait_first:
            raise TimeoutError(
                f"no loadable checkpoint appeared under {ckpt_dir} within "
                f"{wait_first:.0f}s")
        time.sleep(poll_interval)
        payload = watcher.poll()
    params = payload["server"]
    print(f"[serve] serving step {watcher.step} "
          f"(round {int(payload['round'])}) from {ckpt_dir}", flush=True)

    if cfg.n_codebooks > 1:
        prompts = jax.random.randint(
            key, (batch, cfg.n_codebooks, prompt_len), 0, cfg.vocab_size)
    else:
        prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    b = {"tokens": prompts}
    if cfg.frontend == "vision":
        b["patches"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (batch, cfg.n_prefix_tokens, cfg.frontend_dim))

    # jit ONCE; hot swaps only repoint the parameter pytree
    prefill = jax.jit(lambda p, bb: model.prefill(
        p, bb, prompt_len + new_tokens + cfg.n_prefix_tokens))
    decode = jax.jit(model.decode)

    def pick(lg):
        if cfg.n_codebooks > 1:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, :, None]
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]

    def query(p):
        with tracer.span("serve/prefill", {"step": watcher.step}):
            logits, cache = prefill(p, b)
            if tracer.enabled:  # sync only when traced: keeps the span honest
                jax.block_until_ready(logits)
        n = 0
        with tracer.span("serve/decode", {"new_tokens": new_tokens}):
            for _ in range(new_tokens):
                nxt = pick(logits)
                logits, cache = decode(p, cache, nxt)
                n += int(nxt.size)
            jax.block_until_ready(logits)
        return n

    history = [] if history is None else history
    t_end = time.perf_counter() + duration
    while True:
        t_poll = time.perf_counter()
        with tracer.span("serve/poll"):
            fresh = watcher.poll()
        if fresh is not None:
            swap_s = time.perf_counter() - t_poll
            payload, params = fresh, fresh["server"]
            registry.histogram("serve/swap_latency_s").observe(swap_s)
            tracer.instant("serve/swap", {"step": watcher.step,
                                          "round": int(payload["round"]),
                                          "latency_s": swap_s})
            print(f"[serve] hot-swapped to step {watcher.step} "
                  f"(round {int(payload['round'])})", flush=True)
        t_q = time.perf_counter()
        n_tok = query(params)
        q_s = time.perf_counter() - t_q
        registry.counter("serve/tokens").inc(n_tok)
        registry.histogram("serve/query_s").observe(q_s)
        row = {"t": time.time(), "step": watcher.step,
               "round": int(payload["round"]), "tokens": n_tok}
        history.append(row)
        if sink is not None:
            sink.write({"kind": "query", "query_s": q_s, **row})
        tracer.flush()
        if stop_when is not None and stop_when():
            break
        if time.perf_counter() >= t_end:
            break
        time.sleep(poll_interval)
    served = sorted({row["step"] for row in history})
    registry.counter("serve/swaps").inc(watcher.swaps)
    registry.counter("serve/rejections").inc(watcher.failures)
    q_hist = registry.histogram("serve/query_s")
    swap_hist = registry.histogram("serve/swap_latency_s")
    tok_total = registry.counter("serve/tokens").value
    tokens_per_s = tok_total / q_hist.total if q_hist.total > 0 else 0.0
    registry.gauge("serve/tokens_per_s").set(tokens_per_s)
    print(f"[serve] {len(history)} query batches; served steps {served}; "
          f"swaps={watcher.swaps} rejected={watcher.failures}", flush=True)
    mean_swap = ("n/a" if swap_hist.count == 0
                 else f"{swap_hist.mean * 1e3:.1f} ms")
    print(f"[serve] summary: tokens={int(tok_total)} "
          f"tokens_per_s={tokens_per_s:.1f} "
          f"mean_query={q_hist.mean * 1e3:.1f} ms "
          f"mean_swap_latency={mean_swap}", flush=True)
    if sink is not None:
        sink.write({"kind": "summary", **registry.summary_row()})
    if prom_out:
        print(f"[telemetry] prometheus textfile -> "
              f"{tel.write_prometheus(registry, prom_out)}", flush=True)
    _tel_teardown(tracer, sink, trace_out, was_tracing)
    return history, watcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    # --reduced defaults on; --full is the ONLY way to reach full-size
    # serving (a store_true flag that already defaults True is a no-op)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None,
                    help="with --watch: hot-swap serve the trainer's anchors")
    ap.add_argument("--watch", action="store_true",
                    help="train-while-serve: poll --ckpt-dir for new "
                         "checkpoints between query batches")
    ap.add_argument("--poll-interval", type=float, default=0.25)
    ap.add_argument("--duration", type=float, default=30.0,
                    help="watch mode: serve for this many seconds")
    ap.add_argument("--wait-first", type=float, default=60.0,
                    help="watch mode: seconds to wait for the first anchor")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable the metrics registry even without sinks")
    ap.add_argument("--trace-out", default=None,
                    help="write poll/swap/prefill/decode spans as Chrome "
                         "trace-event JSON (Perfetto-loadable)")
    ap.add_argument("--metrics-out", default=None,
                    help="stream per-query rows + summary as JSONL")
    ap.add_argument("--prom-out", default=None,
                    help="write final counters as a Prometheus textfile")
    args = ap.parse_args()
    tel_kw = dict(telemetry=args.telemetry, trace_out=args.trace_out,
                  metrics_out=args.metrics_out, prom_out=args.prom_out)
    if args.watch:
        if not args.ckpt_dir:
            raise SystemExit("--watch needs --ckpt-dir")
        run_watch(args.arch, ckpt_dir=args.ckpt_dir, reduced=args.reduced,
                  batch=args.batch, prompt_len=args.prompt_len,
                  new_tokens=args.new_tokens,
                  poll_interval=args.poll_interval, duration=args.duration,
                  wait_first=args.wait_first, **tel_kw)
    else:
        run(args.arch, reduced=args.reduced, batch=args.batch,
            prompt_len=args.prompt_len, new_tokens=args.new_tokens, **tel_kw)


if __name__ == "__main__":
    main()
