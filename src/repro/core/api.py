"""Unified federated-optimiser interface (the paper's technique as a
first-class, model-agnostic JAX module).

Every algorithm is a pair of pure functions:

    init(params, m)                  -> state          (pytree)
    round(state, grad_fn, batch)     -> (state, metrics)

with the conventions:
  * ``params`` is any pytree (a scalar vector for the paper's experiments or a
    full transformer parameter tree);
  * per-client entries in ``state`` are stacked with a leading client dim m;
  * ``grad_fn(params_i, batch_i) -> grad`` is the per-client gradient oracle;
    ``round`` vmaps it over the client dim, so the same code runs the paper's
    least-squares problems and sharded LM training;
  * ``batch`` leaves have leading dim m, or (K, m, ...) when
    ``per_step_batches=True`` (one minibatch per inner gradient step, the
    paper's softmax-regression setup).

The exact (prox-based) PDMM / FedSplit variants instead take a
``prox_fn(v, rho) -> argmin_x f_i(x) + rho/2 ||x - v||^2`` oracle (vmapped the
same way); they live in ``core.pdmm`` / ``core.fedsplit``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig


class FedOpt(NamedTuple):
    name: str
    init: Callable  # (params, m) -> state
    round: Callable  # (state, grad_fn, batch, per_step_batches=False) -> (state, metrics)
    server_params: Callable  # (state) -> params  (current global estimate)


def resolved_rho(cfg: FederatedConfig) -> float:
    """The paper's default rho = 1/(K * eta) (matched to SCAFFOLD's scaling)."""
    return cfg.rho if cfg.rho is not None else 1.0 / (cfg.inner_steps * cfg.eta)


def client_batches(batch, k: int, per_step: bool):
    """Yields the batch for inner step k (shared or per-step)."""
    if not per_step:
        return batch
    return jax.tree.map(lambda x: x[k], batch)


def make(cfg: FederatedConfig) -> FedOpt:
    from repro.core import agpdmm, fedavg, fedsplit, gpdmm, scaffold

    algos = {
        "gpdmm": gpdmm.make,
        "agpdmm": agpdmm.make,
        "scaffold": scaffold.make,
        "fedavg": fedavg.make,
        "fedsplit": fedsplit.make_inexact,
    }
    if cfg.algorithm not in algos:
        raise KeyError(f"unknown federated algorithm {cfg.algorithm!r}")
    return algos[cfg.algorithm](cfg)
