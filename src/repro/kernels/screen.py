"""Pallas TPU kernel for fused uplink screening (the robustness layer).

ONE pass over the ``(m, width)`` uplink arena emits, per client row,

  * a finite flag -- every entry of the row is finite, and
  * the squared deviation ``sum over the FINITE entries of (u_i - ref)^2``

so the server can demote non-finite or norm-outlier uplinks to silent
without a second read of the buffer (``core.faults.screen_keep``).  The
deviation is taken against the downlink reference rather than as a plain
norm: a sign-flipped uplink is norm-invariant, but its deviation from x_s
is ~ ``||2 x_s||``.  Non-finite entries are excluded from the deviation
(the flag already demotes those rows), so ``sq`` is always finite and
comparable across backends.

Layout: grid ``(m, rows_p // block)`` with the width blocks INNERMOST, so
each client's two per-lane accumulator rows -- ``(1, LANES)`` f32 blocks of
the tiny ``(m, LANES)`` outputs -- are revisited across the row's width
blocks and stay VMEM-resident (the same revisited-output accumulation
contract as ``neighbor_reduce``).  The cheap cross-lane finish (sum / min
over LANES) runs on the ``(m, LANES)`` partials outside the kernel.

``ref`` is either the ``(width,)`` server downlink row (centralised rounds)
or an ``(m, width)`` per-row reference (graph rounds screen each node's
transmitted ``x_ref`` against that node's own previous carry).  Zero
padding -- the arena tail rows and the ``rows_p - rows`` tile pad, zero on
BOTH operands by the arena invariant -- contributes zero deviation and a
finite flag, so padded and unpadded widths screen identically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_update import LANES, assert_vmem_budget
from repro.kernels.round_tail import _resolve_block, _tile


def _screen_kernel(u_ref, r_ref, sq_ref, fin_ref, *, per_row: bool):
    j = pl.program_id(1)
    u = u_ref[0].astype(jnp.float32)  # (br, LANES)
    r = (r_ref[0] if per_row else r_ref[...]).astype(jnp.float32)
    fin_e = jnp.isfinite(u)
    d = jnp.where(fin_e, u - r, 0.0)
    sq = jnp.sum(d * d, axis=0)  # (LANES,) per-lane partial
    fin = jnp.min(jnp.where(fin_e, 1.0, 0.0), axis=0)

    @pl.when(j == 0)
    def _init():
        sq_ref[0] = sq
        fin_ref[0] = fin

    @pl.when(j != 0)
    def _acc():
        sq_ref[0] = sq_ref[0] + sq
        fin_ref[0] = jnp.minimum(fin_ref[0], fin)


def screen_uplink_pallas(u, ref, *, block=None, interpret: bool = False):
    """u: (m, width) uplink arena; ref: (width,) broadcast downlink row or
    (m, width) per-row reference.  Returns ``(finite (m,) bool, sq (m,) f32)``.
    """
    m, w = u.shape
    per_row = ref.ndim == 2
    pad = (-w) % LANES
    if pad:
        # zero on BOTH operands: zero deviation, finite flag -- identical
        # screen to the unpadded width (arena callers are always aligned)
        u = jnp.pad(u, ((0, 0), (0, pad)))
        ref = jnp.pad(ref, ((0, 0), (0, pad)) if per_row else ((0, pad),))
        w += pad
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(2, br)
    ut, _, rows_p = _tile(u, br)
    rt, _, _ = _tile(ref, br)
    client_bs = pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0))
    ref_bs = (client_bs if per_row
              else pl.BlockSpec((br, LANES), lambda i, j: (j, 0)))
    acc_bs = pl.BlockSpec((1, LANES), lambda i, j: (i, 0))
    sq, fin = pl.pallas_call(
        functools.partial(_screen_kernel, per_row=per_row),
        grid=(m, rows_p // br),  # width blocks innermost: accumulators stay hot
        in_specs=[client_bs, ref_bs],
        out_specs=(acc_bs, acc_bs),
        out_shape=(jax.ShapeDtypeStruct((m, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((m, LANES), jnp.float32)),
        interpret=interpret,
    )(ut, rt)
    return jnp.min(fin, axis=1) > 0.5, jnp.sum(sq, axis=1)
