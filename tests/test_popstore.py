"""ISSUE 8: the host-resident population store + the cohort-sizing /
logging / checkpoint bug sweep.

Conformance contract: with the popstore on, every resident ``(m, width)``
client buffer lives in HOST numpy and only the sampled cohort's rows stage
to device -- and the resulting round must equal the all-device cohort round
row for row at f32, on the same participation draw, for all four cohort
algorithms.  The store's two approximating moves are pinned separately:

  * the incrementally maintained compensated-f64 ``sum(u_hat)`` tracks the
    dense column sum (and therefore the dense server mean at f32
    resolution) over many rounds;
  * the lazy dual ``lam_i = rho (u_hat_i - x_s)`` reconstructed from staged
    rows equals the device path's resident ``lam_s`` buffer rows.

Plus: prefetch-ring parity (the overlapped gather + intersect1d
reconciliation is bitwise-identical to restaging from scratch), the
streaming checkpoint round-trip (chunked save -> load -> continue equals
the uninterrupted run), the train launcher's popstore wiring, and the bug
sweep -- ``cohort_count`` exact products, ``--log-every 0``, final-ckpt
retention, R=1/R>1 logged-round alignment, stray checkpoint files.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.checkpoint import msgpack_ckpt
from repro.configs.base import FederatedConfig
from repro.core import make, popstore, quadratic
from repro.core import tree_util as T
from repro.core.api import resolved_rho, use_popstore
from repro.core.gpdmm import participation_key
from repro.launch.train import run as train_run

M = 8


@pytest.fixture(scope="module", params=[24, 130], ids=["d24", "d130_odd"])
def prob(request):
    # d=24 -> width 128; d=130 -> width 256 with 126 zero-padded columns
    return quadratic.generate(jax.random.key(0), m=M, n=60, d=request.param)


def _cfg(prob, algo, *, participation=0.5, K=3, **kw):
    return FederatedConfig(
        algorithm=algo, inner_steps=K, eta=0.3 / prob.L, use_arena=True,
        participation=participation, cohort=True, **kw)


def _close(a, b, *, msg, atol=1e-5):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    scale = max(1.0, float(np.abs(a).max()))
    np.testing.assert_allclose(a / scale, b / scale, atol=atol, err_msg=msg)


# ---------------------------------------------------------------------------
# tentpole conformance: popstore round == device cohort round, same draw
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["plain", "ef21"])
@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm", "scaffold", "fedavg"])
def test_popstore_matches_device_cohort(prob, algo, variant):
    if variant == "ef21" and algo == "scaffold":
        pytest.skip("SCAFFOLD+EF21 rejected by core.scaffold (two-variable uplink)")
    kw = {"uplink_bits": 8} if variant == "ef21" else {}
    cfg = _cfg(prob, algo, **kw)
    x0 = jnp.zeros((prob.d,))

    opt = make(cfg)
    dev = opt.init(x0, prob.m)
    runner = popstore.Runner(cfg, prob.oracle())
    pop = runner.init(x0, prob.m)
    rho = resolved_rho(cfg)
    for r in range(4):
        dev, _ = opt.round(dev, prob.oracle(), prob.batch())
        pop, met = runner.round(pop, prob.batch())
        tag = f"{algo}/{variant} round {r}"
        _close(runner.server_params(pop), dev["x_s"], msg=f"{tag}: x_s")
        for name in popstore.POP_BUFFERS[algo]:
            # host store rows vs the device path's resident arena buffer
            _close(pop["pop"][name], dev[name], msg=f"{tag}: {name}")
        if algo == "gpdmm":
            # the lazy dual: no (m, width) lam buffer exists in the store,
            # yet rho (u_hat - x_s) reconstructs the device lam_s rows
            x_row = np.asarray(runner._spec.pack(runner.server_params(pop)))
            lam = rho * (pop["pop"]["u_hat"] - x_row[None])
            _close(lam, dev["lam_s"], msg=f"{tag}: lazy dual vs lam_s")
        assert float(met["used_popstore"]) == 1.0


def test_popstore_metrics_expose_kkt_invariant(prob):
    cfg = _cfg(prob, "gpdmm")
    runner = popstore.Runner(cfg, prob.oracle())
    s = runner.init(jnp.zeros((prob.d,)), prob.m)
    for _ in range(3):
        s, met = runner.round(s, prob.batch())
    # eq. (25): sum_i lam_{s|i} = rho (sum_i u_hat_i - m x_s); the host
    # metric computes it off the f64 running sum, so it must be finite and
    # match a dense recomputation
    dense = resolved_rho(cfg) * np.linalg.norm(
        popstore._col_sum64(s["pop"]["u_hat"])
        - prob.m * np.asarray(runner._spec.pack(s["x_s"]), np.float64))
    np.testing.assert_allclose(float(met["lam_sum_norm"]), dense, rtol=1e-5)


def test_popstore_requires_cohort_engine(prob):
    runner = popstore.Runner(FederatedConfig(algorithm="gpdmm",
                                             participation=1.0), prob.oracle())
    with pytest.raises(ValueError, match="cohort"):
        runner.init(jnp.zeros((prob.d,)), prob.m)
    with pytest.raises(ValueError, match="popstore supports"):
        popstore.Runner(FederatedConfig(algorithm="fedsplit"), prob.oracle())


def test_use_popstore_policy():
    on = FederatedConfig(participation=0.5, popstore=True)
    auto = FederatedConfig(participation=0.5, popstore="auto",
                           popstore_min_clients=100)
    off = FederatedConfig(participation=0.5, popstore=False)
    full = FederatedConfig(participation=1.0, popstore=True)
    assert use_popstore(on, 8)
    assert not use_popstore(auto, 8) and use_popstore(auto, 100)
    assert not use_popstore(off, 10 ** 6)
    assert not use_popstore(full, 10 ** 6)  # rides the cohort engine


# ---------------------------------------------------------------------------
# prefetch ring + incremental sum
# ---------------------------------------------------------------------------

def test_prefetch_ring_matches_restage(prob):
    """The overlapped next-round gather (+ intersect1d reconciliation of
    rows the current round just scattered) is a pure scheduling choice:
    bitwise-identical to throwing the prefetch away and restaging."""
    cfg = _cfg(prob, "gpdmm")
    ra = popstore.Runner(cfg, prob.oracle())
    rb = popstore.Runner(cfg, prob.oracle())
    sa = ra.init(jnp.zeros((prob.d,)), prob.m)
    sb = rb.init(jnp.zeros((prob.d,)), prob.m)
    for r in range(5):
        sa, _ = ra.round(sa, prob.batch())
        rb._next = None  # kill the ring: force a from-scratch restage
        sb, _ = rb.round(sb, prob.batch())
        for name in popstore.POP_BUFFERS["gpdmm"]:
            np.testing.assert_array_equal(
                sa["pop"][name], sb["pop"][name],
                err_msg=f"prefetch vs restage: {name} round {r}")
        np.testing.assert_array_equal(
            np.asarray(ra.server_params(sa)), np.asarray(rb.server_params(sb)),
            err_msg=f"prefetch vs restage: x_s round {r}")


def test_prefetch_overlaps_consecutive_cohorts(prob):
    """The reconciliation actually fires: consecutive draws at p=0.5 on
    m=8 overlap within a few rounds (seeded, so this is deterministic)."""
    cfg = _cfg(prob, "gpdmm")
    overlaps = 0
    for r in range(5):
        a, _ = T.cohort_indices(participation_key(cfg, jnp.int32(r)), M, 0.5)
        b, _ = T.cohort_indices(participation_key(cfg, jnp.int32(r + 1)), M, 0.5)
        overlaps += np.intersect1d(np.asarray(a), np.asarray(b)).size
    assert overlaps > 0


def test_incremental_sum_tracks_dense(prob):
    """The Kahan-compensated running sum equals a dense chunked f64 column
    sum of the store after many rounds -- the server mean never reads the
    (m, width) buffer."""
    cfg = _cfg(prob, "gpdmm")
    runner = popstore.Runner(cfg, prob.oracle())
    s = runner.init(jnp.zeros((prob.d,)), prob.m)
    for _ in range(8):
        s, _ = runner.round(s, prob.batch())
    dense = popstore._col_sum64(s["pop"]["u_hat"])
    scale = max(1.0, float(np.abs(dense).max()))
    np.testing.assert_allclose(s["pop_sum"] / scale, dense / scale,
                               atol=1e-10, err_msg="incremental vs dense sum")
    # and the published x_s is that sum read at f32 resolution
    x_row = np.asarray(runner._spec.pack(s["x_s"]), np.float64)
    np.testing.assert_allclose(
        x_row, (dense / prob.m).astype(np.float32).astype(np.float64),
        rtol=0, atol=0, err_msg="x_s vs dense mean at f32")


# ---------------------------------------------------------------------------
# streaming checkpoint round-trip
# ---------------------------------------------------------------------------

def test_popstore_checkpoint_streams_and_resumes(prob, tmp_path, monkeypatch):
    """Chunked save -> load -> continue equals the uninterrupted run.  A
    tiny CHUNK_BYTES forces the store's (m, width) buffers down the
    streaming path (skeleton + chunk bins) exactly as the real m=10^6
    store would; streamed buffers must come back as WRITABLE host numpy
    and the f64 running sums must survive without a silent f32 downcast."""
    monkeypatch.setattr(msgpack_ckpt, "CHUNK_BYTES", 1024)
    cfg = _cfg(prob, "gpdmm")
    runner = popstore.Runner(cfg, prob.oracle())
    s = runner.init(jnp.zeros((prob.d,)), prob.m)
    for _ in range(2):
        s, _ = runner.round(s, prob.batch())
    ckpt.save(tmp_path, 2, s)
    back = ckpt.load(tmp_path, 2)

    for name, buf in back["pop"].items():
        assert isinstance(buf, np.ndarray), f"{name} must load as host numpy"
        np.testing.assert_array_equal(buf, s["pop"][name])
    assert back["pop_sum"].dtype == np.float64, "running sum downcast on load"
    np.testing.assert_array_equal(back["pop_sum"], s["pop_sum"])

    # continue both: the restored trajectory is the uninterrupted one
    r2 = popstore.Runner(cfg, prob.oracle())
    for _ in range(3):
        s, _ = runner.round(s, prob.batch())
        back, _ = r2.round(back, prob.batch())
    for name in popstore.POP_BUFFERS["gpdmm"]:
        np.testing.assert_array_equal(s["pop"][name], back["pop"][name],
                                      err_msg=f"resume drift: {name}")
    np.testing.assert_array_equal(np.asarray(s["x_s"]), np.asarray(back["x_s"]))


def test_checkpoint_roundtrip_at_10k_rows(tmp_path):
    """The real streaming threshold (16 MiB), a real 10^4-row store: each
    (10^4, 512) f32 buffer is 20 MB and takes the chunked path unpatched."""
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=2, eta=0.1,
                          use_arena=True, participation=64 / 10_000,
                          cohort=True, arena_min_width=512)
    grad = lambda p, b: jax.tree.map(lambda x: x * 0.1, p)
    runner = popstore.Runner(cfg, grad)
    s = runner.init({"w": jnp.full((512,), 0.5)}, 10_000)
    batch = {"dummy": jnp.zeros((10_000, 1))}
    s, _ = runner.round(s, batch)
    assert s["pop"]["u_hat"].nbytes > msgpack_ckpt.CHUNK_BYTES
    ckpt.save(tmp_path, 1, s)
    back = ckpt.load(tmp_path, 1)
    for name in popstore.POP_BUFFERS["gpdmm"]:
        assert isinstance(back["pop"][name], np.ndarray)
        np.testing.assert_array_equal(back["pop"][name], s["pop"][name])
    assert back["pop_sum"].dtype == np.float64
    s, _ = runner.round(s, batch)
    back, _ = popstore.Runner(cfg, grad).round(back, batch)
    np.testing.assert_array_equal(s["pop"]["u_hat"], back["pop"]["u_hat"])


def test_train_popstore_resume_roundtrip(tmp_path):
    """launch.train with the store forced on: save-at-2 + --resume == the
    uninterrupted run (identical logged rounds and losses)."""
    kw = dict(reduced=True, algorithm="gpdmm", k=1, eta=0.05, m=8,
              per_client_batch=2, seq_len=32, participation=0.5,
              popstore_mode=True, log_every=1)
    full = train_run("olmo-1b", steps=4, **kw)
    part = train_run("olmo-1b", steps=2, ckpt_dir=str(tmp_path), **kw)
    rest = train_run("olmo-1b", steps=4, ckpt_dir=str(tmp_path), resume=True,
                     **kw)
    hist = part + rest
    assert [r["round"] for r in hist] == [r["round"] for r in full]
    for a, b in zip(full, hist):
        assert a["server_loss"] == pytest.approx(b["server_loss"], abs=1e-5)
        assert a.get("used_popstore") == 1.0


def test_train_popstore_resume_mode_mismatch_raises(tmp_path):
    kw = dict(reduced=True, algorithm="gpdmm", k=1, eta=0.05, m=8,
              per_client_batch=2, seq_len=32, participation=0.5, log_every=1)
    train_run("olmo-1b", steps=2, ckpt_dir=str(tmp_path), popstore_mode=True,
              **kw)
    with pytest.raises(ValueError, match="popstore"):
        train_run("olmo-1b", steps=4, ckpt_dir=str(tmp_path), resume=True,
                  popstore_mode=False, **kw)


# ---------------------------------------------------------------------------
# bug sweep
# ---------------------------------------------------------------------------

def test_cohort_count_exact_products():
    """ceil(frac*m) must not overcount on exact products: 0.07*100 is
    7.000000000000001 in binary and a naive float ceil said 8."""
    assert T.cohort_count(100, 0.07) == 7
    assert T.cohort_count(10_000, 0.07) == 700
    assert T.cohort_count(8, 0.5) == 4
    assert T.cohort_count(3, 0.5) == 2  # genuine ceil still rounds up
    assert T.cohort_count(10, 0.01) == 1  # floor of one client
    # the mask agrees with the count (the single-source-of-truth contract)
    mask = T.participation_mask(jax.random.key(0), 100, 0.07)
    assert int(mask.sum()) == 7


def test_config_validator_agrees_with_engine():
    """The cohort_tile divisibility check uses the engine's cohort_count:
    tile=7 at (m=100, p=0.07) is valid (the old duplicated float ceil said
    the cohort was 8 and rejected it); a genuine mismatch still raises."""
    FederatedConfig(algorithm="gpdmm", num_clients=100, participation=0.07,
                    cohort_tile=7)
    with pytest.raises(ValueError, match="divide"):
        FederatedConfig(algorithm="gpdmm", num_clients=100,
                        participation=0.07, cohort_tile=3)


def test_log_every_zero_does_not_crash():
    """--log-every 0 used to ZeroDivisionError on the per-round driver
    (the scan path survived); both drivers now clamp and log every round."""
    for rpc, want in ((1, [1, 2]), (2, [2])):
        # the scan driver can't log inside a dispatch, so rpc=2 only
        # surfaces the final round; the per-round driver logs every round
        hist = train_run("olmo-1b", reduced=True, steps=2, algorithm="gpdmm",
                         k=1, eta=0.05, m=2, per_client_batch=2, seq_len=32,
                         log_every=0, rounds_per_call=rpc)
        assert [r["round"] for r in hist] == want


def test_round_alignment_r1_vs_scan():
    """The per-round and round-batched drivers log the SAME round numbers
    (loss curves line up row for row): steps=6, log_every=2 -> [2, 4, 6]."""
    kw = dict(reduced=True, steps=6, algorithm="gpdmm", k=1, eta=0.05, m=2,
              per_client_batch=2, seq_len=32, log_every=2)
    h1 = train_run("olmo-1b", rounds_per_call=1, **kw)
    h2 = train_run("olmo-1b", rounds_per_call=2, **kw)
    assert [r["round"] for r in h1] == [2, 4, 6]
    assert [r["round"] for r in h1] == [r["round"] for r in h2]
    for a, b in zip(h1, h2):
        assert a["server_loss"] == pytest.approx(b["server_loss"], abs=1e-5)


def test_final_checkpoint_respects_keep(tmp_path):
    """The end-of-run save passes keep=ckpt_keep too: it must prune old
    anchors instead of leaving keep+1 files behind."""
    train_run("olmo-1b", reduced=True, steps=4, algorithm="gpdmm", k=1,
              eta=0.05, m=2, per_client_batch=2, seq_len=32, log_every=1,
              ckpt_dir=str(tmp_path), ckpt_every=1, ckpt_keep=2)
    steps = ckpt.steps(tmp_path)
    assert len(steps) <= 2, steps
    assert steps[-1] == 4  # the final state is among the survivors


def test_ckpt_steps_skips_stray_files(tmp_path):
    train_run("olmo-1b", reduced=True, steps=2, algorithm="gpdmm", k=1,
              eta=0.05, m=2, per_client_batch=2, seq_len=32, log_every=1,
              ckpt_dir=str(tmp_path))
    (tmp_path / "step_tmp.msgpack").write_bytes(b"not a checkpoint")
    with pytest.warns(RuntimeWarning, match="non-checkpoint"):
        steps = ckpt.steps(tmp_path)
    assert steps == [2]
    # and --resume still works with the stray file present
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        back = ckpt.load(tmp_path)
    assert back["round"] == 2
