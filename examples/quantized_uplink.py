"""Beyond-paper demo: EF21 delta-quantised uplink for GPDMM/AGPDMM.

The paper's headline communication property is ONE variable per direction
per round (u_i = x̄_i − λ_{i|s}/ρ).  This extension compresses that variable
on the server-client wire: each client transmits q(u_i − û_i) at
``--bits`` bits with both sides integrating û_i += q(·), so the quantisation
scale shrinks with the residual and the iterates converge to the exact
optimum (see EXPERIMENTS.md §Perf H3).

    PYTHONPATH=src python examples/quantized_uplink.py --bits 4
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import make, quadratic


def run(algo: str, bits, prob, rounds=150):
    cfg = FederatedConfig(algorithm=algo, inner_steps=5, eta=0.5 / prob.L,
                          uplink_bits=bits)
    opt = make(cfg)
    s = opt.init(jnp.zeros((prob.d,)), prob.m)

    @jax.jit
    def rf(s):
        s, m = opt.round(s, prob.grad, prob.batch())
        return s, m

    for _ in range(rounds):
        s, metrics = rf(s)
    return float(prob.dist(opt.server_params(s))), float(metrics["lam_sum_norm"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--algo", default="gpdmm", choices=["gpdmm", "agpdmm"])
    ap.add_argument("--rounds", type=int, default=150)
    args = ap.parse_args()

    prob = quadratic.generate(jax.random.key(0), m=8, n=400, d=64)
    d_exact, _ = run(args.algo, None, prob, args.rounds)
    d_quant, lam = run(args.algo, args.bits, prob, args.rounds)

    bytes_exact = prob.d * 4  # f32 wire
    bytes_quant = prob.d * args.bits / 8 + 4  # int<bits> + one f32 scale
    print(f"{args.algo} after {args.rounds} rounds on the paper's least-squares problem:")
    print(f"  exact uplink      : ||x - x*|| = {d_exact:.3e}   ({bytes_exact:,.0f} B/client/round)")
    print(f"  {args.bits}-bit EF21 uplink : ||x - x*|| = {d_quant:.3e}   "
          f"({bytes_quant:,.0f} B/client/round, {bytes_exact/bytes_quant:.1f}x less wire)")
    print(f"  dual-sum invariant (eq. 25) under quantisation: {lam:.2e}")
    assert d_quant < 50 * d_exact + 1e-3, "quantised run diverged from exact"
    print("EF21 delta compression preserves convergence.")


if __name__ == "__main__":
    main()
