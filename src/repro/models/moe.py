"""Token-choice top-k MoE with capacity-based dispatch (TPU-friendly: static
shapes, sort-free gather/scatter by expert slot) + shared experts.

The routed path materialises (E, C, D) expert inputs where the capacity
C = ceil(top_k * T / E * capacity_factor); tokens overflowing an expert's
capacity are dropped for that slot (standard Switch/MaxText behaviour).
An auxiliary load-balance loss (Switch-style) is returned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from typing import Optional

from repro.configs.base import ArchConfig
from repro.models import layers as L

CAPACITY_FACTOR = 1.25


def moe_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    wr, sr = L.dense_init(ks[0], (d, e), ("embed", None), dtype)
    wi, si = L.dense_init(ks[1], (e, d, ff), ("experts", "embed", "ff"), dtype)
    wg, sg = L.dense_init(ks[2], (e, d, ff), ("experts", "embed", "ff"), dtype)
    wo, so = L.dense_init(ks[3], (e, ff, d), ("experts", "ff", "embed"), dtype)
    params = {"router": wr, "wi": wi, "wg": wg, "wo": wo}
    specs = {"router": sr, "wi": si, "wg": sg, "wo": so}
    if cfg.n_shared_experts:
        sh, shs = L.mlp_init(ks[4], d, cfg.n_shared_experts * ff, dtype)
        params["shared"] = sh
        specs["shared"] = shs
    return params, specs


def moe_apply(cfg: ArchConfig, params, x, act: str = "silu", full_capacity: bool = False,
              fused: Optional[bool] = None):
    """x: (B, S, D) -> (out, aux_loss).

    ``full_capacity`` (decode): capacity == T so no token is ever dropped --
    the decode batch is tiny and drops would make cached decoding diverge
    from the teacher-forced forward.

    Two dispatch strategies (see EXPERIMENTS.md SSPerf H1):

    * per-slot loop (baseline, ``cfg.moe_fused_dispatch=False``): one
      gather/ffn/scatter per top-k slot.  With experts sharded over "model",
      every slot's scatter-add is a separate f32 (T, D) all-reduce -- k big
      collectives per MoE layer.
    * fused (``True``): ONE dispatch over all (token, slot) choices sharing
      the same per-expert capacity, so the expert-combine is a single psum,
      and the partial sums are cast to the activation dtype before crossing
      the mesh (bf16 instead of f32 on the wire).
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt @ params["router"]).astype(jnp.float32)  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)  # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    cap = T if full_capacity else max(1, int((k * T / E) * CAPACITY_FACTOR))
    if cfg.moe_fused_dispatch if fused is None else fused:
        return _moe_fused(cfg, params, x, xt, topv, topi, gates, cap, act)
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)  # sentinel row
    token_ids = jnp.arange(T, dtype=jnp.int32)

    out = jnp.zeros((T, D), jnp.float32)
    # track per-(token,slot) position within the chosen expert across slots so
    # capacity is shared between slots of the same expert
    counts = jnp.zeros((E,), jnp.int32)
    for j in range(k):
        e_j = topi[:, j]  # (T,)
        onehot = jax.nn.one_hot(e_j, E, dtype=jnp.int32)  # (T, E)
        pos_in_e = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # (T, E)
        pos = pos_in_e.sum(-1) + counts[e_j]  # (T,)
        counts = counts + onehot.sum(0)
        slot = jnp.where(pos < cap, pos, cap)  # cap -> dropped (oob)
        # scatter token ids into (E, cap); untouched slots point at sentinel T
        idx = jnp.full((E, cap), T, jnp.int32)
        idx = idx.at[e_j, slot].set(token_ids, mode="drop")
        xg = xt_pad[idx]  # (E, cap, D)
        h = jnp.einsum("ecd,edf->ecf", xg, params["wi"])
        g = jnp.einsum("ecd,edf->ecf", xg, params["wg"])
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        y = jnp.einsum("ecf,efd->ecd", h * g, params["wo"]).astype(jnp.float32)
        # combine weight per (e, c): gate of the token occupying the slot
        w_ec = jnp.where(idx < T, topv[jnp.minimum(idx, T - 1), j], 0.0)
        out = out.at[idx.reshape(-1)].add(
            (y * w_ec[..., None]).reshape(-1, D), mode="drop"
        )

    out = out.astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + L.mlp_apply(params["shared"], xt, act)

    # Switch load-balance loss: E * sum_e f_e * p_e
    frac = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (T * k)
    pmean = gates.mean(0)
    aux = E * jnp.sum(frac * pmean)
    return out.reshape(B, S, D), aux


def _moe_fused(cfg: ArchConfig, params, x, xt, topv, topi, gates, cap, act):
    """Single-dispatch routed path: all k slots share one (E, cap) buffer.

    The per-expert capacity semantics match the loop path (capacity shared
    across slots); only the *priority order* under overflow differs
    (token-major here vs slot-major in the loop) -- identical whenever no
    token is dropped, property-tested in tests/test_archs.py.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S

    e_flat = topi.reshape(-1)  # (T*k,) token-major: choice f = t*k + j
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # running slot per expert
    slot = jnp.where(pos.sum(-1) < cap, pos.sum(-1), cap)  # cap -> dropped

    # scatter flat-choice ids into (E, cap); empty slots point at sentinel T*k
    fidx = jnp.full((E, cap), T * k, jnp.int32)
    fidx = fidx.at[e_flat, slot].set(jnp.arange(T * k, dtype=jnp.int32), mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    tok = jnp.where(fidx < T * k, fidx // k, T)  # sentinel row T
    xg = xt_pad[tok]  # (E, cap, D) -- ONE gather for all slots
    h = jnp.einsum("ecd,edf->ecf", xg, params["wi"])
    g = jnp.einsum("ecd,edf->ecf", xg, params["wg"])
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    y = jnp.einsum("ecf,efd->ecd", h * g, params["wo"]).astype(jnp.float32)

    w_ec = jnp.where(
        fidx < T * k, topv.reshape(-1)[jnp.minimum(fidx, T * k - 1)], 0.0
    )
    # gate-weighting in f32, then accumulate the combine in the activation
    # dtype so the cross-expert psum over the "model" axis (and its backward
    # twin) travels in bf16, not f32: each (token, slot) contribution lives on
    # exactly one device, so the scatter merges <= top_k values per token and
    # the cross-device sum merges disjoint expert outputs -- bf16-safe.
    contrib = (y * w_ec[..., None]).astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype)
    out = out.at[tok.reshape(-1)].add(contrib.reshape(-1, D), mode="drop")
    if cfg.n_shared_experts:
        out = out + L.mlp_apply(params["shared"], xt, act)

    frac = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(frac * gates.mean(0))
    return out.reshape(B, S, D), aux
