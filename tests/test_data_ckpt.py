"""Data-pipeline and checkpoint substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import checkpoint as ckpt
from repro.data import partition, synthetic


def test_by_class_partition_is_heterogeneous():
    ds = synthetic.gaussian_mixture_images(jax.random.key(0), 50, 10)
    xs, ys = partition.by_class(ds.x_train, ds.y_train, ds.n_classes)
    assert xs.shape[0] == 10
    for c in range(10):
        assert bool((ys[c] == c).all())


def test_iid_partition_covers():
    key = jax.random.key(1)
    x = jnp.arange(100 * 3, dtype=jnp.float32).reshape(100, 3)
    y = jnp.arange(100) % 10
    xs, ys = partition.iid(key, x, y, m=4)
    assert xs.shape == (4, 25, 3)
    # no sample duplicated
    flat = np.asarray(xs[..., 0].reshape(-1))
    assert len(np.unique(flat)) == 100


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 8), alpha=st.floats(0.1, 5.0))
def test_dirichlet_partition_covers_all(m, alpha):
    key = jax.random.key(int(alpha * 100) + m)
    y = jnp.asarray(np.random.default_rng(0).integers(0, 5, 200))
    idx = partition.dirichlet(key, None, y, m=m, n_classes=5, alpha=alpha)
    allidx = np.concatenate(idx)
    assert sorted(allidx.tolist()) == list(range(200))


def test_minibatch_schedule_deterministic():
    s1 = partition.minibatch_schedule(1000, 32, 50)
    s2 = partition.minibatch_schedule(1000, 32, 50)
    np.testing.assert_array_equal(s1, s2)
    assert (s1 + 32 <= 1000).all()


def test_lm_batches_heterogeneous():
    gen = synthetic.lm_batches(jax.random.key(0), 1, m=3, per_client_batch=2,
                               seq_len=32, vocab=128)
    batch = next(gen)
    assert batch["tokens"].shape == (3, 2, 32)
    assert batch["targets"].shape == (3, 2, 32)
    # different clients draw from different topic permutations
    h0 = np.bincount(np.asarray(batch["tokens"][0]).ravel(), minlength=128)
    h1 = np.bincount(np.asarray(batch["tokens"][1]).ravel(), minlength=128)
    assert not np.array_equal(h0, h1)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": [jnp.int32(3), jnp.zeros((2, 2))]},
        "e": (jnp.asarray(2.5),),
        "meta": 7,
    }
    ckpt.save(tmp_path, 3, tree)
    assert ckpt.latest_step(tmp_path) == 3
    back = ckpt.load(tmp_path)
    assert back["meta"] == 7
    assert isinstance(back["e"], tuple) and isinstance(back["b"]["d"], list)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(back["b"]["c"], np.float32), np.ones(4, np.float32)
    )


def test_checkpoint_multiple_steps(tmp_path):
    for s in [1, 5, 3]:
        ckpt.save(tmp_path, s, {"x": jnp.asarray(float(s))})
    assert ckpt.latest_step(tmp_path) == 5
    assert float(ckpt.load(tmp_path, 3)["x"]) == 3.0
