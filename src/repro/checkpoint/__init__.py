"""msgpack-based pytree checkpointing (substrate; no orbax offline)."""
from repro.checkpoint.msgpack_ckpt import load, save, latest_step, steps

__all__ = ["save", "load", "latest_step", "steps"]
