"""stablelm-12b -- dense GQA [hf:stabilityai/stablelm-2-1_6b family].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    block_pattern=("dense",),
    attn_kind="gqa",
    norm_kind="layernorm",
    shard_cache_seq=True,  # SSPerf H2: kv=8 can't divide the 16-way model axis
    subquadratic=False,  # long_500k skipped (full attention; see DESIGN.md)
    fed=FederatedConfig(algorithm="gpdmm", layout="client_axis"),
    microbatch=16,  # grad-accum chunks per inner step (activation memory)
    source="hf:stabilityai/stablelm-2-12b",
)
