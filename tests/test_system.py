"""End-to-end behaviour tests: the paper's technique actually trains language
models, the serve path generates, the dry-run machinery lowers on the forced
512-device mesh (subprocess), and the optimizer substrate behaves."""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS
from repro.launch.train import run as train_run
from repro.models import build

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_federated_lm_training_reduces_loss():
    # eta=0.05 is in the stable region for the reduced olmo LM (eta>=0.2
    # diverges: the prox step no longer contracts on the non-convex loss)
    hist = train_run("olmo-1b", reduced=True, steps=12, algorithm="gpdmm",
                     k=2, eta=0.05, m=2, per_client_batch=2, seq_len=64, log_every=4)
    first, last = hist[0]["server_loss"], hist[-1]["server_loss"]
    assert last < first - 0.3, (first, last)


def test_agpdmm_trains_lm_too():
    hist = train_run("olmo-1b", reduced=True, steps=8, algorithm="agpdmm",
                     k=2, eta=0.05, m=2, per_client_batch=2, seq_len=64, log_every=4)
    assert hist[-1]["server_loss"] < hist[0]["server_loss"]


def test_serve_generates():
    from repro.launch.serve import run as serve_run
    gen = serve_run("olmo-1b", reduced=True, batch=2, prompt_len=16, new_tokens=4)
    assert gen.shape == (2, 4)
    v = ARCHS["olmo-1b"].reduced().vocab_size
    assert bool((gen >= 0).all()) and bool((gen < v).all())


def test_serve_ssm_generates():
    from repro.launch.serve import run as serve_run
    gen = serve_run("rwkv6-1.6b", reduced=True, batch=2, prompt_len=16, new_tokens=4)
    assert gen.shape == (2, 4)


@pytest.mark.slow
def test_dryrun_single_combo_subprocess():
    """The dry-run driver (512 forced host devices) must succeed end-to-end;
    run in a subprocess so the forced device count can't leak here."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "olmo-1b", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=str(REPO),
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "ok=1" in out.stdout


def test_checkpoint_resume_roundtrip(tmp_path):
    hist = train_run("olmo-1b", reduced=True, steps=4, algorithm="gpdmm",
                     k=1, eta=0.3, m=2, per_client_batch=2, seq_len=32,
                     ckpt_dir=str(tmp_path), log_every=2)
    from repro import checkpoint as ckpt
    back = ckpt.load(tmp_path)
    assert "server" in back and len(jax.tree.leaves(back["server"])) > 0


def test_adam_optimizes_quadratic():
    opt = optim.adam(0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_sgd_momentum_optimizes():
    opt = optim.sgd(0.05, momentum=0.9)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    # heavy-ball on x^2 is underdamped at (0.05, 0.9): |x| decays ~0.9^t with
    # oscillation; 100 steps land at 0.011 -- give it 160
    for _ in range(160):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    s = optim.cosine(1.0, total_steps=100, warmup_steps=10)
    assert float(s(0)) < 0.2
    assert float(s(10)) == pytest.approx(1.0, rel=0.05)
    assert float(s(100)) == pytest.approx(0.1, rel=0.05)


def test_microbatched_grad_equals_full():
    """Grad accumulation (the memory hillclimb lever) must be exact."""
    cfg = ARCHS["olmo-1b"].reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}

    g_full = jax.grad(lambda p: model.loss(p, batch)[0])(params)

    mb = jax.tree.map(lambda x: x.reshape(2, 2, *x.shape[1:]), batch)

    def acc(g, mb_i):
        gi = jax.grad(lambda p: model.loss(p, mb_i)[0])(params)
        return jax.tree.map(jnp.add, g, gi), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    g_acc, _ = jax.lax.scan(acc, g0, mb)
    g_acc = jax.tree.map(lambda x, p: (x / 2).astype(p.dtype), g_acc, params)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)


def test_quantized_partial_lm_training():
    """Beyond-paper features compose at LM scale: 8-bit EF21 uplink + 50%
    client participation still reduce the federated LM loss."""
    hist = train_run("olmo-1b", reduced=True, steps=10, algorithm="gpdmm",
                     k=2, eta=0.05, m=4, per_client_batch=2, seq_len=64,
                     log_every=3, uplink_bits=8, participation=0.5)
    assert hist[-1]["server_loss"] < hist[0]["server_loss"]
    assert hist[-1]["lam_sum_norm"] < 1e-2
