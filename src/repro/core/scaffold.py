"""SCAFFOLD (Karimireddy et al. 2020), eqs. (29)-(30) of the paper, as the
primary baseline.  Control variates c (server) and c_i (clients) compensate
client heterogeneity; both directions transmit TWO variables per round
(x and c), which is the communication contrast with GPDMM the paper draws.

    x_i^{r,0}   = x_s^r
    x_i^{r,k+1} = x_i^{r,k} - eta (grad f_i(x_i^{r,k}) - c_i^r + c^r)
    c_i^{r+1}   = c_i^r - c^r + (x_s^r - x_i^{r,K}) / (K eta)
    x_s^{r+1}   = x_s^r + eta_g mean_i (x_i^{r,K} - x_s^r)   (all-reduce #1)
    c^{r+1}     = c^r + mean_i (c_i^{r+1} - c_i^r)           (all-reduce #2)

Arena fast path (``core.arena``): ``c_i`` is arena-RESIDENT -- it enters and
leaves the round as one ``(m, width)`` buffer donated in place, exactly like
GPDMM's ``lam_s``.  The K inner steps resolve through the ``core.api``
oracle protocol: for affine oracles the control-variate correction
``- c_i + c`` folds into the affine constant (``c`` into the fresh constant,
``c_i`` as the kernel's per-client offset row), so the WHOLE inner loop
stays the single fused K-step kernel with zero extra HBM materialisation;
otherwise a scan of lam-carried fused arena updates runs with rho = 0.  The
round tail is one fused control-variate kernel (``ops.scaffold_cv``) plus
the TWO server all-reduces (x-mean and c-delta-mean) -- the two-variable
communication pattern the paper contrasts with GPDMM's one.

Partial participation (``cfg.participation < 1``, mask drawn from the
``FederatedConfig.seed`` contract like every other algorithm): silent
clients transmit NOTHING, so their deltas contribute zero to both server
means and their c_i is kept -- the server-side invariant c = mean_i c_i
survives partial rounds exactly.  EF21 uplink quantisation is NOT offered
for SCAFFOLD: its uplink is two coupled variables per round and a single
error-feedback integrator per client does not apply; ``make`` rejects the
combination loudly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig
from repro.core import arena, faults, staleness
from repro.core import tree_util as T
from repro.core.api import (
    FedOpt, affine_case, arena_grad, cohort_batch, run_cohort_inner,
    use_arena, use_cohort,
)
from repro.core.gpdmm import _eta_val, _step_for, participation_key
from repro.kernels import ops


def inner_steps_plain_arena(spec, grad_fn, x0, x_s_row, batch, *, K, eta,
                            per_step, c_i=None, c_row=None):
    """K plain gradient steps over the arena with an optional control-variate
    correction:  x <- x - eta (grad f_i(x) - c_i + c).

    Shared by SCAFFOLD (``c_i``/``c_row`` set) and FedAvg (no correction).
    Resolution, fastest first (the ``core.api`` oracle protocol):

      1. ``affine_arena`` + width fits VMEM: ONE fused K-step kernel.  The
         server variate folds into the (freshly built) affine constant and
         the arena-resident ``c_i`` buffer rides as the kernel's per-client
         offset row -- the correction costs zero extra HBM traffic.
      2. otherwise: a scan of lam-free (FedAvg) or lam-carried (SCAFFOLD,
         lam = c - c_i materialised ONCE per round) fused arena updates with
         rho = 0, the gradient via ``arena_grad`` (arena-native oracles pay
         zero boundary passes).

    ``eta`` may be a scalar, the per-client tuple (auto-eta), or an
    already-gathered per-cohort row -- array forms ride the kernels as a
    per-client stepsize operand (``kernels/ops``).
    """
    eta = _eta_val(eta)
    affine = affine_case(grad_fn, spec, per_step=per_step)
    if affine is not None:
        H, c = affine(spec, batch)
        off = None
        if c_i is not None:
            # grad - c_i + c == H x - ((c_aff - c) + c_i): server variate
            # into the constant, client variate as the offset row
            c = c - c_row[None]
            off = c_i
        x_K, _ = ops.inner_loop_affine(x0, H, c, x_s_row, None, eta, 0.0, K, off=off)
        return x_K

    grad_a, _native = arena_grad(grad_fn, spec)
    lam = None if c_i is None else c_row[None] - c_i  # one (m, width) pass

    def one_step(x, xs_k):
        b = xs_k if per_step else batch
        g = grad_a(x, b)
        # eq. (20) with rho = 0: x - eta (g + lam), lam = c - c_i
        return ops.fused_update_arena(x, g, x_s_row, lam, eta, 0.0), None

    if per_step:
        x_K, _ = jax.lax.scan(one_step, x0, batch)
    else:
        x_K, _ = jax.lax.scan(one_step, x0, None, length=K)
    return x_K


def popstore_body(cfg: FederatedConfig, spec, m: int, grad_fn, per_step):
    """Device half of a host-popstore SCAFFOLD round (see
    gpdmm.popstore_body): the cohort's ``c_i`` rows stage from the host
    store.  Unlike GPDMM's, SCAFFOLD's cohort server update is ALREADY
    O(cohort) on device (both all-reduces are sums over cohort deltas), so
    this body computes the new server rows itself -- bit-identical to
    ``_round_arena_cohort`` -- and returns them in ``server_rows``; only the
    ``c_sum_norm`` diagnostic needs the host driver's incremental
    ``sum(c_i)``."""
    K, eta = cfg.inner_steps, _eta_val(cfg.eta)
    per_client = np.ndim(eta) > 0
    f32 = jnp.float32

    def body(server, staged, idx, round_idx, batch):
        x_s_row = spec.pack(server["x_s"])
        c_row = spec.pack(server["c"])
        c_i_c = staged["c_i"]
        batch_c = cohort_batch(batch, idx, m, per_step)
        eta_c = jnp.asarray(eta)[idx] if per_client else None

        def inner(rows, b):
            ci_t = rows[0]
            eta_t = rows[1] if per_client else eta  # tiled with the rows
            x0 = jnp.broadcast_to(x_s_row[None], ci_t.shape)
            return inner_steps_plain_arena(
                spec, grad_fn, x0, x_s_row, b, K=K, eta=eta_t,
                per_step=per_step, c_i=ci_t, c_row=c_row,
            )

        rows = (c_i_c,) + ((eta_c,) if per_client else ())
        x_K = run_cohort_inner(cfg, inner, rows, batch_c,
                               per_step=per_step)

        fplan = faults.plan(cfg, round_idx, m)
        plan_c = faults.take(fplan, idx)
        x_t = faults.inject(cfg.faults, plan_c, x_K)
        alpha = 1.0 / (K * (eta_c if per_client else eta))
        c_i_new_c = ops.scaffold_cv(c_i_c, x_t, c_row, x_s_row, alpha)
        keep = None
        if faults.screening_on(cfg):
            keep = faults.screen_keep(cfg, x_t, x_s_row)
        keep_c = faults.combine_mask(None, plan_c, keep)
        if keep_c is not None:
            c_i_new_c = jnp.where(keep_c[:, None], c_i_new_c, c_i_c)
            x_t = jnp.where(keep_c[:, None], x_t, x_s_row[None])
        inv_m = 1.0 / m
        x_s_new = x_s_row + cfg.eta_g * inv_m * jnp.sum(
            (x_t - x_s_row[None]).astype(f32), axis=0).astype(x_s_row.dtype)
        c_new = c_row + inv_m * jnp.sum(
            (c_i_new_c - c_i_c).astype(f32), axis=0).astype(c_row.dtype)
        metrics = {
            "client_drift": T.masked_client_mean(
                jnp.sum(jnp.square((x_K - x_s_row[None]).astype(f32)),
                        axis=1), keep_c),
            "used_arena": jnp.ones((), f32),
        }
        if fplan is not None or keep is not None:
            metrics |= faults.fault_metrics(
                fplan, None if plan_c is None else ~plan_c.silent, keep)
        return ({"c_i": c_i_new_c},
                {"x_s": x_s_new, "c": c_new}, metrics)

    return body


def _round_arena_cohort(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches):
    """SCAFFOLD round over the sampled cohort (see gpdmm._round_arena_cohort):
    the cohort's c_i rows gather, run the offset inner loop + fused
    control-variate refresh, and scatter back.  Silent clients transmit
    nothing, so both server means decompose as sum_active(delta) / m -- the
    same zero-delta contract the masked path realises with selects (equal at
    f32: the masked path subtracts the server row back out of the mean, this
    path never adds it in)."""
    K, eta = cfg.inner_steps, _eta_val(cfg.eta)
    per_client = np.ndim(eta) > 0
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    c_i = state["c_i"]
    m = c_i.shape[0]
    x_s_row = spec.pack(state["x_s"])
    c_row = spec.pack(state["c"])
    idx, _mask = T.cohort_indices(
        participation_key(cfg, state["round"]), m, cfg.participation
    )
    c_i_c = ops.row_gather(c_i, idx)
    batch_c = cohort_batch(batch, idx, m, per_step_batches)
    eta_c = jnp.asarray(eta)[idx] if per_client else None

    def inner(rows, b):
        ci_t = rows[0]
        eta_t = rows[1] if per_client else eta  # tiled with the state rows
        x0 = jnp.broadcast_to(x_s_row[None], ci_t.shape)
        return inner_steps_plain_arena(
            spec, grad_fn, x0, x_s_row, b, K=K, eta=eta_t,
            per_step=per_step_batches, c_i=ci_t, c_row=c_row,
        )

    rows = (c_i_c,) + ((eta_c,) if per_client else ())
    x_K = run_cohort_inner(cfg, inner, rows, batch_c,
                           per_step=per_step_batches)

    # the wire corrupts the transmitted packet x_i^{r,K}; both uplinked
    # variables (dx_i and dc_i) derive from it, so both see the corruption
    fplan = faults.plan(cfg, state["round"], m)
    plan_c = faults.take(fplan, idx)
    x_t = faults.inject(cfg.faults, plan_c, x_K)
    # fused per-cohort tail: c_i' = c_i - c + (x_s - x_t)/(K eta_i)
    alpha = 1.0 / (K * (eta_c if per_client else eta))
    c_i_new_c = ops.scaffold_cv(c_i_c, x_t, c_row, x_s_row, alpha)
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep(cfg, x_t, x_s_row)
    keep_c = faults.combine_mask(None, plan_c, keep)
    if keep_c is not None:
        # demoted/silent cohort rows: zero delta on both means, c_i kept
        c_i_new_c = jnp.where(keep_c[:, None], c_i_new_c, c_i_c)
        x_t = jnp.where(keep_c[:, None], x_t, x_s_row[None])
    # server: TWO all-reduces over the cohort's deltas (silent rows are zero)
    inv_m = 1.0 / m
    x_s_new = x_s_row + cfg.eta_g * inv_m * jnp.sum(
        (x_t - x_s_row[None]).astype(jnp.float32), axis=0).astype(x_s_row.dtype)
    c_new = c_row + inv_m * jnp.sum(
        (c_i_new_c - c_i_c).astype(jnp.float32), axis=0).astype(c_row.dtype)
    c_i_new = ops.row_scatter(c_i, idx, c_i_new_c)  # silent clients keep c_i

    new_state = {
        "x_s": spec.unpack(x_s_new),
        "c": spec.unpack(c_new),
        "c_i": c_i_new,
        "round": state["round"] + 1,
    }
    f32 = jnp.float32
    metrics = {
        "c_sum_norm": jnp.linalg.norm(
            jnp.sum((c_i_new - c_new[None]).astype(f32), axis=0)),
        "client_drift": T.masked_client_mean(
            jnp.sum(jnp.square((x_K - x_s_row[None]).astype(f32)), axis=1),
            keep_c),
        "used_arena": jnp.ones((), f32),
    }
    if fplan is not None or keep is not None:
        metrics |= faults.fault_metrics(
            fplan, None if plan_c is None else ~plan_c.silent, keep)
    return new_state, metrics


def _round_arena(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches):
    """SCAFFOLD round over the flat arena: fused K-step inner loop with the
    control-variate offset, ONE fused c_i refresh, and the two server
    all-reduces.  ``c_i`` is arena-resident; only the server-sized x_s and c
    rows (1/m of the state) repack per round."""
    K, eta = cfg.inner_steps, _eta_val(cfg.eta)
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    c_i = state["c_i"]  # arena-resident (m, width)
    m = c_i.shape[0]
    if use_cohort(cfg, m):
        return _round_arena_cohort(cfg, state, grad_fn, batch, per_step_batches)
    x_s_row = spec.pack(state["x_s"])
    c_row = spec.pack(state["c"])
    x0 = jnp.broadcast_to(x_s_row[None], (m, spec.width))

    x_K = inner_steps_plain_arena(
        spec, grad_fn, x0, x_s_row, batch, K=K, eta=eta,
        per_step=per_step_batches, c_i=c_i, c_row=c_row,
    )

    # the wire corrupts the transmitted packet x_i^{r,K}; both uplinked
    # variables (dx_i and dc_i) derive from it, so both see the corruption
    fplan = faults.plan(cfg, state["round"], m)
    x_t = faults.inject(cfg.faults, fplan, x_K)
    # fused per-client tail: c_i' = c_i - c + (x_s - x_t)/(K eta_i)
    c_i_new = ops.scaffold_cv(c_i, x_t, c_row, x_s_row, 1.0 / (K * eta))
    x_up = x_t
    pmask = None
    if cfg.participation < 1.0:
        pmask = T.participation_mask(
            participation_key(cfg, state["round"]), m, cfg.participation
        )
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep(cfg, x_t, x_s_row)
    mask = faults.combine_mask(pmask, fplan, keep)
    sm = {}
    stale_up = {}
    if faults.async_on(cfg):
        # bounded-staleness engine: the fresh-select baseline is the
        # zero-delta server row; a buffered x_t lands s rounds later and
        # mixes toward it with weight gamma**s.  The control variate
        # refreshes on FRESH participation only -- an arriving stale row
        # carries no variate update
        x_up, mask, stale_up, sm = staleness.step_arena(
            cfg, fplan, x_t, x_s_row, mask, state)
        c_i_new = jnp.where(mask[:, None], c_i_new, c_i)
    elif mask is not None:
        # silent/demoted clients transmit nothing: zero delta on both server
        # means, control variate kept
        c_i_new = jnp.where(mask[:, None], c_i_new, c_i)
        x_up = jnp.where(mask[:, None], x_t, x_s_row[None])
    # server: TWO all-reduces (x-delta and c-delta)
    x_s_new = x_s_row + cfg.eta_g * (jnp.mean(x_up, axis=0) - x_s_row)
    c_new = c_row + jnp.mean(c_i_new - c_i, axis=0)

    new_state = {
        "x_s": spec.unpack(x_s_new),  # server-sized; clients stay packed
        "c": spec.unpack(c_new),
        "c_i": c_i_new,
        "round": state["round"] + 1,
        **stale_up,
    }
    f32 = jnp.float32
    metrics = {
        # invariant: sum_i (c_i - c) = 0 given zero init (padding is zero on
        # both sides, so no masking is needed)
        "c_sum_norm": jnp.linalg.norm(
            jnp.sum((c_i_new - c_new[None]).astype(f32), axis=0)),
        # silent clients' x_K never enters the state: average the active set
        "client_drift": T.masked_client_mean(
            jnp.sum(jnp.square((x_K - x_s_row[None]).astype(f32)), axis=1),
            mask),
        "used_arena": jnp.ones((), f32),
    }
    if fplan is not None or keep is not None:
        tx = faults.combine_mask(pmask, fplan, None)
        if faults.async_on(cfg):
            tx = staleness.fresh_mask(tx, fplan)
        metrics |= faults.fault_metrics(fplan, tx, keep) | sm
    return new_state, metrics


def _round(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches=False):
    if use_arena(cfg, state["x_s"]):
        return _round_arena(cfg, state, grad_fn, batch, per_step_batches)
    K, eta = cfg.inner_steps, _eta_val(cfg.eta)
    x_s, c, c_i = state["x_s"], state["c"], state["c_i"]
    m = jax.tree.leaves(c_i)[0].shape[0]
    x_s_b = T.tree_broadcast(x_s, m)
    c_b = T.tree_broadcast(c, m)
    # lam := c - c_i enters the shared fused step with rho = 0
    lam = T.tree_sub(c_b, c_i)
    vgrad = jax.vmap(grad_fn)

    def one_step(x, xs_k):
        b = xs_k if per_step_batches else batch
        g = vgrad(x, b)
        x_new = T.tmap(lambda xx, gg, ll: ops.fused_update(
            xx, gg, xx, ll, _step_for(eta, xx), 0.0), x, g, lam)
        return x_new, None

    if per_step_batches:
        x_K, _ = jax.lax.scan(one_step, x_s_b, batch)
    else:
        x_K, _ = jax.lax.scan(one_step, x_s_b, None, length=K)

    # multiply by the precomputed 1/(K eta), NOT divide by (K eta): the same
    # rounding as the fused arena kernel, so the parity tests compare paths
    # at f32 resolution instead of absorbing a divide-vs-multiply ulp
    alpha = 1.0 / (K * eta)
    fplan = faults.plan(cfg, state["round"], m)
    x_t = faults.inject_tree(cfg.faults, fplan, x_K)
    c_i_new = T.tmap(
        lambda ci, cc, s, xk: ci - cc + (s - xk) * _step_for(alpha, xk),
        c_i, c_b, x_s_b, x_t)
    x_up = x_t
    pmask = None
    if cfg.participation < 1.0:
        pmask = T.participation_mask(
            participation_key(cfg, state["round"]), m, cfg.participation
        )
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep_tree(cfg, x_t, x_s)
    mask = faults.combine_mask(pmask, fplan, keep)
    sm = {}
    stale_up = {}
    if faults.async_on(cfg):
        # same stale-dual contract as the arena path: x_s_b is the
        # zero-delta baseline, c_i refreshes on fresh participation only
        x_up, mask, stale_up, sm = staleness.step_tree(
            cfg, fplan, x_t, x_s_b, mask, state)
        c_i_new = T.tree_select(mask, c_i_new, c_i)
    elif mask is not None:
        # silent/demoted clients transmit nothing (zero delta, c_i kept) --
        # same contract as the arena path
        c_i_new = T.tree_select(mask, c_i_new, c_i)
        x_up = T.tree_select(mask, x_t, x_s_b)
    # server: TWO all-reduces (x-delta and c-delta)
    dx = T.tree_client_mean(T.tree_sub(x_up, x_s_b))
    dc = T.tree_client_mean(T.tree_sub(c_i_new, c_i))
    x_s_new = T.tree_axpy(cfg.eta_g, dx, x_s)
    c_new = T.tree_add(c, dc)

    new_state = {
        "x_s": x_s_new,
        "c": c_new,
        "c_i": c_i_new,
        "round": state["round"] + 1,
        **stale_up,
    }
    metrics = {
        # invariant: sum_i (c_i - c) = 0 given zero init
        "c_sum_norm": T.tree_norm(T.tree_client_sum(T.tree_sub(c_i_new, T.tree_broadcast(c_new, m)))),
        # silent clients' x_K never enters the state: average the active set
        "client_drift": T.masked_client_mean(
            T.tree_client_sqnorms(T.tree_sub(x_K, x_s_b)), mask),
        "used_arena": jnp.zeros((), jnp.float32),
    }
    if fplan is not None or keep is not None:
        tx = faults.combine_mask(pmask, fplan, None)
        if faults.async_on(cfg):
            tx = staleness.fresh_mask(tx, fplan)
        metrics |= faults.fault_metrics(fplan, tx, keep) | sm
    return new_state, metrics


def make(cfg: FederatedConfig) -> FedOpt:
    if cfg.uplink_bits is not None:
        raise NotImplementedError(
            "SCAFFOLD+EF21 (uplink_bits is not None) is not supported: each "
            "SCAFFOLD round uplinks two coupled variables per client -- the "
            "model delta dx_i = x_i^{r,K} - x_s^r and the control-variate "
            "delta dc_i = c_i^{r+1} - c_i^r = (x_s^r - x_i^{r,K})/(K eta) - "
            "c^r.  EF21 integrates ONE error-feedback state u_hat_i per "
            "client; quantising dx_i alone desynchronises the server's c = "
            "mean_i c_i invariant, and a second integrator for dc_i is NOT "
            "error-feedback (dc_i is a function of dx_i, so the two "
            "quantisation errors are coupled).  Use algorithm='gpdmm' (one "
            "uplink variable, EF21 supported) or drop uplink_bits."
        )

    def init(params, m):
        if use_arena(cfg, params):
            # arena-resident control variates: one (m, width) buffer donated
            # in place round over round; x_s and c stay pytrees (the public
            # server-params / server-variate contract, p_shard in launchers)
            spec = arena.ArenaSpec.from_tree(params)
            st = {
                "x_s": params,
                "c": T.tree_zeros_like(params),
                "c_i": arena.zeros(spec, m),
                "round": jnp.zeros((), jnp.int32),
            }
            if faults.async_on(cfg):
                st |= staleness.init_arena(spec, m)
            return st
        st = {
            "x_s": params,
            "c": T.tree_zeros_like(params),
            "c_i": T.tree_zeros_like(T.tree_broadcast(params, m)),
            "round": jnp.zeros((), jnp.int32),
        }
        if faults.async_on(cfg):
            st |= staleness.init_tree(params, m)
        return st

    return FedOpt(
        name="scaffold",
        init=init,
        round=partial(_round, cfg),
        server_params=lambda s: s["x_s"],
    )
