"""FedAvg (McMahan et al. 2017) -- the weakest baseline in the paper's
experiments: plain local SGD + parameter averaging, no dual/control state, so
it drifts under client heterogeneity when K > 1 (paper Fig. 2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import tree_util as T
from repro.core.api import FedOpt
from repro.kernels import ops


def _round(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches=False):
    K, eta = cfg.inner_steps, cfg.eta
    x_s = state["x_s"]
    # FedAvg keeps no per-client state, so the client count comes from the
    # batch layout: (m, ...) or (K, m, ...) with per-step batches.
    b0 = jax.tree.leaves(batch)[0]
    m = b0.shape[1] if per_step_batches else b0.shape[0]
    x_s_b = T.tree_broadcast(x_s, m)
    vgrad = jax.vmap(grad_fn)

    def one_step(x, xs_k):
        b = xs_k if per_step_batches else batch
        g = vgrad(x, b)
        zeros = T.tree_zeros_like(g)
        x_new = T.tmap(lambda xx, gg, zz: ops.fused_update(xx, gg, xx, zz, eta, 0.0), x, g, zeros)
        return x_new, None

    if per_step_batches:
        x_K, _ = jax.lax.scan(one_step, x_s_b, batch)
    else:
        x_K, _ = jax.lax.scan(one_step, x_s_b, None, length=K)

    x_s_new = T.tree_client_mean(x_K)
    new_state = {"x_s": x_s_new, "round": state["round"] + 1}
    metrics = {"client_drift": jnp.mean(T.tree_client_sqnorms(T.tree_sub(x_K, x_s_b)))}
    return new_state, metrics


def make(cfg: FederatedConfig) -> FedOpt:
    def init(params, m):
        del m
        return {"x_s": params, "round": jnp.zeros((), jnp.int32)}

    return FedOpt(
        name="fedavg",
        init=init,
        round=partial(_round, cfg),
        server_params=lambda s: s["x_s"],
    )
