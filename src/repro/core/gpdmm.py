"""GPDMM (Algorithm 1, Zhang et al. 2021): gradient-based PDMM for a
centralised network, one transmitted variable per direction per round.

Per round r (client i, K inner steps, rho = 1/(K eta) by default):

    x_i^{r,0}   = x_i^{r-1,K}                        (carry, NOT x_s - lam/rho:
                                                      the Inexact-FedSplit fix)
    x_i^{r,k+1} = x_i^{r,k} - (1/(1/eta+rho)) [grad f_i(x_i^{r,k})
                                               + rho (x_i^{r,k} - x_s^r)
                                               + lam_{s|i}^r]        (eq. 20)
    lam_{i|s}^{r+1} = rho (x_s^r - xref_i) - lam_{s|i}^r             (eq. 23/24)
    uplink   u_i   = xref_i - lam_{i|s}^{r+1} / rho                 (ONE var)
    x_s^{r+1}      = mean_i u_i                                      (all-reduce)
    lam_{s|i}^{r+1} = rho (xref_i - x_s^{r+1}) - lam_{i|s}^{r+1}     (local)

where xref_i = mean_k x_i^{r,k} (eq. 23, Alg. 1) or x_i^{r,K} (eq. 24,
Remark 1) when ``use_avg=False``.

Communication note (recorded in EXPERIMENTS.md): in the SPMD mapping the
uplink-mean is one all-reduce of a single parameter-sized tensor; the downlink
combination x_s - lam_{s|i}/rho is reconstructed client-locally, so GPDMM's
1-variable-per-direction claim is exactly one collective per round.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig
from repro.core import arena, faults, staleness
from repro.core import tree_util as T
from repro.core.api import (
    FedOpt, affine_case, arena_grad, cohort_batch, resolved_rho,
    run_cohort_inner, use_arena, use_cohort,
)
from repro.kernels import ops


def _eta_val(eta):
    """Kernel-ready view of ``cfg.eta``: the host-resolved per-client tuple
    (``eta="auto"``, see ``core.autotune.resolve``) becomes a static
    ``(m,) np.float32`` array; scalars (and already-traced per-cohort rows)
    pass through untouched, so the scalar path's step arithmetic stays the
    identical baked Python float and its traced graphs are bitwise
    unchanged."""
    return np.asarray(eta, np.float32) if isinstance(eta, tuple) else eta


def _step_for(step, leaf):
    """Per-leaf view of a (possibly per-client) stepsize for the pytree
    path: scalars pass through, per-client arrays broadcast over the leaf's
    trailing dims."""
    if np.ndim(step) == 0:
        return step
    return jnp.asarray(step, jnp.float32).reshape((-1,) + (1,) * (leaf.ndim - 1))


def inner_steps(grad_fn, x0, x_s_b, lam_s, batch, *, K, eta, rho, per_step,
                vr_snapshot=None):
    """Runs the K inexact-PDMM client steps (shared by GPDMM/AGPDMM).

    x0, x_s_b, lam_s: stacked (m, ...) pytrees.  Returns (x_K, x_bar).

    ``vr_snapshot`` (beyond paper; requires ``per_step`` batches): SVRG-style
    variance reduction in the stochastic setting the paper names as future
    work (SSVII), following [14]'s PDMM+SVRG for P2P networks.  With snapshot
    z (the round's fresh server estimate) the step-k gradient becomes

        g_k(x) - g_k(z) + mean_j g_j(z)

    -- unbiased, with variance -> 0 as x -> z, restoring the deterministic
    rates under minibatch noise at the cost of 2x gradient evals per step
    plus one pass at the snapshot.
    """
    eta = _eta_val(eta)
    step_c = 1.0 / (1.0 / eta + rho)
    vgrad = jax.vmap(grad_fn)

    gbar = None
    if vr_snapshot is not None:
        assert per_step, "SVRG needs per-step minibatches (K, m, ...)"
        # full-pass gradient at the snapshot: mean over the K step batches
        snap_grads = jax.lax.map(lambda b: vgrad(vr_snapshot, b), batch)
        gbar = T.tmap(lambda t: jnp.mean(t, axis=0), snap_grads)

    def one_step(carry, xs_k):
        x, xsum = carry
        b = xs_k if per_step else batch
        g = vgrad(x, b)
        if gbar is not None:
            g_snap = vgrad(vr_snapshot, b)
            g = T.tmap(lambda a, c, d: a - c + d, g, g_snap, gbar)
        x_new = T.tmap(
            lambda xx, gg, ss, ll: ops.fused_update(
                xx, gg, ss, ll, _step_for(step_c, xx), rho),
            x, g, x_s_b, lam_s,
        )
        return (x_new, T.tree_add(xsum, x_new)), None

    init = (x0, T.tree_zeros_like(x0))
    if per_step:
        (x_K, xsum), _ = jax.lax.scan(one_step, init, batch)
    else:
        (x_K, xsum), _ = jax.lax.scan(one_step, init, None, length=K)
    return x_K, T.tree_scale(xsum, 1.0 / K)


def inner_steps_arena(spec, grad_fn, x0, x_s_row, lam, batch, *, K, eta, rho,
                      per_step, vr_snapshot=None):
    """Arena counterpart of ``inner_steps``: client state carried as one
    ``(m, width)`` buffer, end to end.

    Gradient oracle resolution (``core.api`` protocol), fastest first:

      1. ``grad_fn.affine_arena`` + the width fits VMEM (and the plain
         full-batch case): the WHOLE K-step loop is ONE fused kernel
         (``kernels/inner_loop.py``) -- 1 HBM read + 1 write of the client
         state for the entire inner loop.
      2. ``grad_fn.grad_arena``: one fused-update kernel per step with the
         gradient evaluated directly on the packed buffer -- 0 boundary
         passes.
      3. plain ``grad_fn``: same scan, paying the unpack->vgrad->pack
         round trip through the model's pytree each step.

    ``eta`` may be a scalar, the per-client tuple (auto-eta), or an
    already-gathered per-cohort row -- array forms ride the kernels as a
    per-client stepsize operand (``kernels/ops``).
    """
    eta = _eta_val(eta)
    step_c = 1.0 / (1.0 / eta + rho)

    affine = affine_case(grad_fn, spec, per_step=per_step, vr_snapshot=vr_snapshot)
    if affine is not None:
        H, c = affine(spec, batch)
        return ops.inner_loop_affine(x0, H, c, x_s_row, lam, step_c, rho, K)

    grad_a, _native = arena_grad(grad_fn, spec)

    gbar = None
    if vr_snapshot is not None:
        assert per_step, "SVRG needs per-step minibatches (K, m, ...)"
        snap_grads = jax.lax.map(lambda b: grad_a(vr_snapshot, b), batch)
        gbar = jnp.mean(snap_grads, axis=0)

    def one_step(carry, xs_k):
        x, xsum = carry
        b = xs_k if per_step else batch
        g = grad_a(x, b)
        if gbar is not None:
            g = g - grad_a(vr_snapshot, b) + gbar
        x_new = ops.fused_update_arena(x, g, x_s_row, lam, step_c, rho)
        return (x_new, xsum + x_new), None

    init = (x0, jnp.zeros_like(x0))
    if per_step:
        (x_K, xsum), _ = jax.lax.scan(one_step, init, batch)
    else:
        (x_K, xsum), _ = jax.lax.scan(one_step, init, None, length=K)
    return x_K, xsum * (1.0 / K)


def participation_key(cfg: FederatedConfig, round_idx):
    """The round's participation RNG key: folded from ``cfg.seed``, so every
    algorithm under comparison draws the SAME mask sequence by contract (the
    old hard-coded key(17) made that an accident of duplication)."""
    return jax.random.fold_in(jax.random.key(cfg.seed), round_idx)


def arena_tail(cfg: FederatedConfig, spec, state, uplink, m):
    """Shared GPDMM/AGPDMM arena round tail: fused EF21 quantise-delta,
    fault injection + uplink screening (core.faults), the combined
    participation/fault/screen select vs the u_hat cache, the single
    client-mean all-reduce, and the fused dual refresh.  Returns
    (state_updates, x_s_new_row, lam_s_new, mask, fault_metrics) -- ``mask``
    is the round's effective active mask (None = every uplink entered the
    mean); demoted and faulted clients are SILENT, full stop, so the round
    is bit-identical to a participation-masked round with the same mask.

    With the bounded-staleness engine on (``faults.async_on``) the select
    against the cache routes through ``staleness.step_arena`` instead:
    delayed rows are buffered, arriving stale rows mix into the cache with
    their discounted weight, and the returned mask additionally excludes
    delayed clients (their carry keeps, like a silent client's)."""
    rho = resolved_rho(cfg)
    new_state = {}
    u_hat = state.get("u_hat")  # arena-resident (m, width) or absent
    if cfg.uplink_bits is not None:  # fused EF21: 2 passes instead of ~4
        uplink = ops.ef21_update(uplink, u_hat, cfg.uplink_bits, spec.leaf_rows())
    # the wire corrupts what was TRANSMITTED, i.e. the EF21-integrated view
    fplan = faults.plan(cfg, state["round"], m)
    uplink = faults.inject(cfg.faults, fplan, uplink)
    pmask = None
    if cfg.participation < 1.0:
        pmask = T.participation_mask(
            participation_key(cfg, state["round"]), m, cfg.participation
        )
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep(cfg, uplink, spec.pack(state["x_s"]))
    mask = faults.combine_mask(pmask, fplan, keep)
    sm = {}
    if faults.async_on(cfg):
        uplink, mask, stale_up, sm = staleness.step_arena(
            cfg, fplan, uplink, u_hat, mask, state)
        new_state |= stale_up
    elif mask is not None:
        uplink = jnp.where(mask[:, None], uplink, u_hat)
    if u_hat is not None:
        new_state["u_hat"] = uplink
    x_s_new = jnp.mean(uplink, axis=0)  # <- the round's single all-reduce
    # fused tail pass 2: lam' = rho (u - x_s'), server row broadcast in-kernel
    lam_s_new = ops.dual_from_uplink(uplink, x_s_new, rho)
    fm = {}
    if fplan is not None or keep is not None:
        tx = faults.combine_mask(pmask, fplan, None)
        if faults.async_on(cfg):
            # delayed clients transmit nothing fresh this round
            tx = staleness.fresh_mask(tx, fplan)
        fm = faults.fault_metrics(fplan, tx, keep) | sm
    return new_state, x_s_new, lam_s_new, mask, fm


def arena_metrics(lam_s_new, x_K, x_s_row, mask=None):
    """KKT-invariant and drift metrics straight off the arena buffers;
    padding columns are identically zero, so no masking is needed there.
    ``client_drift`` averages over the ACTIVE cohort only (``mask``, or all
    rows of ``x_K`` when None -- the cohort path passes its already-gathered
    x_K): silent clients' x_K is computed-then-discarded on the masked path
    (the carry is kept), so averaging it in reported movement that never
    entered the state.  ``used_arena`` records the (static) layout decision
    so benches can see which path a round actually ran."""
    f32 = jnp.float32
    return {
        "lam_sum_norm": jnp.linalg.norm(jnp.sum(lam_s_new.astype(f32), axis=0)),
        "client_drift": T.masked_client_mean(
            jnp.sum(jnp.square((x_K - x_s_row[None]).astype(f32)), axis=1), mask
        ),
        "used_arena": jnp.ones((), f32),
    }


def cohort_tail(cfg: FederatedConfig, spec, state, uplink, idx, fplan=None):
    """Shared GPDMM/AGPDMM cohort round tail (the cohort sibling of
    ``arena_tail``): fused EF21 against the cohort's cached ``u_hat`` rows,
    fault injection + screening on the cohort uplink, the scatter into the
    population cache, the scattered-mean server update (the
    ``(sum_active uplink + sum_silent u_hat) / m`` identity, computed as ONE
    mean over the scattered buffer so it matches the masked path bitwise),
    and the full dual refresh.  Returns ``({u_hat, x_s, lam_s}, keep_c,
    fault_metrics)`` -- ``keep_c`` is the cohort-shaped surviving mask (None
    = the whole cohort's uplink entered the cache).  Note the screening
    median is taken over the COHORT, not the population."""
    rho = resolved_rho(cfg)
    u_hat = state["u_hat"]  # guaranteed: participation < 1 carries the cache
    if cfg.uplink_bits is not None:  # EF21 on the cohort's cached rows only
        uplink = ops.ef21_update(uplink, ops.row_gather(u_hat, idx),
                                 cfg.uplink_bits, spec.leaf_rows())
    plan_c = faults.take(fplan, idx)
    uplink = faults.inject(cfg.faults, plan_c, uplink)
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep(cfg, uplink, spec.pack(state["x_s"]))
    keep_c = faults.combine_mask(None, plan_c, keep)
    if keep_c is not None:
        uplink = jnp.where(keep_c[:, None], uplink, ops.row_gather(u_hat, idx))
    u_hat_new = ops.row_scatter(u_hat, idx, uplink)
    x_s_new = jnp.mean(u_hat_new, axis=0)  # <- the round's single all-reduce
    lam_s_new = ops.dual_from_uplink(u_hat_new, x_s_new, rho)
    fm = {}
    if fplan is not None or keep is not None:
        fm = faults.fault_metrics(
            fplan, None if plan_c is None else ~plan_c.silent, keep)
    return {
        "u_hat": u_hat_new,
        "x_s": spec.unpack(x_s_new),
        "lam_s": lam_s_new,
    }, keep_c, fm


def popstore_tail(cfg: FederatedConfig, spec, x_s_row, u_hat_c, uplink, idx,
                  round_idx, m):
    """Cohort-resident round tail for the HOST-popstore path (shared by
    GPDMM/AGPDMM/FedAvg): identical per-row math to ``cohort_tail`` --
    fused EF21 against the STAGED cohort ``u_hat`` rows (the host store's
    copy of exactly the rows ``cohort_tail`` would ``row_gather``), fault
    injection + screening on the cohort uplink, and the combined keep-select
    back to the staged rows.  What it does NOT do is the O(m) tail: no
    scatter into a device-resident population buffer, no full-buffer mean,
    no dense dual refresh -- the host driver (``core.popstore.Runner``)
    scatters the returned rows into the host store and maintains the server
    mean incrementally.  Returns ``(uplink, keep_c, fault_metrics)``."""
    if cfg.uplink_bits is not None:
        uplink = ops.ef21_update(uplink, u_hat_c, cfg.uplink_bits,
                                 spec.leaf_rows())
    fplan = faults.plan(cfg, round_idx, m)
    plan_c = faults.take(fplan, idx)
    uplink = faults.inject(cfg.faults, plan_c, uplink)
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep(cfg, uplink, x_s_row)
    keep_c = faults.combine_mask(None, plan_c, keep)
    if keep_c is not None:
        # demoted/faulted cohort rows are silent: the store keeps their row
        uplink = jnp.where(keep_c[:, None], uplink, u_hat_c)
    fm = {}
    if fplan is not None or keep is not None:
        fm = faults.fault_metrics(
            fplan, None if plan_c is None else ~plan_c.silent, keep)
    return uplink, keep_c, fm


def popstore_body(cfg: FederatedConfig, spec, m: int, grad_fn, per_step):
    """Device half of a host-popstore GPDMM round (see ``core.popstore``).

    The returned ``body(server, staged, idx, round_idx, batch)`` touches
    ONLY O(cohort) device memory: ``staged`` carries the sampled rows of the
    host store (``u_hat`` -- the server's cached uplink view -- and ``x_c``,
    the primal carry), and the dual rows are reconstructed LAZILY via the
    round invariant lam_{s|i} = rho (u_hat_i - x_s) (``ops.dual_from_uplink``
    on the staged rows -- elementwise, so bit-identical to gathering rows of
    the dense refresh the arena path materialises).  Returns
    ``(rows_out, server_rows, metrics)`` where ``rows_out = {u_hat, x_c}``
    scatters back into the host store."""
    rho = resolved_rho(cfg)
    K = cfg.inner_steps
    f32 = jnp.float32

    eta_v = _eta_val(cfg.eta)
    per_client = np.ndim(eta_v) > 0

    def body(server, staged, idx, round_idx, batch):
        x_s_row = spec.pack(server["x_s"])
        u_hat_c, x0_c = staged["u_hat"], staged["x_c"]
        lam_c = ops.dual_from_uplink(u_hat_c, x_s_row, rho)  # lazy dual
        batch_c = cohort_batch(batch, idx, m, per_step)

        def inner(rows, b):
            x0, lam_t = rows[0], rows[1]
            # per-client eta rides the rows tuple so the cohort tiler slices
            # it alongside the state rows (a closure capture would stay
            # cohort-sized inside a tile-sized call)
            eta_t = rows[2] if per_client else eta_v
            snap = (jnp.broadcast_to(x_s_row[None], x0.shape)
                    if cfg.variance_reduction == "svrg" else None)
            return inner_steps_arena(
                spec, grad_fn, x0, x_s_row, lam_t, b, K=K, eta=eta_t,
                rho=rho, per_step=per_step, vr_snapshot=snap,
            )

        rows = (x0_c, lam_c) + (
            (jnp.asarray(eta_v)[idx],) if per_client else ())
        x_K, x_bar = run_cohort_inner(cfg, inner, rows, batch_c,
                                      per_step=per_step)
        x_ref = x_bar if cfg.use_avg else x_K
        _, uplink = ops.round_tail(x_ref, lam_c, x_s_row, rho,
                                   with_lam_is=False)
        uplink, keep_c, fm = popstore_tail(cfg, spec, x_s_row, u_hat_c,
                                           uplink, idx, round_idx, m)
        x_K_kept = (x_K if keep_c is None
                    else jnp.where(keep_c[:, None], x_K, x0_c))
        metrics = {
            "client_drift": T.masked_client_mean(
                jnp.sum(jnp.square((x_K - x_s_row[None]).astype(f32)),
                        axis=1), keep_c),
            "used_arena": jnp.ones((), f32),
        } | fm
        return {"u_hat": uplink, "x_c": x_K_kept}, {}, metrics

    return body


def _round_arena_cohort(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches):
    """GPDMM round over the SAMPLED COHORT (ISSUE 5): gather the round's
    active rows out of the population arena, run the fused inner loop +
    round tail on the ``(m_active, width)`` cohort buffer (tiled via
    ``cohort_tile`` when set), scatter the updated rows back.  Compute and
    gradient-batch traffic scale with the cohort, not the population; the
    O(m) work that remains is inherent to the algorithm (every client's
    lam_{s|i} moves with the new x_s, and the server mean reads every cached
    u_hat row).

    Row-for-row identical to the masked path: the cohort rows see the same
    per-row kernels, and the server mean is taken over the SCATTERED
    population buffer -- the same mean-of-selected-rows the masked path
    computes, realising (sum_active uplink + sum_silent u_hat) / m without a
    reordered reduction (tests/test_cohort.py pins this per round)."""
    rho = resolved_rho(cfg)
    K = cfg.inner_steps
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    lam, x_c = state["lam_s"], state["x_c"]
    m = lam.shape[0]
    x_s_row = spec.pack(state["x_s"])
    idx, mask = T.cohort_indices(
        participation_key(cfg, state["round"]), m, cfg.participation
    )
    lam_c = ops.row_gather(lam, idx)
    x0_c = ops.row_gather(x_c, idx)
    batch_c = cohort_batch(batch, idx, m, per_step_batches)
    eta_v = _eta_val(cfg.eta)
    per_client = np.ndim(eta_v) > 0

    def inner(rows, b):
        x0, lam_t = rows[0], rows[1]
        eta_t = rows[2] if per_client else eta_v  # tiled with the state rows
        snap = (jnp.broadcast_to(x_s_row[None], x0.shape)
                if cfg.variance_reduction == "svrg" else None)
        return inner_steps_arena(
            spec, grad_fn, x0, x_s_row, lam_t, b, K=K, eta=eta_t, rho=rho,
            per_step=per_step_batches, vr_snapshot=snap,
        )

    rows = (x0_c, lam_c) + ((jnp.asarray(eta_v)[idx],) if per_client else ())
    x_K, x_bar = run_cohort_inner(cfg, inner, rows, batch_c,
                                  per_step=per_step_batches)
    x_ref = x_bar if cfg.use_avg else x_K

    _, uplink = ops.round_tail(x_ref, lam_c, x_s_row, rho, with_lam_is=False)
    fplan = faults.plan(cfg, state["round"], m)
    new_state, keep_c, fm = cohort_tail(cfg, spec, state, uplink, idx, fplan)
    # demoted cohort rows are silent, full stop: the carry keeps its
    # round-start row exactly as a never-sampled client's does
    x_K_kept = x_K if keep_c is None else jnp.where(keep_c[:, None], x_K, x0_c)
    new_state |= {
        "x_c": ops.row_scatter(x_c, idx, x_K_kept),  # silent clients keep carry
        "round": state["round"] + 1,
    }
    return new_state, arena_metrics(new_state["lam_s"], x_K, x_s_row, keep_c) | fm


def _round_arena(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches, return_trace):
    """GPDMM round over the flat arena: the tail is 3 fused kernels + the
    single client-mean all-reduce instead of ~6 per-leaf pytree passes.

    The stacked hot state (lam_s, x_c, u_hat) is arena-RESIDENT: it enters
    and leaves the round as ``(m, width)`` buffers (donated in place by the
    launchers), so the only per-round layout work is packing the
    server-sized x_s row -- 1/m of the state."""
    rho = resolved_rho(cfg)
    K = cfg.inner_steps
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    lam = state["lam_s"]
    x_c = state["x_c"]
    m = lam.shape[0]
    if use_cohort(cfg, m) and not return_trace:
        # trace consumers need the full-population x_K/x_ref stacking, so
        # traced rounds stay on the masked path
        return _round_arena_cohort(cfg, state, grad_fn, batch, per_step_batches)
    x_s_row = spec.pack(state["x_s"])

    snapshot = None
    if cfg.variance_reduction == "svrg":
        snapshot = jnp.broadcast_to(x_s_row[None], x_c.shape)
    x_K, x_bar = inner_steps_arena(
        spec, grad_fn, x_c, x_s_row, lam, batch, K=K, eta=cfg.eta, rho=rho,
        per_step=per_step_batches, vr_snapshot=snapshot,
    )
    x_ref = x_bar if cfg.use_avg else x_K

    # fused tail pass 1: the uplink (and lam_is only when a trace wants it --
    # 3 reads + 1 write on the training path, +1 write with the trace)
    lam_is, uplink = ops.round_tail(x_ref, lam, x_s_row, rho, with_lam_is=return_trace)
    new_state, x_s_new, lam_s_new, mask, fm = arena_tail(cfg, spec, state, uplink, m)

    # silent clients did not really run their inner steps: keep their carry
    x_c_new = x_K if mask is None else jnp.where(mask[:, None], x_K, x_c)
    new_state |= {
        "x_s": spec.unpack(x_s_new),  # server-sized; clients stay packed
        "lam_s": lam_s_new,
        "x_c": x_c_new,
        "round": state["round"] + 1,
    }
    metrics = arena_metrics(lam_s_new, x_K, x_s_row, mask) | fm
    if return_trace:
        metrics["trace"] = {
            "x_ref": spec.unpack_stacked(x_ref),
            "x_bar": spec.unpack_stacked(x_bar),
            "lam_is": spec.unpack_stacked(lam_is),
            "x_K": spec.unpack_stacked(x_K),
        }
    return new_state, metrics


def _round(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches=False, return_trace=False):
    if use_arena(cfg, state["x_s"]):
        return _round_arena(cfg, state, grad_fn, batch, per_step_batches, return_trace)
    rho = resolved_rho(cfg)
    K = cfg.inner_steps
    x_s, lam_s, x_c = state["x_s"], state["lam_s"], state["x_c"]
    m = jax.tree.leaves(lam_s)[0].shape[0]
    x_s_b = T.tree_broadcast(x_s, m)

    x_K, x_bar = inner_steps(
        grad_fn, x_c, x_s_b, lam_s, batch, K=K, eta=cfg.eta, rho=rho,
        per_step=per_step_batches,
        vr_snapshot=x_s_b if cfg.variance_reduction == "svrg" else None,
    )
    x_ref = x_bar if cfg.use_avg else x_K

    lam_is = T.tmap(lambda s, xr, l: rho * (s - xr) - l, x_s_b, x_ref, lam_s)
    uplink = T.tmap(lambda xr, l: xr - l / rho, x_ref, lam_is)
    new_state = {}
    if cfg.uplink_bits is not None:  # beyond-paper: EF21 delta-quantised uplink
        uplink = T.tree_quantize_delta(uplink, state["u_hat"], cfg.uplink_bits)
    # the robustness layer is layout-independent: the same inject ->
    # participation -> screen -> combined-select pipeline as arena_tail
    fplan = faults.plan(cfg, state["round"], m)
    uplink = faults.inject_tree(cfg.faults, fplan, uplink)
    pmask = None
    if cfg.participation < 1.0:  # beyond-paper: async PDMM (partial rounds)
        pmask = T.participation_mask(
            participation_key(cfg, state["round"]), m, cfg.participation
        )
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep_tree(cfg, uplink, x_s)
    mask = faults.combine_mask(pmask, fplan, keep)
    sm = {}
    if faults.async_on(cfg):
        # bounded-staleness engine: delayed rows buffer, arrivals mix
        uplink, mask, stale_up, sm = staleness.step_tree(
            cfg, fplan, uplink, state["u_hat"], mask, state)
        new_state |= stale_up
    elif mask is not None:
        # silent clients transmit nothing; the server keeps its cached view
        uplink = T.tree_select(mask, uplink, state["u_hat"])
    if "u_hat" in state:
        new_state["u_hat"] = uplink  # the server's per-client view
    x_s_new = T.tree_client_mean(uplink)  # <- the round's single all-reduce
    x_s_new_b = T.tree_broadcast(x_s_new, m)
    # lam_{s|i}^{r+1} = rho (x_ref - x_s) - lam_{i|s} == rho (u_i - x_s):
    # reconstructed from the TRANSMITTED uplink, so the quantised variant
    # stays faithful to what a real server would see (it cannot separate
    # x_ref from lam_{i|s} inside u_i).
    lam_s_new = T.tmap(lambda u, s: rho * (u - s), uplink, x_s_new_b)

    # silent clients did not really run their inner steps: keep their carry
    x_c_new = x_K if mask is None else T.tree_select(mask, x_K, x_c)
    new_state |= {"x_s": x_s_new, "lam_s": lam_s_new, "x_c": x_c_new, "round": state["round"] + 1}
    metrics = {
        # KKT invariant (25): sum_i lam_{s|i} == 0 identically
        "lam_sum_norm": T.tree_norm(T.tree_client_sum(lam_s_new)),
        # silent clients keep their carry, so drift averages the ACTIVE set
        "client_drift": T.masked_client_mean(
            T.tree_client_sqnorms(T.tree_sub(x_K, x_s_b)), mask),
        "used_arena": jnp.zeros((), jnp.float32),
    }
    if fplan is not None or keep is not None:
        tx = faults.combine_mask(pmask, fplan, None)
        if faults.async_on(cfg):
            tx = staleness.fresh_mask(tx, fplan)
        metrics |= faults.fault_metrics(fplan, tx, keep) | sm
    if return_trace:  # quantities the convergence-theory checks need
        metrics["trace"] = {"x_ref": x_ref, "x_bar": x_bar, "lam_is": lam_is, "x_K": x_K}
    return new_state, metrics


def make(cfg: FederatedConfig) -> FedOpt:
    def init(params, m):
        if use_arena(cfg, params):
            # arena-resident client state: one (m, width) buffer per stacked
            # tensor, donated in place round over round; x_s stays a pytree
            # (the public server-params contract)
            spec = arena.ArenaSpec.from_tree(params)
            row = spec.pack(params)
            st = {
                "x_s": params,
                "lam_s": arena.zeros(spec, m),
                "x_c": jnp.broadcast_to(row[None], (m, spec.width)),
                "round": jnp.zeros((), jnp.int32),
            }
            if (cfg.uplink_bits is not None or cfg.participation < 1.0
                    or faults.needs_cache(cfg)):
                st["u_hat"] = jnp.broadcast_to(row[None], (m, spec.width))
            if faults.async_on(cfg):
                st |= staleness.init_arena(spec, m)
            return st
        st = {
            "x_s": params,
            "lam_s": T.tree_zeros_like(T.tree_broadcast(params, m)),
            "x_c": T.tree_broadcast(params, m),  # x_i^{0,K} = x_s^1 (Alg. 1)
            "round": jnp.zeros((), jnp.int32),
        }
        if (cfg.uplink_bits is not None or cfg.participation < 1.0
                or faults.needs_cache(cfg)):
            # server's running view of each client's uplink (EF21 integrator /
            # async-PDMM cache / fault-silence fallback); init == round-0
            # uplink x_c - 0/rho.  A fresh broadcast, NOT an alias of x_c:
            # donated round states must not contain the same buffer twice.
            st["u_hat"] = T.tree_broadcast(params, m)
        if faults.async_on(cfg):
            st |= staleness.init_tree(params, m)
        return st

    return FedOpt(
        name="gpdmm",
        init=init,
        round=partial(_round, cfg),
        server_params=lambda s: s["x_s"],
    )
