"""ISSUE 3: arena-native SCAFFOLD/FedAvg + the cross-algorithm conformance
suite.

The paper's headline empirical claim compares AGPDMM against SCAFFOLD, with
the analytical anchor that at K = 1 (under the parameter mapping rho =
1/(K eta), eta_g = 1) AGPDMM, SCAFFOLD, and FedAvg all collapse to vanilla
gradient descent with stepsize eta (paper eqs. (27)/(31)).  This suite
enforces those invariants as ONE parameterised harness instead of ad-hoc
per-algorithm tests:

  * K=1 conformance: every algorithm's trajectory == the explicit GD
    recursion, exact to f32 tolerance for the least-squares oracle, on BOTH
    the arena and pytree paths.
  * Differential parity: SCAFFOLD/FedAvg arena-vs-pytree round equality
    across variants (partial participation via the ``FederatedConfig.seed``
    mask contract; EF21 for FedAvg -- SCAFFOLD's two-variable uplink opts
    out loudly), per-step batches, and the round-batched scan driver.
  * Interpret-mode kernel parity for the NEW kernels: the offset-row fused
    K-step inner loop and the fused SCAFFOLD control-variate round tail.
  * Hypothesis properties (``tests/_hyp`` shim): SCAFFOLD state pack/unpack
    round trips over random leaf shapes/dtypes, and zero-padding
    preservation across a full SCAFFOLD round.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs.base import FederatedConfig
from repro.core import arena, make, make_scan_rounds, quadratic
from repro.core import tree_util as T
from repro.core.scaffold import inner_steps_plain_arena
from repro.kernels import ops

IMPLS = ["xla", "pallas_interpret"]


@pytest.fixture(scope="module", params=[24, 130], ids=["d24", "d130_odd"])
def prob(request):
    # d=24 -> width 128; d=130 -> width 256 with 126 zero-padded columns
    return quadratic.generate(jax.random.key(0), m=6, n=80, d=request.param)


def run_rounds(algo, prob, *, K, use_arena, rounds, eta=None, **cfg_kw):
    eta = eta if eta is not None else 0.5 / prob.L
    opt = make(FederatedConfig(algorithm=algo, inner_steps=K, eta=eta,
                               use_arena=use_arena, **cfg_kw))
    grad = prob.oracle() if use_arena else prob.grad
    s = opt.init(jnp.zeros((prob.d,)), prob.m)
    metrics = None
    for _ in range(rounds):
        s, metrics = opt.round(s, grad, prob.batch())
    return s, metrics


# ---------------------------------------------------------------------------
# K=1 conformance: AGPDMM == SCAFFOLD == FedAvg == vanilla GD (paper (27)/(31))
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_arena", [True, False], ids=["arena", "pytree"])
@pytest.mark.parametrize("algo", ["agpdmm", "scaffold", "fedavg"])
def test_k1_conformance(prob, algo, use_arena):
    """Under the paper's parameter mapping (K=1, rho = 1/eta its default,
    eta_g = 1) every algorithm's server trajectory IS the vanilla-GD
    recursion x <- x - eta mean_i grad f_i(x), checked round by round so a
    drift anywhere in the trajectory (not just at the end) fails."""
    eta = 0.5 / prob.L
    opt = make(FederatedConfig(algorithm=algo, inner_steps=1, eta=eta,
                               use_arena=use_arena))
    grad = prob.oracle() if use_arena else prob.grad
    s = opt.init(jnp.zeros((prob.d,)), prob.m)
    xg = jnp.zeros((prob.d,))
    for r in range(8):
        s, metrics = opt.round(s, grad, prob.batch())
        g = (jnp.einsum("mde,e->d", prob.AtA, xg) - prob.Atb.sum(0)) / prob.m
        xg = xg - eta * g
        np.testing.assert_allclose(
            np.asarray(opt.server_params(s)), np.asarray(xg), atol=5e-5,
            err_msg=f"{algo}/{'arena' if use_arena else 'pytree'} diverges "
                    f"from GD at round {r}")
    assert float(metrics["used_arena"]) == float(use_arena)


def test_k1_all_algorithms_identical(prob):
    """The three K=1 trajectories are identical to EACH OTHER (not merely
    each close to GD), across both layout paths."""
    finals = {}
    for algo in ["agpdmm", "scaffold", "fedavg"]:
        for use_arena in [True, False]:
            s, _ = run_rounds(algo, prob, K=1, use_arena=use_arena, rounds=8)
            finals[(algo, use_arena)] = np.asarray(s["x_s"])
    ref = finals[("agpdmm", True)]
    for key, got in finals.items():
        np.testing.assert_allclose(got, ref, atol=5e-5, err_msg=str(key))


def test_k1_collapse_needs_the_parameter_mapping(prob):
    """Negative control: off the paper's mapping (eta_g != 1) SCAFFOLD does
    NOT reduce to GD -- the conformance above is a real constraint, not a
    tolerance accident."""
    s_gd, _ = run_rounds("fedavg", prob, K=1, use_arena=False, rounds=8)
    s_off, _ = run_rounds("scaffold", prob, K=1, use_arena=False, rounds=8,
                          eta_g=0.5)
    assert not np.allclose(np.asarray(s_off["x_s"]), np.asarray(s_gd["x_s"]),
                           atol=5e-5)


# ---------------------------------------------------------------------------
# differential parity: arena path == pytree path for SCAFFOLD/FedAvg
# ---------------------------------------------------------------------------

SCAFFOLD_VARIANTS = {"plain": {}, "partial": {"participation": 0.5},
                     "server_lr": {"eta_g": 0.7}}
FEDAVG_VARIANTS = {"plain": {}, "partial": {"participation": 0.5},
                   "ef21": {"uplink_bits": 8},
                   "ef21+partial": {"uplink_bits": 8, "participation": 0.5}}


def _assert_state_parity(algo, variant, prob, sa, sp, ma, mp):
    assert set(sa) == set(sp)
    spec = arena.ArenaSpec.from_tree(sp["x_s"])
    for k in sorted(sa):
        got, want = sa[k], sp[k]
        if k not in ("x_s", "c", "round"):  # arena keeps clients packed
            want = spec.pack_stacked(want)
        # c_i amplifies inner-loop f32 noise by 1/(K eta) ~ O(L), so the
        # cross-path tolerance is 1e-4 (x_s itself agrees to ~1e-7; the K=1
        # conformance suite pins exactness where the paper claims it)
        got_l, want_l = jax.tree.leaves(got), jax.tree.leaves(want)
        assert len(got_l) == len(want_l), f"{algo}/{variant}: state[{k}]"
        for i, (gl, wl) in enumerate(zip(got_l, want_l)):
            np.testing.assert_allclose(
                np.asarray(gl), np.asarray(wl), atol=1e-4, rtol=1e-4,
                err_msg=f"{algo}/{variant}: state[{k}] leaf {i}")
    for k in ma:
        if k == "used_arena":  # records the layout decision: differs by design
            continue
        np.testing.assert_allclose(float(ma[k]), float(mp[k]), atol=1e-4,
                                   err_msg=f"{algo}/{variant}: metrics[{k}]")


@pytest.mark.parametrize("variant", sorted(SCAFFOLD_VARIANTS))
def test_scaffold_round_parity_arena_vs_pytree(prob, variant):
    kw = SCAFFOLD_VARIANTS[variant]
    sa, ma = run_rounds("scaffold", prob, K=3, use_arena=True, rounds=5, **kw)
    sp, mp = run_rounds("scaffold", prob, K=3, use_arena=False, rounds=5, **kw)
    _assert_state_parity("scaffold", variant, prob, sa, sp, ma, mp)


@pytest.mark.parametrize("variant", sorted(FEDAVG_VARIANTS))
def test_fedavg_round_parity_arena_vs_pytree(prob, variant):
    kw = FEDAVG_VARIANTS[variant]
    sa, ma = run_rounds("fedavg", prob, K=3, use_arena=True, rounds=5, **kw)
    sp, mp = run_rounds("fedavg", prob, K=3, use_arena=False, rounds=5, **kw)
    _assert_state_parity("fedavg", variant, prob, sa, sp, ma, mp)


def test_scaffold_seed_mask_contract(prob):
    """Partial-participation SCAFFOLD draws the SAME mask sequence as GPDMM
    under the same seed (the cross-algorithm contract): same seed -> bitwise
    identical repeat runs, different seed -> different rounds."""
    finals = []
    for seed in (3, 3, 9):
        s, _ = run_rounds("scaffold", prob, K=2, use_arena=True, rounds=3,
                          participation=0.5, seed=seed)
        finals.append(np.asarray(s["x_s"]))
    np.testing.assert_array_equal(finals[0], finals[1])
    assert not np.allclose(finals[0], finals[2])


def test_scaffold_rejects_ef21():
    with pytest.raises(NotImplementedError, match="two coupled variables"):
        make(FederatedConfig(algorithm="scaffold", uplink_bits=8))


@pytest.mark.parametrize("algo", ["scaffold", "fedavg"])
def test_per_step_batches_parity(prob, algo):
    """Per-step minibatches (the softmax-regression setup) run the scan path
    on the arena; states still match the pytree path."""
    K = 3
    batch = {"AtA": jnp.broadcast_to(prob.AtA[None], (K,) + prob.AtA.shape),
             "Atb": jnp.broadcast_to(prob.Atb[None], (K,) + prob.Atb.shape)}
    outs = {}
    for use_arena in [True, False]:
        opt = make(FederatedConfig(algorithm=algo, inner_steps=K,
                                   eta=0.5 / prob.L, use_arena=use_arena))
        grad = prob.oracle() if use_arena else prob.grad
        s = opt.init(jnp.zeros((prob.d,)), prob.m)
        for _ in range(3):
            s, _ = opt.round(s, grad, batch, per_step_batches=True)
        outs[use_arena] = np.asarray(s["x_s"])
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("algo", ["scaffold", "fedavg"])
@pytest.mark.parametrize("variant", [{}, {"participation": 0.5}],
                         ids=["plain", "partial"])
def test_scan_rounds_equals_loop(prob, algo, variant):
    """R rounds inside one lax.scan land on the SAME state as R separate
    round calls (incl. the round-counter-folded participation RNG) -- the
    rounds_per_call driver contract, now for SCAFFOLD/FedAvg."""
    R = 4
    opt = make(FederatedConfig(algorithm=algo, inner_steps=2, eta=0.5 / prob.L,
                               use_arena=True, **variant))
    grad = prob.oracle()
    batch = prob.batch()
    s_loop = opt.init(jnp.zeros((prob.d,)), prob.m)
    for _ in range(R):
        s_loop, _ = opt.round(s_loop, grad, batch)
    scan = make_scan_rounds(opt, grad)
    batches = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), batch)
    s_scan, stacked = scan(opt.init(jnp.zeros((prob.d,)), prob.m), batches)
    for k in s_loop:
        for i, (gl, wl) in enumerate(zip(jax.tree.leaves(s_scan[k]),
                                         jax.tree.leaves(s_loop[k]))):
            np.testing.assert_allclose(
                np.asarray(gl), np.asarray(wl),
                atol=1e-4, rtol=1e-4, err_msg=f"state[{k}] leaf {i}")
    assert all(np.asarray(v).shape[0] == R for v in jax.tree.leaves(stacked))


# ---------------------------------------------------------------------------
# interpret-mode kernel parity: the offset inner loop + control-variate tail
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("K", [1, 4])
def test_inner_loop_offset_parity(prob, impl, K):
    """The fused K-step kernel with the per-client offset row reproduces the
    step-at-a-time recursion x <- x - eta (grad - c_i + c), padding
    included, for both backends."""
    m, d = prob.m, prob.d
    eta = 0.5 / prob.L
    spec = arena.ArenaSpec.from_tree(jnp.zeros((d,)))
    w = spec.width
    key = jax.random.key(1)
    pad = lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, w - d)])
    x0 = pad(jax.random.normal(jax.random.fold_in(key, 0), (m, d)))
    c_i = pad(0.1 * jax.random.normal(jax.random.fold_in(key, 1), (m, d)))
    xs = pad(jax.random.normal(jax.random.fold_in(key, 2), (d,)))
    c_row = pad(0.05 * jax.random.normal(jax.random.fold_in(key, 3), (d,)))
    oracle = prob.oracle()
    H, c = oracle.affine_arena(spec, prob.batch())

    x_K, x_bar = ops.inner_loop_affine(
        x0, H, c - c_row[None], xs, None, eta, 0.0, K, off=c_i, impl=impl)

    x = x0
    xsum = jnp.zeros_like(x0)
    for _ in range(K):
        g = jnp.einsum("mij,mj->mi", H, x) - c  # the TRUE gradient
        x = x - eta * (g - c_i + c_row[None])
        xsum = xsum + x
    for got, want, name in [(x_K, x, "x_K"), (x_bar, xsum / K, "x_bar")]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4, err_msg=name)
        assert np.all(np.asarray(got)[:, d:] == 0.0), name


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scaffold_cv_kernel_parity(impl, dtype):
    """Fused c-aggregation tail == the per-leaf tmap reference, odd leaf
    sizes and both dtypes, with the server rows broadcast in-kernel."""
    m, alpha = 5, 2.5
    shapes = {"a": (7,), "b": {"w": (3, 50), "s": ()}, "c": (130,)}
    ks = iter(jax.random.split(jax.random.key(2), 16))

    def mk(lead):
        return jax.tree.map(
            lambda sh: jax.random.normal(next(ks), lead + sh).astype(dtype),
            shapes, is_leaf=lambda x: isinstance(x, tuple))

    ci_t, xk_t = mk((m,)), mk((m,))
    c_t, xs_t = mk(()), mk(())
    spec = arena.ArenaSpec.from_tree(c_t)
    ref = T.tmap(lambda ci, cc, s, xk: (ci - cc + alpha * (s - xk)).astype(dtype),
                 ci_t, T.tree_broadcast(c_t, m), T.tree_broadcast(xs_t, m), xk_t)
    got = ops.scaffold_cv(spec.pack_stacked(ci_t), spec.pack_stacked(xk_t),
                          spec.pack(c_t), spec.pack(xs_t), alpha, impl=impl)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(spec.pack_stacked(ref), np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("impl", IMPLS)
def test_fused_update_arena_nolam_parity(impl):
    """lam=None drops the dual operand on the arena-wide fused step (the
    SCAFFOLD/FedAvg rho = 0 inner step): same math as lam = 0."""
    k = jax.random.key(3)
    x = jax.random.normal(jax.random.fold_in(k, 0), (4, 256))
    g = 0.3 * x
    xs = jax.random.normal(jax.random.fold_in(k, 1), (256,))
    out = ops.fused_update_arena(x, g, xs, None, 0.05, 2.0, impl=impl)
    exp = np.asarray(x) - 0.05 * (np.asarray(g) + 2.0 * (np.asarray(x) - np.asarray(xs)[None]))
    np.testing.assert_allclose(np.asarray(out), exp, atol=1e-5, rtol=1e-5)


def test_offset_inner_loop_falls_back_past_vmem():
    """A width past the fused-kernel VMEM budget must take the scan path --
    the resolution helper returns the same states either way."""
    from repro.kernels.inner_loop import fits_vmem
    d = 2048
    assert not fits_vmem(d)
    spec = arena.ArenaSpec.from_tree(jnp.zeros((d,)))

    def plain(x, _b):
        return 0.3 * x

    from repro.core.api import make_oracle
    oracle = make_oracle(plain, grad_arena=lambda spec: (lambda xa, b: 0.3 * xa),
                         affine_arena=lambda spec, b: (None, None))  # must not be called
    x0 = jnp.ones((3, spec.width))
    xs = jnp.zeros((spec.width,))
    x_K = inner_steps_plain_arena(spec, oracle, x0, xs, {"d": jnp.zeros((3, 1))},
                                  K=2, eta=0.1, per_step=False)
    x = x0
    for _ in range(2):
        x = x - 0.1 * 0.3 * x
    np.testing.assert_allclose(np.asarray(x_K), np.asarray(x), atol=1e-6)


# ---------------------------------------------------------------------------
# hypothesis properties: SCAFFOLD state pack/unpack + padding preservation
# ---------------------------------------------------------------------------

@st.composite
def _param_trees(draw):
    n_leaves = draw(st.integers(1, 3))
    dtype = draw(st.sampled_from(["float32", "bfloat16"]))
    tree = {}
    for i in range(n_leaves):
        shape = tuple(draw(st.lists(st.integers(1, 9), min_size=1, max_size=2)))
        tree[f"w{i}"] = (float(i + 1) * jnp.ones(shape)).astype(dtype)
    return tree


@settings(max_examples=10, deadline=None)
@given(params=_param_trees(), m=st.integers(2, 4))
def test_scaffold_state_pack_roundtrip(params, m):
    """Arena pack/unpack round-trips every tensor of a SCAFFOLD state dict
    (server x_s/c rows, stacked c_i) for random leaf shapes/dtypes."""
    spec = arena.ArenaSpec.from_tree(params)
    opt = make(FederatedConfig(algorithm="scaffold", use_arena=True))
    s = opt.init(params, m)
    assert s["c_i"].shape == (m, spec.width)
    for tree in (s["x_s"], s["c"]):
        back = spec.unpack(spec.pack(tree))
        assert jax.tree.structure(back) == jax.tree.structure(tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
    stacked = spec.unpack_stacked(s["c_i"])
    np.testing.assert_array_equal(
        np.asarray(spec.pack_stacked(stacked)), np.asarray(s["c_i"]))


@settings(max_examples=10, deadline=None)
@given(params=_param_trees(), m=st.integers(2, 4), k=st.integers(1, 3))
def test_scaffold_round_preserves_padding(params, m, k):
    """Zero-padding columns of every arena-resident SCAFFOLD buffer stay
    identically zero across a full round (the invariant that makes norms and
    sums over arena buffers mask-free)."""
    if len({leaf.dtype for leaf in jax.tree.leaves(params)}) > 1:
        return  # mixed-dtype trees fall back to the pytree path by design
    spec = arena.ArenaSpec.from_tree(params)
    pad_mask = np.ones((spec.width,), bool)
    for e in spec.leaves:
        pad_mask[e.offset:e.offset + e.size] = False

    def grad_fn(p, _b):
        return jax.tree.map(lambda x: 0.3 * x, p)

    opt = make(FederatedConfig(algorithm="scaffold", inner_steps=k, eta=0.1,
                               use_arena=True))
    s = opt.init(params, m)
    s, _ = opt.round(s, grad_fn, {"dummy": jnp.zeros((m, 1))})
    assert np.all(np.asarray(s["c_i"], np.float32)[:, pad_mask] == 0.0)
    assert np.all(np.asarray(spec.pack(s["x_s"]), np.float32)[pad_mask] == 0.0)
    assert np.all(np.asarray(spec.pack(s["c"]), np.float32)[pad_mask] == 0.0)


# ---------------------------------------------------------------------------
# SCAFFOLD+EF21 contract (ISSUE 4 satellite): rejected loudly, with the two
# coupled uplink variables named -- pinned so the message can't silently rot
# ---------------------------------------------------------------------------

def test_scaffold_ef21_rejection_names_coupled_uplinks():
    with pytest.raises(NotImplementedError) as exc:
        make(FederatedConfig(algorithm="scaffold", uplink_bits=8))
    msg = str(exc.value)
    # the two coupled uplink variables, by name
    assert "dx_i = x_i^{r,K} - x_s^r" in msg
    assert "dc_i = c_i^{r+1} - c_i^r" in msg
    # and the actionable way out
    assert "gpdmm" in msg and "uplink_bits" in msg
