"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit included via warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def time_round_donated(round_fn, state, iters: int = 5, warmup: int = 2) -> float:
    """Median us/round of a donated steady-state round chain: the state is
    consumed and rebound every call (``state = fn(state)``), exactly how the
    launchers drive rounds.  Donation is what lets in-place updates (the
    cohort engine's row scatter) actually alias instead of copying the
    population buffer -- ``time_fn`` cannot donate because it re-passes the
    same arguments."""
    fn = jax.jit(round_fn, donate_argnums=(0,))
    for _ in range(warmup):
        state = fn(state)
    state = jax.block_until_ready(state)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state = jax.block_until_ready(fn(state))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
