"""ISSUE 5: the cohort-sampled round engine + the partial-participation /
CLI / checkpoint bug sweep.

Conformance contract: with ``participation < 1`` the cohort engine gathers
the round's active rows, runs the fused inner loop / round tail on the
``(m_active, width)`` cohort buffer, and scatters back -- and the resulting
round must equal the masked full-population round, round by round at f32,
for all four arena algorithms x {plain, EF21 where supported}, against BOTH
the masked arena path and the per-leaf pytree path.  Plus:

  * ``cohort_tile`` (lax.map tiling) parity with the one-shot cohort round;
  * externally produced cohort-sized batches (rows sorted by client id, the
    ``data.synthetic.cohort_lm_batches`` contract) == engine-gathered
    population batches;
  * interpret-mode parity for the row gather/scatter kernels;
  * the drift-metric bugfix (silent clients' discarded x_K no longer
    pollutes ``client_drift``);
  * hypothesis round-trips for full-state checkpoints of arena states
    (bf16 leaves, scalars, round counter) and the train launcher's
    save-at-r / --resume == uninterrupted continuation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro import checkpoint as ckpt
from repro.configs.base import FederatedConfig
from repro.core import make, quadratic
from repro.core import tree_util as T
from repro.core.api import cohort_batch, map_cohort_tiles, use_cohort
from repro.core.gpdmm import participation_key
from repro.data import synthetic
from repro.kernels import ops

M = 8


@pytest.fixture(scope="module", params=[24, 130], ids=["d24", "d130_odd"])
def prob(request):
    # d=24 -> width 128; d=130 -> width 256 with 126 zero-padded columns
    return quadratic.generate(jax.random.key(0), m=M, n=60, d=request.param)


def _assert_state_close(a, b, *, msg, rtol=1e-5):
    la, paths = jax.tree.flatten(a)[0], jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree.leaves(b)
    for (path, xa), xb in zip(paths, lb):
        xa = np.asarray(xa, np.float32)
        xb = np.asarray(xb, np.float32)
        # rho-amplified duals / 1/(K eta)-scaled variates carry large
        # magnitudes: compare at f32 resolution relative to the buffer scale
        scale = max(1.0, float(np.abs(xa).max()))
        np.testing.assert_allclose(
            xa / scale, xb / scale, atol=rtol,
            err_msg=f"{msg}: {jax.tree_util.keystr(path)}")


def _run(algo, prob, *, rounds, participation, cohort, use_arena=True,
         cohort_tile=None, K=3, **cfg_kw):
    cfg = FederatedConfig(
        algorithm=algo, inner_steps=K, eta=0.3 / prob.L, use_arena=use_arena,
        participation=participation, cohort=cohort, cohort_tile=cohort_tile,
        **cfg_kw)
    opt = make(cfg)
    grad = prob.oracle() if use_arena else prob.grad
    s = opt.init(jnp.zeros((prob.d,)), prob.m)
    states, metrics = [], None
    for _ in range(rounds):
        s, metrics = opt.round(s, grad, prob.batch())
        states.append(s)
    return states, metrics


# ---------------------------------------------------------------------------
# tentpole conformance: cohort round == masked round, round by round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("participation", [0.5, 0.25], ids=["p50", "p25"])
@pytest.mark.parametrize("variant", ["plain", "ef21"])
@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm", "scaffold", "fedavg"])
def test_cohort_matches_masked_arena(prob, algo, variant, participation):
    if variant == "ef21" and algo == "scaffold":
        pytest.skip("SCAFFOLD+EF21 rejected by core.scaffold (two-variable uplink)")
    kw = {"uplink_bits": 8} if variant == "ef21" else {}
    masked, _ = _run(algo, prob, rounds=5, participation=participation,
                     cohort=False, **kw)
    cohort, _ = _run(algo, prob, rounds=5, participation=participation,
                     cohort=True, **kw)
    for r, (sm, sc) in enumerate(zip(masked, cohort)):
        _assert_state_close(
            sm, sc, msg=f"{algo}/{variant}/p{participation} round {r}")


@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm", "scaffold", "fedavg"])
def test_cohort_matches_pytree_masked(prob, algo):
    """Cross-path: the cohort-arena trajectory equals the per-leaf PYTREE
    masked trajectory too (the seed contract draws identical masks)."""
    pyt, _ = _run(algo, prob, rounds=5, participation=0.5, cohort=False,
                  use_arena=False)
    coh, _ = _run(algo, prob, rounds=5, participation=0.5, cohort=True)
    for r, (sp, sc) in enumerate(zip(pyt, coh)):
        np.testing.assert_allclose(
            np.asarray(sp["x_s"], np.float32), np.asarray(sc["x_s"], np.float32),
            atol=2e-5, err_msg=f"{algo} pytree-vs-cohort x_s at round {r}")


@pytest.mark.parametrize("tile", [1, 2])
@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm", "scaffold", "fedavg"])
def test_cohort_tile_parity(prob, algo, tile):
    """lax.map tiling of the cohort inner loop is a pure scheduling choice:
    state-identical to the one-shot cohort round."""
    one, _ = _run(algo, prob, rounds=3, participation=0.5, cohort=True)
    tiled, _ = _run(algo, prob, rounds=3, participation=0.5, cohort=True,
                    cohort_tile=tile)
    for r, (sa, sb) in enumerate(zip(one, tiled)):
        _assert_state_close(sa, sb, msg=f"{algo} tile={tile} round {r}")


def test_cohort_tile_must_divide(prob):
    with pytest.raises(ValueError, match="divide"):
        _run("gpdmm", prob, rounds=1, participation=0.5, cohort=True,
             cohort_tile=3)  # cohort of 4


def test_cohort_per_step_batches(prob):
    """Per-step (K, m, ...) minibatches gather on axis 1."""
    K = 3
    cfg = dict(rounds=3, participation=0.5, K=K)
    batch = {"AtA": jnp.broadcast_to(prob.AtA[None], (K,) + prob.AtA.shape),
             "Atb": jnp.broadcast_to(prob.Atb[None], (K,) + prob.Atb.shape)}

    def run(cohort, tile=None):
        opt = make(FederatedConfig(
            algorithm="gpdmm", inner_steps=K, eta=0.3 / prob.L, use_arena=True,
            participation=0.5, cohort=cohort, cohort_tile=tile))
        s = opt.init(jnp.zeros((prob.d,)), prob.m)
        out = []
        for _ in range(3):
            s, _ = opt.round(s, prob.oracle(), batch, per_step_batches=True)
            out.append(s)
        return out

    for tile in [None, 2]:
        for r, (sm, sc) in enumerate(zip(run(False), run(True, tile))):
            _assert_state_close(sm, sc, msg=f"per-step tile={tile} round {r}")


def test_external_cohort_sized_batches(prob):
    """A data stream that only materialises the active cohort's rows (sorted
    by client id -- the cohort_lm_batches contract) produces the same
    trajectory as handing the engine the full population batch."""
    opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=2,
                               eta=0.3 / prob.L, use_arena=True,
                               participation=0.5, cohort=True))
    cfg = FederatedConfig(participation=0.5)
    s_full = opt.init(jnp.zeros((prob.d,)), prob.m)
    s_coh = opt.init(jnp.zeros((prob.d,)), prob.m)
    for r in range(4):
        idx, _ = T.cohort_indices(participation_key(cfg, jnp.int32(r)), M, 0.5)
        small = jax.tree.map(lambda x: x[idx], prob.batch())
        s_full, _ = opt.round(s_full, prob.oracle(), prob.batch())
        s_coh, _ = opt.round(s_coh, prob.oracle(), small)
        _assert_state_close(s_full, s_coh, msg=f"external cohort batch round {r}")


def test_full_participation_keeps_population_path(prob):
    """participation=1 (or cohort=False) never touches the gather/scatter
    engine; cohort='auto' at a cohort == population also stays masked."""
    assert not use_cohort(FederatedConfig(participation=1.0), M)
    assert not use_cohort(FederatedConfig(participation=0.5, cohort=False), M)
    # ceil(0.95 * 8) = 8 == m -> auto backs off, True forces
    assert not use_cohort(FederatedConfig(participation=0.95), M)
    assert use_cohort(FederatedConfig(participation=0.95, cohort=True), M)
    assert use_cohort(FederatedConfig(participation=0.25), M)


def test_cohort_engine_is_scoped_to_its_algorithms(prob):
    """Algorithms without a cohort round (fedsplit, the graph subsystem)
    must never see cohort-sized batches from the launchers -- use_cohort is
    the single predicate both consult, so the guard lives there.  A fedsplit
    partial round with FULL batches keeps working exactly as before."""
    for algo in ["fedsplit", "pdmm_graph", "gpdmm_graph"]:
        assert not use_cohort(
            FederatedConfig(algorithm=algo, participation=0.5), M), algo
    # gpdmm rerouted onto a non-star topology: graph firing, no cohort
    assert not use_cohort(
        FederatedConfig(algorithm="gpdmm", topology="ring",
                        participation=0.5), M)
    # previously-working configuration: fedsplit + participation < 1 with
    # population-sized batches (fedsplit ignores the mask; it must not crash)
    opt = make(FederatedConfig(algorithm="fedsplit", inner_steps=2,
                               eta=0.3 / prob.L, participation=0.5))
    s = opt.init(jnp.zeros((prob.d,)), prob.m)
    s, _ = opt.round(s, prob.oracle(), prob.batch())
    assert np.all(np.isfinite(np.asarray(s["x_s"])))


def test_cohort_knob_validation():
    with pytest.raises(ValueError, match="participation"):
        FederatedConfig(participation=0.0)
    with pytest.raises(ValueError, match="cohort"):
        FederatedConfig(cohort="sometimes")
    with pytest.raises(ValueError, match="cohort_tile"):
        FederatedConfig(cohort_tile=0)


# ---------------------------------------------------------------------------
# cohort plumbing units
# ---------------------------------------------------------------------------

def test_cohort_indices_match_mask_contract():
    key = jax.random.key(3)
    for frac in [0.1, 0.25, 0.5, 0.9]:
        idx, mask = T.cohort_indices(key, 16, frac)
        ref = T.participation_mask(key, 16, frac)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(ref))
        assert idx.shape[0] == T.cohort_count(16, frac)
        ids = np.asarray(idx)
        assert sorted(ids.tolist()) == ids.tolist(), "indices must be sorted"
        assert np.asarray(ref)[ids].all()


def test_cohort_batch_gather_and_passthrough():
    idx = jnp.asarray([1, 3], jnp.int32)
    pop = {"a": jnp.arange(4 * 3, dtype=jnp.float32).reshape(4, 3)}
    got = cohort_batch(pop, idx, 4, False)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(pop["a"][idx]))
    small = {"a": pop["a"][idx]}
    passed = cohort_batch(small, idx, 4, False)
    assert passed["a"] is small["a"]  # already cohort-sized: untouched
    per_step = {"a": jnp.stack([pop["a"], pop["a"] + 1.0])}  # (K=2, m, 3)
    got_ps = cohort_batch(per_step, idx, 4, True)
    np.testing.assert_array_equal(
        np.asarray(got_ps["a"]), np.asarray(per_step["a"][:, idx]))
    with pytest.raises(ValueError, match="client dim"):
        cohort_batch({"a": jnp.zeros((5, 3))}, idx, 4, False)


def test_map_cohort_tiles_matches_direct():
    rows = jnp.arange(6 * 4, dtype=jnp.float32).reshape(6, 4)
    batch = {"b": jnp.arange(6.0)}

    def fn(r, b):
        (x,) = r
        return {"y": x * 2.0 + b["b"][:, None], "z": jnp.flip(x, axis=1)}

    direct = fn((rows,), batch)
    tiled = map_cohort_tiles(2, fn, (rows,), batch)
    for k in direct:
        np.testing.assert_array_equal(np.asarray(tiled[k]), np.asarray(direct[k]))
    # per-step batches tile on axis 1
    ps = {"b": jnp.arange(2.0 * 6).reshape(2, 6)}

    def fn_ps(r, b):
        (x,) = r
        return x + b["b"].sum(0)[:, None]

    np.testing.assert_array_equal(
        np.asarray(map_cohort_tiles(3, fn_ps, (rows,), ps, per_step=True)),
        np.asarray(fn_ps((rows,), ps)))


@pytest.mark.parametrize("width", [128, 384])
def test_row_gather_scatter_interpret_parity(width):
    """The Pallas cohort-movement kernels == the XLA reference (interpret
    mode on CPU), including non-trivial block tiling."""
    k = jax.random.key(0)
    arr = jax.random.normal(k, (7, width))
    idx = jnp.asarray([6, 0, 3], jnp.int32)
    rows = jax.random.normal(jax.random.fold_in(k, 1), (3, width))
    np.testing.assert_array_equal(
        np.asarray(ops.row_gather(arr, idx, impl="pallas_interpret")),
        np.asarray(ops.row_gather(arr, idx, impl="xla")))
    np.testing.assert_array_equal(
        np.asarray(ops.row_scatter(arr, idx, rows, impl="pallas_interpret")),
        np.asarray(ops.row_scatter(arr, idx, rows, impl="xla")))


# ---------------------------------------------------------------------------
# satellite: the drift metric averages the ACTIVE cohort only
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_arena", [True, False], ids=["arena", "pytree"])
def test_client_drift_ignores_silent_clients(prob, use_arena):
    """Silent clients' x_K is computed-then-discarded (carry kept), so the
    logged drift must equal the mean over the ACTIVE set alone -- pinned by
    recomputing it from the round's trace and mask directly."""
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=2, eta=0.3 / prob.L,
                          use_arena=use_arena, participation=0.5, cohort=False)
    opt = make(cfg)
    grad = prob.oracle() if use_arena else prob.grad
    s = opt.init(jnp.zeros((prob.d,)), prob.m)
    for r in range(3):
        x_s_prev = np.asarray(s["x_s"], np.float32)
        mask = np.asarray(T.participation_mask(
            participation_key(cfg, s["round"]), prob.m, 0.5))
        s, metrics = opt.round(s, grad, prob.batch(), False, True)
        x_K = np.asarray(metrics["trace"]["x_K"], np.float32)
        per_client = np.square(x_K - x_s_prev[None]).sum(axis=1)
        want = per_client[mask].mean()
        np.testing.assert_allclose(float(metrics["client_drift"]), want,
                                   rtol=1e-5, err_msg=f"round {r}")
        # the buggy all-clients mean genuinely differs here (real regression)
        assert abs(per_client.mean() - want) > 0
    # direct unit check of the masked mean itself
    vals = jnp.asarray([1.0, 10.0, 100.0, 1000.0])
    mask = jnp.asarray([True, False, True, False])
    assert float(T.masked_client_mean(vals, mask)) == pytest.approx(50.5)
    assert float(T.masked_client_mean(vals, None)) == pytest.approx(277.75)


def test_cohort_and_masked_drift_agree(prob):
    """The masked path's (fixed) active-mean drift == the cohort path's
    drift over its gathered rows, round by round."""
    _, m_masked = _run("gpdmm", prob, rounds=4, participation=0.25, cohort=False)
    _, m_cohort = _run("gpdmm", prob, rounds=4, participation=0.25, cohort=True)
    np.testing.assert_allclose(
        float(m_masked["client_drift"]), float(m_cohort["client_drift"]),
        rtol=1e-4)


# ---------------------------------------------------------------------------
# satellite: full-state checkpointing (hypothesis round-trip + train resume)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 6),
    rows=st.integers(1, 3),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    rounds=st.integers(0, 1_000_000),
    seed=st.integers(0, 2**31 - 1),
)
def test_checkpoint_arena_state_roundtrip(tmp_path_factory, m, rows, dtype,
                                          rounds, seed):
    """Arena-shaped fed states (bf16/f32 (m, width) buffers, server pytrees,
    int round counters) survive save/load BIT-exactly -- dtypes, shapes,
    values, and python scalars."""
    width = rows * 128
    k = jax.random.key(seed)
    state = {
        "x_s": {"w": jax.random.normal(k, (37,)),
                "b": jax.random.normal(jax.random.fold_in(k, 1), (5, 3))},
        "lam_s": jax.random.normal(jax.random.fold_in(k, 2), (m, width)).astype(dtype),
        "u_hat": jax.random.normal(jax.random.fold_in(k, 3), (m, width)).astype(dtype),
        "round": jnp.asarray(rounds, jnp.int32),
    }
    d = tmp_path_factory.mktemp("ckpt")
    ckpt.save(d, 1, {"fed_state": state, "round": rounds})
    back = ckpt.load(d, 1)
    assert back["round"] == rounds
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(state),
                            jax.tree.leaves(back["fed_state"])):
        assert a.dtype == b.dtype, path
        assert a.shape == b.shape, path
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            err_msg=jax.tree_util.keystr(path))


def test_train_resume_equals_uninterrupted(tmp_path):
    """The launcher bugfix pinned end to end: save the FULL fed state at
    round 3, --resume to 6, and the final state equals the uninterrupted
    6-round run at f32 (bitwise on CPU: same program, same data keys)."""
    from repro.launch.train import run as train_run

    d1, d2 = tmp_path / "a", tmp_path / "b"
    kw = dict(reduced=True, algorithm="gpdmm", k=1, eta=0.05, m=2,
              per_client_batch=2, seq_len=32, log_every=2)
    train_run("olmo-1b", steps=3, ckpt_dir=str(d1), **kw)
    assert int(ckpt.load(d1)["round"]) == 3
    train_run("olmo-1b", steps=6, ckpt_dir=str(d1), resume=True, **kw)
    train_run("olmo-1b", steps=6, ckpt_dir=str(d2), **kw)
    a, b = ckpt.load(d1), ckpt.load(d2)
    assert int(a["round"]) == int(b["round"]) == 6
    for (path, la), lb in zip(jax.tree_util.tree_leaves_with_path(a["fed_state"]),
                              jax.tree.leaves(b["fed_state"])):
        np.testing.assert_allclose(
            np.asarray(la, np.float32), np.asarray(lb, np.float32),
            atol=1e-6, err_msg=f"resume diverged at {jax.tree_util.keystr(path)}")


def test_train_resume_requires_checkpoint(tmp_path):
    from repro.launch.train import run as train_run

    with pytest.raises(FileNotFoundError):
        train_run("olmo-1b", steps=2, ckpt_dir=str(tmp_path / "none"),
                  resume=True, reduced=True, m=2, per_client_batch=2,
                  seq_len=32)
    with pytest.raises(ValueError, match="ckpt-dir"):
        train_run("olmo-1b", steps=2, resume=True, reduced=True, m=2,
                  per_client_batch=2, seq_len=32)


def test_train_resume_rejects_bad_checkpoints(tmp_path):
    """Old server-only checkpoints and hyper-parameter mismatches must fail
    loudly -- silently 'resuming' a different trajectory is the bug class
    this satellite fixes."""
    from repro.launch.train import run as train_run

    old = tmp_path / "old"
    ckpt.save(old, 3, {"server": {"w": jnp.zeros((4,))}})  # pre-ISSUE-5 format
    with pytest.raises(ValueError, match="fed_state"):
        train_run("olmo-1b", steps=6, ckpt_dir=str(old), resume=True,
                  reduced=True, m=2, per_client_batch=2, seq_len=32)
    kw = dict(reduced=True, algorithm="gpdmm", k=1, eta=0.05, m=2,
              per_client_batch=2, seq_len=32)
    good = tmp_path / "good"
    train_run("olmo-1b", steps=2, ckpt_dir=str(good), **kw)
    with pytest.raises(ValueError, match="config mismatch"):
        train_run("olmo-1b", steps=4, ckpt_dir=str(good), resume=True,
                  **{**kw, "eta": 0.1})


# ---------------------------------------------------------------------------
# satellite: cohort-sized synthetic data stream
# ---------------------------------------------------------------------------

def test_cohort_lm_batches_align_with_full_stream():
    """Round r of the cohort stream == the full stream's rows at that
    round's active client ids (sorted) -- so the engine's pass-through path
    sees exactly what its own gather would have produced."""
    key = jax.random.key(9)
    m, frac, seed = 6, 0.5, 17
    full = list(synthetic.lm_batches(key, 3, m, 2, 16, 64))
    coh = list(synthetic.cohort_lm_batches(key, 3, m, 2, 16, 64,
                                           participation=frac, fed_seed=seed))
    for r, (f, c) in enumerate(zip(full, coh)):
        idx, _ = T.cohort_indices(
            jax.random.fold_in(jax.random.key(seed), r), m, frac)
        idx = np.asarray(idx)
        assert c["tokens"].shape[0] == len(idx)
        np.testing.assert_array_equal(np.asarray(c["tokens"]),
                                      np.asarray(f["tokens"])[idx])
        np.testing.assert_array_equal(np.asarray(c["targets"]),
                                      np.asarray(f["targets"])[idx])


def test_lm_batches_start_offset():
    """lm_batches(start=r) yields exactly the tail of the full stream (the
    resume contract)."""
    key = jax.random.key(2)
    full = list(synthetic.lm_batches(key, 5, 3, 2, 16, 64))
    tail = list(synthetic.lm_batches(key, 2, 3, 2, 16, 64, start=3))
    for f, t in zip(full[3:], tail):
        np.testing.assert_array_equal(np.asarray(f["tokens"]), np.asarray(t["tokens"]))


# ---------------------------------------------------------------------------
# satellite: serve CLI --full actually reaches full-size serving
# ---------------------------------------------------------------------------

def test_serve_cli_full_flag():
    """--reduced is store_true with default=True, so before the fix
    full-size serving was unreachable from the CLI; --full must flip it."""
    import argparse

    from repro.launch import serve, train

    for mod in (serve, train):
        ap = None
        # rebuild each launcher's parser without running main()
        orig_parse = argparse.ArgumentParser.parse_args

        def fake_parse(self, *a, **k):
            raise _Captured(self)

        class _Captured(Exception):
            def __init__(self, parser):
                self.parser = parser

        argparse.ArgumentParser.parse_args = fake_parse
        try:
            mod.main()
        except _Captured as e:
            ap = e.parser
        finally:
            argparse.ArgumentParser.parse_args = orig_parse
        assert ap is not None
        assert ap.parse_args([]).reduced is True
        assert ap.parse_args(["--full"]).reduced is False, mod.__name__
