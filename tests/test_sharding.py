"""Sharding-rule unit/property tests (pure: no multi-device requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.configs import ARCHS
from repro.launch.mesh import make_smoke_mesh
from repro.models import build


@pytest.fixture(scope="module")
def mesh():
    return make_smoke_mesh(1, 1)


def test_dedupe_moe_spec(mesh):
    rules = sh.logical_rules(mesh, layout="client_axis")
    # (experts, embed, ff): experts takes "model"; ff must NOT reuse it
    spec = sh.spec_to_pspec(mesh, ("experts", "embed", "ff"), (4, 8, 16), rules)
    flat = [a for a in spec if a is not None]
    assert len(flat) == len(set(map(str, flat)))


def test_divisibility_gate(mesh):
    rules = {"heads": "model", None: None}
    # heads=3 not divisible by model axis (1 divides everything on smoke mesh)
    spec = sh.spec_to_pspec(mesh, ("heads",), (3,), rules)
    assert spec == P("model")  # size-1 axis divides


@settings(max_examples=30, deadline=None)
@given(
    dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
    axes=st.lists(st.sampled_from(["embed", "ff", "heads", "kv", None]), min_size=1, max_size=4),
)
def test_spec_never_duplicates_axes(mesh, dims, axes):
    n = min(len(dims), len(axes))
    rules = sh.logical_rules(mesh, layout="client_axis")
    spec = sh.spec_to_pspec(mesh, tuple(axes[:n]), tuple(dims[:n]), rules)
    used = []
    for entry in spec:
        if entry is None:
            continue
        for ax in (entry,) if isinstance(entry, str) else entry:
            used.append(ax)
    assert len(used) == len(set(used))


@pytest.mark.parametrize("name", list(ARCHS))
def test_param_specs_cover_params(name, mesh):
    """Every param leaf has a spec leaf of matching rank."""
    cfg = ARCHS[name].reduced()
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    specs = model.specs()
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda t: isinstance(t, tuple))
    assert len(flat_shapes) == len(flat_specs)
    for sds, sp in zip(flat_shapes, flat_specs):
        assert len(sp) == len(sds.shape), (sp, sds.shape)
    # and they convert to NamedShardings without error in both layouts
    for layout in ["client_axis", "fsdp"]:
        sh.param_shardings(mesh, specs, shapes, layout=layout)


@pytest.mark.parametrize("name", list(ARCHS))
def test_cache_specs_cover_caches(name, mesh):
    cfg = ARCHS[name].reduced()
    model = build(cfg)
    shapes = model.cache_shapes(2, 32)["layers"]
    specs = model.cache_specs()["layers"]
    flat_shapes = jax.tree.leaves(
        shapes, is_leaf=lambda t: isinstance(t, jax.ShapeDtypeStruct)
    )
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda t: isinstance(t, tuple))
    assert len(flat_shapes) == len(flat_specs)
    for sds, sp in zip(flat_shapes, flat_specs):
        assert len(sp) == len(sds.shape), (name, sp, sds.shape)
    sh.cache_shardings(mesh, shapes, specs)


def test_cache_seq_axis_fallback():
    """SSPerf H2 rules: seq-sharding engages only when the head dim cannot use
    the model axis (GQA kv %% axis != 0, or MLA), via steps._cache_seq_axis."""
    import dataclasses

    from repro.launch.steps import _cache_seq_axis

    class FakeMesh:
        shape = {"data": 4, "model": 4}
        axis_names = ("data", "model")

    mesh = FakeMesh()
    yi = dataclasses.replace(ARCHS["yi-34b"], shard_cache_seq=True)       # kv=8 % 4 == 0 -> no need
    assert _cache_seq_axis(yi, mesh) is None
    mesh16 = type("M", (), {"shape": {"data": 16, "model": 16},
                            "axis_names": ("data", "model")})()
    yi16 = dataclasses.replace(ARCHS["yi-34b"], shard_cache_seq=True)     # kv=8 % 16 != 0 -> shard seq
    assert _cache_seq_axis(yi16, mesh16) == "model"
    ds = dataclasses.replace(ARCHS["deepseek-v2-lite-16b"], shard_cache_seq=True)  # MLA -> always
    assert _cache_seq_axis(ds, mesh16) == "model"
    off = ARCHS["yi-34b"]  # default: paper-faithful baseline, flag off
    assert off.shard_cache_seq is False or _cache_seq_axis(off, mesh16) == "model"


def test_cache_seq_sharding_spec(mesh):
    """With seq_axis="model", the GQA cache seq dim takes the axis and the
    kv dim must not reuse it; k_pos follows the seq dim."""
    cfg = ARCHS["yi-34b"].reduced()
    model = build(cfg)
    shapes = model.cache_shapes(2, 32)["layers"]
    specs = model.cache_specs()["layers"]
    shardings = sh.cache_shardings(mesh, shapes, specs, seq_axis="model")
    for ns in jax.tree.leaves(shardings):
        used = []
        for entry in ns.spec:
            if entry is None:
                continue
            for ax in (entry,) if isinstance(entry, str) else entry:
                used.append(ax)
        assert len(used) == len(set(used))
