"""Pallas TPU kernels for the two graph-PDMM hot loops over the edge-dual
arena (``core.topology``: ``(2|E|, width)`` rows of directed duals z_{i|j},
128-lane padded like the client arena).

  * ``neighbor_reduce_pallas`` -- the per-node dual sum
    ``s_i = sum_{j in N(i)} A_{ij} z_{i|j}``: one fused pass over the
    edge-dual arena that applies the constraint sign and segment-sums each
    node's contiguous slot range into its offset row.  The topology
    compiler lays node i's outgoing slots out contiguously
    (``indptr[i]:indptr[i+1]``), so the reduction is the classic Pallas
    revisited-output accumulation: the grid walks slots with the node's
    output block resident in VMEM, zero-initialised at each segment start
    (``first`` flag) and flushed when the segment id changes.  One read of
    the dual arena + one write of the (n, width) offsets -- no
    materialised ``sgn * z`` intermediate, no scatter.

  * ``edge_flip_pallas`` -- PDMM's directed dual exchange
    ``z_{j|i}' = z_{i|j} + 2 c A_{ij} x_i`` written slot-wise at the
    RECEIVING slot t = (j|i):

        z'[t] = z[rev[t]] - 2 c sgn[t] x[nbr[t]]

    (``sgn[rev[t]] = -sgn[t]`` and ``src[rev[t]] = nbr[t]``).  The reverse
    permutation and the x-row gather ride the scalar-prefetch index maps,
    so the permuted read is free of any materialised ``z[rev]`` copy.  The
    masked variant (stochastic node firing / color-sequential schedules)
    keeps z[t] where the sending node did not fire.

Both kernels tile rows as ``(block, 128)`` under the shared 8 MiB VMEM
budget and block-size conventions of ``round_tail.py``.  Static slot tables
(seg/first/sgn/rev/nbr) and the dynamic fire mask are scalar-prefetch
operands (SMEM), read inside index maps and kernel bodies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_update import LANES, assert_vmem_budget
from repro.kernels.round_tail import _resolve_block, _tile, _untile


# ---------------------------------------------------------------------------
# (a) signed segment-sum of edge-dual rows into per-node offset rows
# ---------------------------------------------------------------------------

def _reduce_kernel(seg_ref, first_ref, sgn_ref, z_ref, o_ref):
    t = pl.program_id(1)
    z = z_ref[0].astype(jnp.float32)
    contrib = jnp.where(sgn_ref[t] >= 0, z, -z)

    @pl.when(first_ref[t] != 0)
    def _init():
        o_ref[0] = contrib.astype(o_ref.dtype)

    @pl.when(first_ref[t] == 0)
    def _acc():
        o_ref[0] = (o_ref[0].astype(jnp.float32) + contrib).astype(o_ref.dtype)


def neighbor_reduce_pallas(z, seg, first, sgn, n: int, *, block=None,
                           interpret: bool = False):
    """z: (2E, width) edge-dual arena; seg/first/sgn: (2E,) int32 static slot
    tables (segment id = slot owner, segment-start flag, constraint sign).
    Returns the (n, width) per-node offsets s_i = sum_j A_{ij} z_{i|j}.

    Every node must own at least one slot (connected graphs always do):
    unvisited output rows would stay undefined.
    """
    S, w = z.shape
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(2, br)
    zt, _, rows_p = _tile(z, br)
    wb = rows_p // br
    # lane blocks OUTER, slots inner: consecutive grid steps sharing a
    # segment revisit the same output block, which therefore stays resident
    # in VMEM across the whole segment (the accumulation contract)
    grid = (wb, S)
    out = pl.pallas_call(
        _reduce_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, br, LANES), lambda j, t, seg, first, sgn: (t, j, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, br, LANES), lambda j, t, seg, first, sgn: (seg[t], j, 0)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((n, rows_p, LANES), z.dtype),
        interpret=interpret,
    )(jnp.asarray(seg, jnp.int32), jnp.asarray(first, jnp.int32),
      jnp.asarray(sgn, jnp.int32), zt)
    return _untile(out, w, (n,))


# ---------------------------------------------------------------------------
# (b) one-pass directed dual flip z'[t] = z[rev[t]] - 2c sgn[t] x[nbr[t]]
# ---------------------------------------------------------------------------

def _flip_kernel(rev_ref, nbr_ref, sgn_ref, z_ref, x_ref, o_ref, *, c2: float):
    t = pl.program_id(0)
    x = x_ref[0].astype(jnp.float32)
    zr = z_ref[0].astype(jnp.float32)
    xs = jnp.where(sgn_ref[t] >= 0, -c2 * x, c2 * x)  # -2c sgn[t] x[nbr[t]]
    o_ref[0] = (zr + xs).astype(o_ref.dtype)


def _flip_kernel_masked(rev_ref, nbr_ref, sgn_ref, mask_ref, z_ref, x_ref,
                        zk_ref, o_ref, *, c2: float):
    t = pl.program_id(0)
    x = x_ref[0].astype(jnp.float32)
    zr = z_ref[0].astype(jnp.float32)
    zk = zk_ref[0].astype(jnp.float32)
    xs = jnp.where(sgn_ref[t] >= 0, -c2 * x, c2 * x)  # -2c sgn[t] x[nbr[t]]
    out = jnp.where(mask_ref[t] != 0, zr + xs, zk)
    o_ref[0] = out.astype(o_ref.dtype)


def edge_flip_pallas(z, x, c, rev, nbr, sgn, mask=None, *, block=None,
                     interpret: bool = False):
    """z: (2E, width); x: (n, width) node rows; rev/nbr/sgn: (2E,) int32
    static slot tables; mask: optional (2E,) int32 (1 = the SENDING node
    ``nbr[t]`` fired this phase, flip; 0 = keep z[t]).  Returns the new
    (2E, width) edge-dual arena.  Both gathers (z[rev[t]], x[nbr[t]]) ride
    the scalar-prefetch index maps -- no permuted copy is materialised."""
    S, w = z.shape
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(3 if mask is None else 5, br)
    zt, _, rows_p = _tile(z, br)
    xt, _, _ = _tile(x, br)
    wb = rows_p // br
    grid = (S, wb)
    out_sds = jax.ShapeDtypeStruct((S, rows_p, LANES), z.dtype)
    rev = jnp.asarray(rev, jnp.int32)
    nbr = jnp.asarray(nbr, jnp.int32)
    sgn = jnp.asarray(sgn, jnp.int32)
    if mask is None:
        return _untile(
            pl.pallas_call(
                functools.partial(_flip_kernel, c2=2.0 * float(c)),
                grid_spec=pltpu.PrefetchScalarGridSpec(
                    num_scalar_prefetch=3,
                    grid=grid,
                    in_specs=[
                        pl.BlockSpec((1, br, LANES),
                                     lambda t, j, rev, nbr, sgn: (rev[t], j, 0)),
                        pl.BlockSpec((1, br, LANES),
                                     lambda t, j, rev, nbr, sgn: (nbr[t], j, 0)),
                    ],
                    out_specs=pl.BlockSpec(
                        (1, br, LANES), lambda t, j, rev, nbr, sgn: (t, j, 0)
                    ),
                ),
                out_shape=out_sds,
                interpret=interpret,
            )(rev, nbr, sgn, zt, xt),
            w, (S,),
        )
    return _untile(
        pl.pallas_call(
            functools.partial(_flip_kernel_masked, c2=2.0 * float(c)),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=4,
                grid=grid,
                in_specs=[
                    pl.BlockSpec((1, br, LANES),
                                 lambda t, j, rev, nbr, sgn, mk: (rev[t], j, 0)),
                    pl.BlockSpec((1, br, LANES),
                                 lambda t, j, rev, nbr, sgn, mk: (nbr[t], j, 0)),
                    pl.BlockSpec((1, br, LANES),
                                 lambda t, j, rev, nbr, sgn, mk: (t, j, 0)),
                ],
                out_specs=pl.BlockSpec(
                    (1, br, LANES), lambda t, j, rev, nbr, sgn, mk: (t, j, 0)
                ),
            ),
            out_shape=out_sds,
            interpret=interpret,
        )(rev, nbr, sgn, jnp.asarray(mask, jnp.int32), zt, xt, zt),
        w, (S,),
    )
