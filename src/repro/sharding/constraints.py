"""Mesh-aware activation sharding constraints.

Model code is mesh-agnostic: ``constrain(x, None, None, "model")`` is a no-op
when no mesh is active (CPU smoke tests) or when the named axes don't exist /
don't divide the dim; under ``jax.set_mesh(production_mesh)`` it pins the
activation layout so GSPMD doesn't materialise unsharded giants (the
vocab-sharded logits constraint alone is worth ~13 GiB/device on olmo-1b).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _active_mesh():
    try:
        mesh = jax.sharding.get_abstract_mesh()  # jax >= 0.5
        if not mesh.empty:
            return mesh
    except AttributeError:
        pass
    # jax 0.4.x, or a newer jax driven through the legacy `with mesh:`
    # context (launch.mesh.mesh_context falls back to it when jax.set_mesh
    # is missing): read the thread-local physical mesh
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


def constrain(x, *axes):
    """axes: one entry per dim of x -- a mesh-axis name, tuple of names, or
    None.  Silently no-ops outside a mesh context."""
    mesh = _active_mesh()
    if mesh.empty:
        return x
    names = set(mesh.axis_names)
    spec = []
    for dim, ax in zip(x.shape, axes):
        cand = (ax,) if isinstance(ax, str) else tuple(ax) if ax else ()
        if cand and set(cand) <= names:
            size = 1
            for a in cand:
                size *= mesh.shape[a]
            spec.append(ax if dim % size == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
