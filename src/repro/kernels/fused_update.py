"""Pallas TPU kernel for the paper's fused federated client step (eq. 20):

    x' = x - step * (g + rho * (x - xs) + lam)

Why a kernel: the GPDMM/AGPDMM inner loop runs K times per round over every
parameter; it is purely memory-bound (arithmetic intensity ~1 flop/byte).  An
unfused XLA graph reads/writes intermediate tensors; the fusion does exactly
4 HBM reads + 1 write per element, the roofline minimum.

Tiling: inputs are flattened and tiled (BLOCK_ROWS, 128) -- the TPU lane width
-- so the kernel is a straight VMEM-resident vector op per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256  # 256 x 128 x 4B x 5 arrays ~ 0.7 MB of VMEM per step


def _kernel(x_ref, g_ref, xs_ref, lam_ref, o_ref, *, step: float, rho: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    xs = xs_ref[...].astype(jnp.float32)
    lam = lam_ref[...].astype(jnp.float32)
    out = x - step * (g + rho * (x - xs) + lam)
    o_ref[...] = out.astype(o_ref.dtype)


def fused_update_pallas(x, g, xs, lam, step, rho, *, block: int = BLOCK_ROWS, interpret: bool = False):
    shape, dtype = x.shape, x.dtype
    n = x.size
    tile = block * LANES
    n_pad = (tile - n % tile) % tile

    def flat(a):
        a = a.reshape(-1)
        if n_pad:
            a = jnp.pad(a, (0, n_pad))
        return a.reshape(-1, LANES)

    xf, gf, xsf, lf = flat(x), flat(g), flat(xs), flat(lam)
    rows = xf.shape[0]
    grid = (rows // block,)
    bs = pl.BlockSpec((block, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, step=float(step), rho=float(rho)),
        grid=grid,
        in_specs=[bs, bs, bs, bs],
        out_specs=bs,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), dtype),
        interpret=interpret,
    )(xf, gf, xsf, lf)
    return out.reshape(-1)[:n].reshape(shape)
