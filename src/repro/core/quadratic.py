"""The paper's least-squares testbed (SSVI-A): f_i(x) = 1/2 ||A_i x - b_i||^2
with A_i ~ N(0,1)^{n x d}, b_i = A_i y0 + v_i, v_i ~ N(0, 0.25 I).

Provides the gradient oracle (via precomputed A^T A, A^T b -- O(d^2) per
step), the closed-form prox oracle for exact PDMM/FedSplit (via a per-client
eigendecomposition, so prox is O(d^2) for any rho), the global optimum, and
the smoothness/strong-convexity constants (L, mu) the theory bounds need.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LeastSquares:
    AtA: jax.Array  # (m, d, d)
    Atb: jax.Array  # (m, d)
    btb: jax.Array  # (m,)
    evals: jax.Array  # (m, d)  eigenvalues of AtA
    evecs: jax.Array  # (m, d, d)
    x_star: jax.Array  # (d,) global optimum
    f_star: jax.Array  # () optimal value of F = sum_i f_i
    L: float  # max_i lambda_max(AtA_i)
    mu: float  # min_i lambda_min(AtA_i)

    @property
    def m(self) -> int:
        return self.AtA.shape[0]

    @property
    def d(self) -> int:
        return self.AtA.shape[1]

    # -- oracles -----------------------------------------------------------
    def grad(self, x, client_batch):
        """grad f_i(x) = AtA_i x - Atb_i; client_batch = {"AtA","Atb"}."""
        return client_batch["AtA"] @ x - client_batch["Atb"]

    def batch(self):
        """Stacked client batch for the federated round API."""
        return {"AtA": self.AtA, "Atb": self.Atb}

    def prox_fn(self, i_free=True):
        """Returns prox(v, rho) usable under vmap over the client dim.

        The closure carries the stacked eigendecompositions; under
        ``jax.vmap`` each client sees its own slice, so we expose a stacked
        variant: ``prox_stacked(v_stacked, rho)`` mapped in the caller.
        """

        def prox_one(evals, evecs, Atb, v, rho):
            # argmin 1/2||Ax-b||^2 + rho/2 ||x - v||^2
            rhs = Atb + rho * v
            return evecs @ ((evecs.T @ rhs) / (evals + rho))

        return prox_one

    def make_client_prox(self):
        """prox_fn(v_i, rho) for core.pdmm / core.fedsplit: the client index
        is implicit in vmap position, so we close over stacked arrays and let
        vmap slice them via lexical closure trick (see usage in tests)."""
        ev, eV, Atb = self.evals, self.evecs, self.Atb

        def stacked_prox(v_stacked, rho):
            def one(evals, evecs, atb, v):
                rhs = atb + rho * v
                return evecs @ ((evecs.T @ rhs) / (evals + rho))

            return jax.vmap(one)(ev, eV, Atb, v_stacked)

        return stacked_prox

    # -- objective ---------------------------------------------------------
    def F(self, x):
        """Global objective sum_i f_i(x) (x: (d,))."""
        quad = jnp.einsum("d,mde,e->", x, self.AtA, x)
        lin = jnp.einsum("md,d->", self.Atb, x)
        return 0.5 * quad - lin + 0.5 * jnp.sum(self.btb)

    def gap(self, x):
        return self.F(x) - self.f_star

    def dist(self, x):
        """||x - x*||: unlike the f32 functional gap (F ~ 1e6, so F - F* is
        +-O(10) noise near the optimum), the iterate distance stays accurate
        through convergence -- use it for method-vs-method claims."""
        return jnp.linalg.norm(x - self.x_star)

    def lam_star(self):
        """Optimal duals: lam*_{i|s} = grad f_i(x*) (KKT (7))."""
        return jnp.einsum("mde,e->md", self.AtA, self.x_star) - self.Atb


def generate(key, m: int, n: int, d: int, noise_std: float = 0.5) -> LeastSquares:
    k1, k2, k3 = jax.random.split(key, 3)
    A = jax.random.normal(k1, (m, n, d), dtype=jnp.float32)
    y0 = jax.random.normal(k2, (d,), dtype=jnp.float32)
    v = noise_std * jax.random.normal(k3, (m, n), dtype=jnp.float32)
    b = jnp.einsum("mnd,d->mn", A, y0) + v

    AtA = jnp.einsum("mnd,mne->mde", A, A)
    Atb = jnp.einsum("mnd,mn->md", A, b)
    btb = jnp.einsum("mn,mn->m", b, b)
    evals, evecs = jnp.linalg.eigh(AtA)

    H = AtA.sum(0)
    g = Atb.sum(0)
    x_star = jnp.linalg.solve(H, g)
    f_star = 0.5 * x_star @ H @ x_star - g @ x_star + 0.5 * btb.sum()

    return LeastSquares(
        AtA=AtA,
        Atb=Atb,
        btb=btb,
        evals=evals,
        evecs=evecs,
        x_star=x_star,
        f_star=f_star,
        L=float(evals[:, -1].max()),
        mu=float(evals[:, 0].min()),
    )
