"""Paper Table I / Fig. 3: softmax regression with one class per client
(maximum heterogeneity), deterministic mini-batch order, K in {1,5,10,30,40}.

MNIST/Fashion-MNIST are not available offline; the identical protocol runs on
a deterministic 10-class Gaussian-mixture image set (28x28 -> 784 features,
m=10 clients).  Claims reproduced: validation accuracy improves with K for
GPDMM/AGPDMM/SCAFFOLD but not FedAvg; AGPDMM is best or tied; GPDMM slightly
below SCAFFOLD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.base import FederatedConfig
from repro.core import make
from repro.core.softmax import SoftmaxRegression
from repro.data import partition, synthetic

BATCH = 300
ETA = 0.05
ROUNDS = 60
METHODS = ["fedavg", "gpdmm", "agpdmm", "scaffold"]

# the built-in objective (core.softmax); its oracle() carries the
# arena-native gradient, so the arena rounds skip the per-step
# unpack->grad->pack boundary entirely
PROB = SoftmaxRegression(n_features=784, n_classes=10)
grad_fn = PROB.oracle()


def accuracy(w, x, y):
    return float(PROB.accuracy(w, x, y))


def make_round_batches(xs, ys, K, r):
    """Deterministic mini-batch schedule: step k of round r takes the slice
    starting at ((r*K + k) * BATCH) mod n (the paper's no-randomness setup).
    Returns leaves (K, m, BATCH, ...)."""
    m, n = xs.shape[0], xs.shape[1]
    outx, outy = [], []
    for k in range(K):
        start = ((r * K + k) * BATCH) % max(1, n - BATCH + 1)
        outx.append(jax.lax.dynamic_slice_in_dim(xs, start, BATCH, axis=1))
        outy.append(jax.lax.dynamic_slice_in_dim(ys, start, BATCH, axis=1))
    return {"x": jnp.stack(outx), "y": jnp.stack(outy)}


def run(rounds=ROUNDS, ks=(1, 5, 10, 30, 40)):
    # sep=0.12 calibrates the mixture so the best linear classifier lands at
    # ~92% val accuracy (MNIST-softmax-like); the generator default (1.2) is
    # linearly separable and made every method saturate at 100%.
    ds = synthetic.gaussian_mixture_images(jax.random.key(0), 600, 120, sep=0.12)
    xs, ys = partition.by_class(ds.x_train, ds.y_train, 10)  # (10, n, 784)
    xs = xs / 10.0  # feature scale ~ MNIST pixel scale
    xv, yv = ds.x_val / 10.0, ds.y_val
    w0 = PROB.init_params()
    table = {}
    for K in ks:
        for method in METHODS:
            cfg = FederatedConfig(algorithm=method, inner_steps=K, eta=ETA)
            opt = make(cfg)

            @jax.jit
            def round_fn(s, r):
                batch = make_round_batches(xs, ys, K, r)
                s, _ = opt.round(s, grad_fn, batch, per_step_batches=True)
                return s

            s = opt.init(w0, 10)
            for r in range(rounds):
                s = round_fn(s, r)
            acc = accuracy(opt.server_params(s), xv, yv)
            us = time_fn(lambda s=s: round_fn(s, 0), iters=3, warmup=0)
            table[(K, method)] = acc
            emit(f"tab1_softmax_K={K}_{method}", us, f"val_acc={acc:.4f}")
    # headline orderings at the largest K (paper Table I): AGPDMM best or
    # tied; GPDMM within noise of FedAvg or better (the paper's GPDMM edge
    # over FedAve is ~1.4pp at K=40; allow 0.5pp slack at reduced rounds);
    # and K>1 local steps help AGPDMM (the anti-FedSplit claim).
    kmax = max(ks)
    assert table[(kmax, "agpdmm")] >= table[(kmax, "fedavg")], table
    assert table[(kmax, "agpdmm")] >= table[(kmax, "gpdmm")] - 0.002, table
    assert table[(kmax, "gpdmm")] >= table[(kmax, "fedavg")] - 0.005, table
    assert table[(kmax, "agpdmm")] > table[(1, "agpdmm")], table
    return table


if __name__ == "__main__":
    run()
