"""deepseek-v2-lite-16b -- MLA kv_lora=512, shared + routed top-6 experts
[arXiv:2405.04434].

27L d_model=2048 16H d_ff=1408(per expert) vocab=102400, MoE 64 routed experts
top-6 + 2 shared, first layer dense (d_ff 10944).  MLA compressed-KV cache.

Note: the assignment line reads "MoE 64e top-6" and "2 shared+160 routed"; the
published v2-Lite card has 64 routed + 2 shared, which we follow (the 160
figure belongs to full V2's 160 routed experts).  Recorded in DESIGN.md.
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,  # MLA: all heads share the compressed cache
    d_ff=10944,  # dense-layer hidden (layer 0)
    vocab_size=102400,
    block_pattern=("moe",),
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=0,  # v2-lite has no q compression
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    norm_kind="rmsnorm",
    fed=FederatedConfig(algorithm="gpdmm", layout="client_axis"),
    moe_fused_dispatch=True,  # SSPerf H1: single top-k dispatch (-47% train FLOPs)
    shard_cache_seq=True,  # SSPerf H2: MLA compressed cache seq-sharded over "model"
    microbatch=4,  # grad-accum chunks per inner step (activation memory)
    source="arXiv:2405.04434 (DeepSeek-V2-Lite)",
)
