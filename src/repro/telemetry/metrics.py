"""Unified metrics registry with JSONL and Prometheus-textfile sinks.

The round drivers already COMPUTE the paper's convergence signals -- the
eq.-(24)/(25) residual diagnostics (``lam_sum_norm``), ``server_loss``,
``client_drift``, the fault/staleness accounting -- but until now they died
in stdout.  ``Registry`` absorbs every logged metrics row and keeps three
metric kinds:

  * ``Counter``   -- monotonic totals (faults injected/demoted, stale
                     admitted/dropped, rollbacks, ring hits, checkpoint
                     bytes).  Round rows carry PER-ROUND counts; ``absorb``
                     sums them, so the registry total equals the launcher's
                     own accounting (tests pin this against
                     ``--expect-demotions``).
  * ``Gauge``     -- last-value signals (server_loss, lam_sum_norm,
                     cohort m_active, eta_scale).
  * ``Histogram`` -- distributions (swap latency, round wall time,
                     tokens/sec): count/sum/min/max, mean derived.

Sinks:

  * ``JsonlSink`` -- one JSON object per line, flushed per row, so a
    crashed run keeps every completed row and at worst tears the final
    line; ``read_jsonl`` tolerates exactly that torn tail.  The train
    launcher streams its ``history`` rows through this (loss curves used
    to live only in stdout).
  * ``write_prometheus`` -- the node-exporter *textfile collector* format
    for the serving path: counters get a ``_total`` suffix, histograms
    export ``_count``/``_sum``/``_min``/``_max``; the file is written
    atomically (tmp + rename) because the textfile collector may scrape
    mid-write.
"""
from __future__ import annotations

import json
import math
import os
import pathlib
import re
import threading
from typing import Any, Iterable

# Device-side round-metric keys with COUNTER semantics (per-round counts
# that sum over the run); everything else numeric in a round row is a gauge
# unless the caller asks for a histogram.
COUNTER_KEYS = frozenset({
    "faults_injected", "faults_demoted",
    "stale_admitted", "stale_dropped",
})


class Counter:
    __slots__ = ("name", "value")

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment "
                             f"{amount} (use a gauge)")
        self.value += amount

    def snapshot(self) -> float:
        return self.value


class Gauge:
    __slots__ = ("name", "value")

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = math.nan

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Streaming count/sum/min/max (mean derived).  No buckets: the
    consumers (bench cells, the serve summary) want the moments, and the
    Prometheus export stays a fixed four lines per metric."""

    __slots__ = ("name", "count", "total", "min", "max")

    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "mean": self.mean if self.count else None}


class Registry:
    """Get-or-create registry of named metrics.  Thread-safe creation (the
    serve watcher observes from its own thread); mutation of a single
    metric is GIL-atomic float arithmetic."""

    def __init__(self):
        self._metrics: dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls(name)
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def absorb(self, row: dict, *, counters: Iterable[str] = COUNTER_KEYS,
               prefix: str = "") -> None:
        """Fold one logged metrics row in: keys named in ``counters`` sum
        into Counters, every other scalar sets a Gauge AND feeds a same-name
        ``<key>_hist`` Histogram so both the trajectory endpoint and the
        distribution survive.  Non-numeric values are skipped.  Keys with
        GLOBAL counter semantics (``COUNTER_KEYS``) that this call was told
        not to count (``counters=()``: the caller accumulates them from a
        more complete stream) are skipped entirely -- registering them as
        gauges would collide with the counter of the same name."""
        counters = set(counters)
        for key, val in row.items():
            try:
                v = float(val)
            except (TypeError, ValueError):
                continue
            name = prefix + key
            if key in counters:
                if math.isfinite(v):
                    self.counter(name).inc(v)
            elif key not in COUNTER_KEYS:
                self.gauge(name).set(v)
                if math.isfinite(v):
                    self.histogram(name + "_hist").observe(v)

    def snapshot(self) -> dict:
        """{name: value-or-histogram-dict} for every registered metric."""
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def summary_row(self) -> dict:
        """The flat one-line form the JSONL sink and end-of-run prints use:
        histogram moments inline as ``name_count``/``name_mean``/etc."""
        out: dict[str, Any] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                snap = m.snapshot()
                for k in ("count", "mean", "min", "max", "sum"):
                    out[f"{name}_{k}"] = snap[k]
            else:
                out[name] = m.snapshot()
        return out


# -- JSONL sink -------------------------------------------------------------


class JsonlSink:
    """Append-only JSON-lines writer, one flush per row: a crash loses at
    most the torn final line, never an earlier row.  Values that json can't
    serialise (numpy scalars) are coerced via ``float`` as a fallback."""

    def __init__(self, path: str | os.PathLike):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._file = open(self.path, "a")
        self._lock = threading.Lock()
        self.rows_written = 0

    @staticmethod
    def _default(obj):
        try:
            return float(obj)
        except (TypeError, ValueError):
            return str(obj)

    def write(self, row: dict) -> None:
        line = json.dumps(row, default=self._default)
        with self._lock:
            self._file.write(line + "\n")
            self._file.flush()
            self.rows_written += 1

    def close(self) -> None:
        with self._lock:
            if not self._file.closed:
                self._file.close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


def read_jsonl(path: str | os.PathLike) -> list[dict]:
    """Read a JSONL file, tolerating a crash-torn FINAL line (dropped with
    no error).  A malformed line anywhere else raises -- that is corruption,
    not truncation, and silently skipping it would fake a clean run."""
    rows = []
    with open(path) as f:
        lines = f.readlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            rows.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                break  # torn tail of a crashed writer
            raise
    return rows


# -- Prometheus textfile exporter -------------------------------------------

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")


def _prom_name(name: str) -> str:
    """Metric names like ``serve/swap_latency_s`` -> ``serve_swap_latency_s``
    (Prometheus names admit only [a-zA-Z0-9_:])."""
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not _NAME_OK.match(out):
        out = "_" + out
    return out


def _prom_val(v) -> str:
    if v is None or (isinstance(v, float) and math.isnan(v)):
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    return repr(float(v))


def write_prometheus(registry: Registry, path: str | os.PathLike,
                     *, namespace: str = "repro") -> str:
    """Write the registry as a node-exporter textfile-collector file.
    Atomic (tmp + ``os.replace``): the collector may scrape mid-write, and
    a torn exposition file fails the whole scrape."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    lines: list[str] = []
    for name, m in sorted(registry._metrics.items()):
        base = _prom_name(f"{namespace}_{name}" if namespace else name)
        if isinstance(m, Counter):
            lines.append(f"# TYPE {base}_total counter")
            lines.append(f"{base}_total {_prom_val(m.value)}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {base} gauge")
            lines.append(f"{base} {_prom_val(m.value)}")
        else:  # Histogram moments as gauges (no buckets kept)
            snap = m.snapshot()
            lines.append(f"# TYPE {base}_count counter")
            lines.append(f"{base}_count {_prom_val(snap['count'])}")
            lines.append(f"# TYPE {base}_sum counter")
            lines.append(f"{base}_sum {_prom_val(snap['sum'])}")
            for stat in ("min", "max", "mean"):
                lines.append(f"# TYPE {base}_{stat} gauge")
                lines.append(f"{base}_{stat} {_prom_val(snap[stat])}")
    text = "\n".join(lines) + "\n"
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return str(path)
