"""ISSUE 4: decentralized graph-PDMM (core.topology + core.pdmm_graph +
kernels/neighbor_reduce).

The built-in conformance oracle: on a STAR graph, graph-PDMM under the
color-sequential schedule ({clients}, {server}) must reproduce the
centralised implementations round for round --

  * exact prox mode == ``core.pdmm`` (x_s trajectory AND the dual mapping
    z_{i|s} = lam_{s|i} - rho x_s);
  * gradient mode == arena ``core.gpdmm`` (x_s + the client primal carry),
    across use_avg (eq. 23 vs 24) and partial participation on the shared
    ``FederatedConfig.seed`` mask contract.

Plus: interpret-mode Pallas parity for the two neighbor-reduce kernels and
for a whole graph round; decentralized convergence on ring / complete / er
topologies (consensus + optimality); stochastic node firing semantics;
``core.make`` topology routing; the round-batched scan driver; and
hypothesis round-trips of the edge-dual slice map over random graphs
(``tests/_hyp`` shim).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs.base import FederatedConfig
from repro.core import gpdmm, make, make_scan_rounds, pdmm, pdmm_graph, quadratic, topology
from repro.kernels import ops

IMPLS = ["xla", "pallas_interpret"]


@pytest.fixture(scope="module", params=[20, 130], ids=["d20", "d130_odd"])
def prob(request):
    # d=20 -> width 128; d=130 -> width 256 with 126 zero-padded columns
    return quadratic.generate(jax.random.key(0), m=6, n=60, d=request.param)


# ---------------------------------------------------------------------------
# static structure of compiled topologies
# ---------------------------------------------------------------------------

TOPOS = {
    "star": lambda: topology.star(5),
    "ring": lambda: topology.ring(6),
    "complete": lambda: topology.complete(5),
    "torus": lambda: topology.torus2d(3, 4),
    "er": lambda: topology.erdos_renyi(9, 0.3, seed=2),
}


def check_structure(t: topology.Topology):
    S = t.n_slots
    assert S == 2 * t.n_edges
    # rev is an involution pairing (i|j) with (j|i), flipping the sign
    assert (t.rev[t.rev] == np.arange(S)).all()
    assert (t.src[t.rev] == t.nbr).all()
    assert (t.nbr[t.rev] == t.src).all()
    assert (t.sgn[t.rev] == -t.sgn).all()
    assert set(np.unique(t.sgn)) <= {-1, 1}
    assert ((t.sgn == 1) == (t.src < t.nbr)).all()  # A_{ij} = +1 iff i < j
    # CSR slot ownership: node i owns exactly indptr[i]:indptr[i+1]
    assert t.indptr[0] == 0 and t.indptr[-1] == S
    for i in range(t.n):
        lo, hi = int(t.indptr[i]), int(t.indptr[i + 1])
        assert (t.src[lo:hi] == i).all()
    assert (t.deg >= 1).all()  # connected -> no isolated nodes
    first = t.first_flags()
    assert first.sum() == t.n
    assert (first[t.indptr[:-1]] == 1).all()
    # colors form a proper coloring covering every node exactly once
    seen = np.concatenate(t.colors)
    assert sorted(seen.tolist()) == list(range(t.n))
    color_of = np.empty(t.n, np.int32)
    for ci, members in enumerate(t.colors):
        color_of[members] = ci
    assert (color_of[t.src] != color_of[t.nbr]).all()


@pytest.mark.parametrize("name", sorted(TOPOS))
def test_topology_structure(name):
    check_structure(TOPOS[name]())


def test_star_coloring_is_clients_then_server():
    t = topology.star(7)
    assert t.n == 8 and t.n_data == 7 and t.n_aux == 1
    assert t.colors[0].tolist() == list(range(7))
    assert t.colors[1].tolist() == [7]


def test_make_parses_specs():
    assert topology.make("star", 4).n == 5
    assert topology.make("ring", 5).n == 5
    assert topology.make("complete", 4).n_edges == 6
    assert topology.make("torus", 12).max_degree <= 4
    assert topology.make("er:0.9", 6, seed=1).n == 6
    with pytest.raises(ValueError):
        topology.make("moebius", 4)
    with pytest.raises(ValueError):
        topology.make("torus", 7)  # prime node count has no 2D grid


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 12), p=st.floats(0.05, 0.9), seed=st.integers(0, 999))
def test_slice_map_roundtrip_random_graphs(n, p, seed):
    """The edge-dual slice map over random (connected-ified) graphs: every
    directed pair routes to a unique arena row, slot() inverts the tables,
    and a scatter of distinct values survives the rev permutation twice."""
    t = topology.erdos_renyi(n, p, seed=seed)
    check_structure(t)
    slots = {(int(t.src[s]), int(t.nbr[s])): s for s in range(t.n_slots)}
    assert len(slots) == t.n_slots  # no two duals share a row
    for (i, j), s in slots.items():
        assert t.slot(i, j) == s
        assert slots[(j, i)] == t.rev[s]
    vals = np.arange(t.n_slots, dtype=np.float32)
    assert (vals[t.rev][t.rev] == vals).all()


# ---------------------------------------------------------------------------
# star conformance: the graph subsystem must BE the centralised algorithms
# ---------------------------------------------------------------------------

def test_exact_star_matches_centralised_pdmm(prob):
    """Exact graph-PDMM on a star == core.pdmm round for round: the x_s
    trajectory at f32 resolution AND the dual-coordinate mapping
    z_{i|s} = lam_{s|i} - rho x_s after every round."""
    cfg = FederatedConfig(algorithm="pdmm_graph", inner_steps=1, eta=0.1, rho=2.0)
    g = pdmm_graph.make_exact(cfg)
    cen = pdmm.make_exact(cfg)
    prox = prob.make_client_prox()
    sg = g.init(jnp.zeros((prob.d,)), prob.m)
    sc = cen.init(jnp.zeros((prob.d,)), prob.m)
    topo = pdmm_graph.topo_for(cfg, prob.m)
    gr = jax.jit(lambda s: g.round(s, prox, None))
    cr = jax.jit(lambda s: cen.round(s, prox, None))
    for r in range(12):
        sg, _ = gr(sg)
        sc, _ = cr(sc)
        np.testing.assert_allclose(
            np.asarray(g.server_params(sg)), np.asarray(sc["x_s"]),
            atol=1e-4, rtol=1e-4, err_msg=f"x_s diverged at round {r}")
        # z_{i|s} rows live at each client's (single) slot
        z = np.asarray(sg["z"])
        lam = np.asarray(sc["lam_s"])
        xs = np.asarray(sc["x_s"])
        for i in range(prob.m):
            np.testing.assert_allclose(
                z[topo.slot(i, prob.m), : prob.d], lam[i] - 2.0 * xs,
                atol=1e-3, rtol=1e-3,
                err_msg=f"dual mapping broke at round {r}, client {i}")


@pytest.mark.parametrize("participation", [1.0, 0.5], ids=["full", "partial"])
@pytest.mark.parametrize("use_avg", [True, False], ids=["avg", "last"])
def test_gradient_star_matches_centralised_gpdmm(prob, use_avg, participation):
    """Gradient graph-PDMM on a star == arena core.gpdmm round for round
    (x_s AND the client primal carry), across the eq. 23/24 dual variants
    and partial participation on the shared seed mask contract."""
    kw = dict(inner_steps=3, eta=0.5 / prob.L, use_avg=use_avg,
              participation=participation)
    g = pdmm_graph.make(FederatedConfig(algorithm="gpdmm_graph", **kw))
    cen = gpdmm.make(FederatedConfig(algorithm="gpdmm", use_arena=True, **kw))
    oracle = prob.oracle()
    batch = prob.batch()
    sg = g.init(jnp.zeros((prob.d,)), prob.m)
    sc = cen.init(jnp.zeros((prob.d,)), prob.m)
    gr = jax.jit(lambda s: g.round(s, oracle, batch))
    cr = jax.jit(lambda s: cen.round(s, oracle, batch))
    for r in range(15):
        sg, _ = gr(sg)
        sc, _ = cr(sc)
        np.testing.assert_allclose(
            np.asarray(g.server_params(sg)),
            np.asarray(jax.tree.leaves(cen.server_params(sc))[0]),
            atol=1e-4, rtol=1e-4, err_msg=f"x_s diverged at round {r}")
        np.testing.assert_allclose(
            np.asarray(sg["x"][: prob.m]), np.asarray(sc["x_c"]),
            atol=1e-4, rtol=1e-4, err_msg=f"primal carry diverged at round {r}")


def test_gradient_star_nonaffine_oracle_matches(prob):
    """The scan path (grad_arena oracle, no affine fast path) conforms too:
    strip the affine annotation so the graph round and the centralised round
    both fall back to the step-at-a-time fused update."""
    from repro.core.api import make_oracle

    base = prob.oracle()
    oracle = make_oracle(prob.grad, grad_arena=base.grad_arena)
    kw = dict(inner_steps=2, eta=0.5 / prob.L)
    g = pdmm_graph.make(FederatedConfig(algorithm="gpdmm_graph", **kw))
    cen = gpdmm.make(FederatedConfig(algorithm="gpdmm", use_arena=True, **kw))
    batch = prob.batch()
    sg = g.init(jnp.zeros((prob.d,)), prob.m)
    sc = cen.init(jnp.zeros((prob.d,)), prob.m)
    for r in range(8):
        sg, _ = g.round(sg, oracle, batch)
        sc, _ = cen.round(sc, oracle, batch)
    np.testing.assert_allclose(
        np.asarray(g.server_params(sg)),
        np.asarray(jax.tree.leaves(cen.server_params(sc))[0]),
        atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# interpret-mode kernel parity (the TPU kernel bodies, validated on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["ring", "er"])
def test_neighbor_reduce_kernel_parity(name):
    t = TOPOS[name]()
    w = 384
    k = jax.random.key(3)
    z = jax.random.normal(k, (t.n_slots, w))
    kw = dict(seg=t.src, first=t.first_flags(), sgn=t.sgn, n=t.n)
    outs = {impl: ops.neighbor_reduce(z, **kw, impl=impl) for impl in IMPLS}
    np.testing.assert_allclose(np.asarray(outs["xla"]),
                               np.asarray(outs["pallas_interpret"]),
                               atol=1e-6, rtol=1e-6)
    # and against the unfused reference: an explicit python loop over slots
    ref = np.zeros((t.n, w), np.float32)
    zn = np.asarray(z)
    for s in range(t.n_slots):
        ref[t.src[s]] += t.sgn[s] * zn[s]
    np.testing.assert_allclose(np.asarray(outs["xla"]), ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("masked", [False, True], ids=["sync", "masked"])
@pytest.mark.parametrize("name", ["ring", "er"])
def test_edge_flip_kernel_parity(name, masked):
    t = TOPOS[name]()
    w = 384
    k = jax.random.key(4)
    z = jax.random.normal(k, (t.n_slots, w))
    x = jax.random.normal(jax.random.fold_in(k, 1), (t.n, w))
    mask = (np.arange(t.n_slots) % 3 == 0).astype(np.int32) if masked else None
    kw = dict(rev=t.rev, nbr=t.nbr, sgn=t.sgn, mask=mask)
    outs = {impl: ops.edge_flip(z, x, 1.7, **kw, impl=impl) for impl in IMPLS}
    np.testing.assert_allclose(np.asarray(outs["xla"]),
                               np.asarray(outs["pallas_interpret"]),
                               atol=1e-6, rtol=1e-6)
    # slot-wise reference: z'[t] = z[rev[t]] + 2c A_{nbr,src} x[nbr[t]]
    zn, xn = np.asarray(z), np.asarray(x)
    ref = zn[t.rev] - 2 * 1.7 * t.sgn[:, None] * xn[t.nbr]
    if masked:
        ref = np.where(mask[:, None] != 0, ref, zn)
    np.testing.assert_allclose(np.asarray(outs["xla"]), ref, atol=1e-5, rtol=1e-5)


def test_graph_round_interpret_parity(prob):
    """A WHOLE gradient graph round through the interpret-mode Pallas
    kernels (neighbor reduce, fused K-step inner loop, edge flip) lands on
    the XLA round's state at f32 resolution."""
    cfg = FederatedConfig(algorithm="gpdmm_graph", topology="ring",
                          inner_steps=2, eta=0.5 / prob.L)
    g = pdmm_graph.make(cfg)
    oracle = prob.oracle()
    batch = prob.batch()
    s0 = g.init(jnp.zeros((prob.d,)), prob.m)
    states = {}
    for impl in IMPLS:
        ops.set_default_impl(impl)
        try:
            s, _ = g.round(s0, oracle, batch)
        finally:
            ops.set_default_impl("xla")
        states[impl] = s
    for k in ("x", "z"):
        np.testing.assert_allclose(
            np.asarray(states["xla"][k]), np.asarray(states["pallas_interpret"][k]),
            atol=1e-5, rtol=1e-5, err_msg=k)


# ---------------------------------------------------------------------------
# decentralized behaviour on non-star topologies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", ["ring", "complete", "er:0.5"])
def test_graph_pdmm_converges(prob, topo):
    """Graph-PDMM solves the SAME global problem on any connected topology:
    every node's primal reaches x* and the consensus residual vanishes."""
    cfg = FederatedConfig(algorithm="gpdmm_graph", topology=topo,
                          inner_steps=4, eta=0.5 / prob.L, seed=3)
    g = pdmm_graph.make(cfg)
    oracle = prob.oracle()
    batch = prob.batch()
    s = g.init(jnp.zeros((prob.d,)), prob.m)
    gr = jax.jit(lambda st: g.round(st, oracle, batch))
    for _ in range(300):
        s, metrics = gr(s)
    assert float(prob.dist(g.server_params(s))) < 1e-3
    assert float(metrics["consensus_err"]) < 1e-8
    # every NODE row individually reached the global optimum
    x = np.asarray(s["x"])[:, : prob.d]
    np.testing.assert_allclose(x, np.broadcast_to(np.asarray(prob.x_star), x.shape),
                               atol=1e-3)


def test_sync_schedule_converges_and_differs(prob):
    cfg_kw = dict(algorithm="gpdmm_graph", topology="ring", inner_steps=3,
                  eta=0.5 / prob.L)
    oracle, batch = prob.oracle(), prob.batch()
    finals = {}
    for sched in ("color", "sync"):
        g = pdmm_graph.make(FederatedConfig(graph_schedule=sched, **cfg_kw))
        s = g.init(jnp.zeros((prob.d,)), prob.m)
        gr = jax.jit(lambda st: g.round(st, oracle, batch))
        s, _ = gr(s)
        first = np.asarray(g.server_params(s)).copy()
        for _ in range(399):
            s, _ = gr(s)
        finals[sched] = first, float(prob.dist(g.server_params(s)))
    assert finals["color"][1] < 1e-3 and finals["sync"][1] < 1e-3
    # the schedules are genuinely different algorithms (Gauss-Seidel vs
    # Jacobi): their first rounds must not coincide
    assert not np.allclose(finals["color"][0], finals["sync"][0], atol=1e-6)


def test_stochastic_firing_semantics(prob):
    """Silent nodes keep their primal rows AND the duals they own; over many
    rounds the stochastic iteration still converges."""
    cfg = FederatedConfig(algorithm="gpdmm_graph", topology="ring",
                          graph_schedule="sync", inner_steps=3,
                          eta=0.5 / prob.L, participation=0.5, seed=11)
    g = pdmm_graph.make(cfg)
    oracle, batch = prob.oracle(), prob.batch()
    topo = pdmm_graph.topo_for(cfg, prob.m)
    s = g.init(jnp.zeros((prob.d,)), prob.m)
    # one round: recompute the mask the round used (the seed contract)
    from repro.core import tree_util as T
    mask = np.asarray(T.participation_mask(
        gpdmm.participation_key(cfg, s["round"]), prob.m, 0.5))
    s1, _ = g.round(s, oracle, batch)
    x0, x1 = np.asarray(s["x"]), np.asarray(s1["x"])
    z0, z1 = np.asarray(s["z"]), np.asarray(s1["z"])
    for i in range(prob.m):
        if mask[i]:
            assert not np.allclose(x0[i], x1[i])
        else:
            np.testing.assert_array_equal(x0[i], x1[i])
            # duals at slots RECEIVING from i (owned by neighbors) are kept
            for t in range(topo.n_slots):
                if topo.nbr[t] == i:
                    np.testing.assert_array_equal(z0[t], z1[t])
    gr = jax.jit(lambda st: g.round(st, oracle, batch))
    for _ in range(800):
        s, metrics = gr(s)
    assert float(prob.dist(g.server_params(s))) < 1e-2


def test_scan_driver_matches_loop(prob):
    cfg = FederatedConfig(algorithm="gpdmm_graph", topology="ring",
                          inner_steps=2, eta=0.5 / prob.L)
    g = make(cfg)
    oracle, batch = prob.oracle(), prob.batch()
    s0 = g.init(jnp.zeros((prob.d,)), prob.m)
    R = 4
    batches = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (R,) + a.shape), batch)
    s_scan, metrics = jax.jit(make_scan_rounds(g, oracle))(s0, batches)
    assert metrics["consensus_err"].shape == (R,)
    s_loop = s0
    round_fn = jax.jit(lambda s: g.round(s, oracle, batch))
    for _ in range(R):
        s_loop, _ = round_fn(s_loop)
    np.testing.assert_array_equal(np.asarray(s_scan["round"]), np.asarray(s_loop["round"]))
    for k in ("x", "z"):
        np.testing.assert_allclose(np.asarray(s_scan[k]), np.asarray(s_loop[k]),
                                   atol=1e-6, rtol=1e-6, err_msg=k)


# ---------------------------------------------------------------------------
# config wiring
# ---------------------------------------------------------------------------

def test_make_routes_topology():
    # plain gpdmm over a non-star topology IS graph-PDMM
    opt = make(FederatedConfig(algorithm="gpdmm", topology="ring"))
    assert opt.name == "gpdmm_graph"
    # star keeps the centralised fast path
    assert make(FederatedConfig(algorithm="gpdmm")).name == "gpdmm"
    # explicit graph algorithms run on any topology, star included
    assert make(FederatedConfig(algorithm="gpdmm_graph")).name == "gpdmm_graph"
    assert make(FederatedConfig(algorithm="pdmm_graph")).name == "pdmm_graph"
    # no decentralized analogue -> loud
    for algo in ("scaffold", "fedavg", "agpdmm", "fedsplit"):
        with pytest.raises(ValueError, match="no decentralized analogue"):
            make(FederatedConfig(algorithm=algo, topology="ring"))


def test_graph_rejects_unsupported_variants():
    with pytest.raises(NotImplementedError, match="EF21"):
        make(FederatedConfig(algorithm="gpdmm_graph", uplink_bits=8))
    with pytest.raises(NotImplementedError, match="variance reduction"):
        make(FederatedConfig(algorithm="gpdmm_graph", variance_reduction="svrg"))
    cfg = FederatedConfig(algorithm="gpdmm_graph", graph_schedule="bogus")
    g = make(cfg)
    s = g.init(jnp.zeros((4,)), 3)
    with pytest.raises(ValueError, match="graph_schedule"):
        g.round(s, lambda x, b: x, None)


def test_padding_stays_zero(prob):
    """The arena zero-padding invariant survives graph rounds (both arenas):
    only meaningful for the odd width."""
    if prob.d % 128 == 0:
        pytest.skip("no padding at this width")
    cfg = FederatedConfig(algorithm="gpdmm_graph", topology="ring",
                          inner_steps=3, eta=0.5 / prob.L)
    g = pdmm_graph.make(cfg)
    s = g.init(jnp.zeros((prob.d,)), prob.m)
    for _ in range(3):
        s, _ = g.round(s, prob.oracle(), prob.batch())
    assert not np.asarray(s["x"][:, : prob.d] == 0).all()
    np.testing.assert_array_equal(np.asarray(s["x"][:, prob.d:]), 0.0)
    np.testing.assert_array_equal(np.asarray(s["z"][:, prob.d:]), 0.0)


@pytest.mark.parametrize("idx_aware", [True, False], ids=["idx_prox", "plain_prox"])
def test_exact_graph_pdmm_on_ring(prob, idx_aware):
    """Exact graph-PDMM on a multi-color topology: the idx-aware prox
    (subset evaluation per firing phase) and the plain 2-arg fallback
    (full-stacking evaluation + row select) take identical trajectories and
    both reach the global optimum."""
    base = prob.make_client_prox()
    prox = base if idx_aware else (lambda v, rho: base(v, rho))
    cfg = FederatedConfig(algorithm="pdmm_graph", topology="ring", rho=30.0)
    g = pdmm_graph.make_exact(cfg)
    s = g.init(jnp.zeros((prob.d,)), prob.m)
    gr = jax.jit(lambda st: g.round(st, prox, None))
    for _ in range(150):
        s, metrics = gr(s)
    assert float(prob.dist(g.server_params(s))) < 5e-3
    assert float(metrics["consensus_err"]) < 1e-3


def test_exact_prox_idx_and_plain_agree(prob):
    base = prob.make_client_prox()
    cfg = FederatedConfig(algorithm="pdmm_graph", topology="ring", rho=2.0)
    g = pdmm_graph.make_exact(cfg)
    s_i = g.init(jnp.zeros((prob.d,)), prob.m)
    s_p = s_i
    for _ in range(5):
        s_i, _ = g.round(s_i, base, None)
        s_p, _ = g.round(s_p, lambda v, rho: base(v, rho), None)
    for k in ("x", "z"):
        np.testing.assert_allclose(np.asarray(s_i[k]), np.asarray(s_p[k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)
