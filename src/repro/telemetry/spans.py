"""Round-phase span tracing: Chrome trace-event JSON, near-zero when off.

The five load-bearing runtime paths (the scan round driver, the popstore
prefetch ring, the async staleness engine, the watchdog/rollback loop, the
hot-swap server) emit their phase breakdown through ONE global ``Tracer``:

    from repro.telemetry import spans
    with spans.span("round/dispatch"):
        state, metrics = step_fn(state, batch)
    spans.instant("watchdog/rollback", {"to_round": 3})
    spans.counter("popstore/ring_hit", hits)

Design constraints, in order:

  * DISABLED is the default and must cost nothing measurable per call:
    ``span()`` on a disabled tracer is one attribute test and returns a
    shared singleton whose ``__enter__``/``__exit__`` allocate nothing
    (fixed-arity ``__exit__`` -- a ``*args`` signature would allocate a
    tuple per call; tests/test_telemetry.py pins zero allocations per
    disabled span).  Instrumented library code (``core.popstore``,
    ``launch.serve``) therefore calls the tracer unconditionally.

  * Timestamps are MONOTONIC (``time.perf_counter_ns``) -- wall-clock
    ``time.time`` steps under NTP adjustment and can negate a duration.
    Events record microseconds relative to the tracer's start, which is
    what the trace-event format's ``ts`` field wants anyway.

  * Thread-safe: events append to a ``collections.deque`` (atomic under
    the GIL, no lock on the hot path); per-thread ``tid`` keeps the serve
    thread's spans on their own Perfetto track.  ``flush``/``close`` take
    a lock only around draining and file IO.

  * CRASH-TOLERANT output: the trace file is the Chrome trace-event JSON
    *array* format, appended incrementally on every ``flush()``.  The
    closing ``]`` is only written by ``close()``, but the format is
    specified so that a missing terminator is legal -- Perfetto and
    chrome://tracing both load a truncated trace, so a killed run keeps
    every span flushed before the crash.

Span names are ``path/phase`` (taxonomy in docs/telemetry.md).  ``ph`` codes
emitted: ``X`` (complete span), ``i`` (instant), ``C`` (counter).
"""
from __future__ import annotations

import functools
import json
import os
import pathlib
import threading
import time
from collections import deque
from typing import Any, Optional


class _NullSpan:
    """Shared no-op context manager returned by every disabled ``span()``.

    ``__exit__`` takes the three exception operands POSITIONALLY: a
    ``*args`` signature would build a tuple per call, and the whole point
    of this object is that the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span on an ENABLED tracer; records on ``__exit__``."""

    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter_ns()
        # ("X", name, start_ns, dur_ns, tid, args) -- rendered at flush
        self._tracer._events.append(
            ("X", self._name, self._t0, t1 - self._t0,
             threading.get_ident(), self._args))
        return False


class Tracer:
    """Buffering trace-event recorder.  One global instance (``get_tracer``)
    serves the whole process; tests construct private ones."""

    def __init__(self):
        self.enabled = False
        self._events: deque = deque()
        self._lock = threading.Lock()
        self._t0_ns = time.perf_counter_ns()
        self._pid = os.getpid()
        self._path: Optional[pathlib.Path] = None
        self._file = None
        self._wrote_any = False

    # -- configuration ----------------------------------------------------

    def configure(self, *, enabled: bool = True,
                  trace_out: str | os.PathLike | None = None) -> "Tracer":
        """Enable/disable recording and (re)target the output file.  A new
        ``trace_out`` closes any previous file and starts a fresh array."""
        if trace_out is not None:
            new = pathlib.Path(trace_out)
            with self._lock:
                if self._path != new:
                    self._close_file_locked()
                    self._path = new
        self.enabled = bool(enabled)
        return self

    # -- recording (hot path) ---------------------------------------------

    def span(self, name: str, args: Optional[dict] = None):
        """Context manager timing a phase.  Disabled: returns the shared
        no-op singleton (zero allocations)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def instant(self, name: str, args: Optional[dict] = None) -> None:
        """Point event (watchdog strike, rollback, hot swap)."""
        if not self.enabled:
            return
        self._events.append(("i", name, time.perf_counter_ns(), 0,
                             threading.get_ident(), args))

    def counter(self, name: str, value) -> None:
        """Counter track sample (prefetch-ring hits/misses).  ``value`` may
        be a number or a {series: number} dict for stacked counters."""
        if not self.enabled:
            return
        self._events.append(("C", name, time.perf_counter_ns(), 0,
                             threading.get_ident(), value))

    def traced(self, name: Optional[str] = None):
        """Decorator form: ``@tracer.traced("serve/query")``."""

        def deco(fn):
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                if not self.enabled:
                    return fn(*a, **kw)
                with self.span(label):
                    return fn(*a, **kw)

            return wrapper

        return deco

    # -- rendering / IO ----------------------------------------------------

    def _render(self, ev) -> dict:
        ph, name, t_ns, dur_ns, tid, args = ev
        out = {"ph": ph, "name": name, "pid": self._pid, "tid": tid,
               "ts": (t_ns - self._t0_ns) / 1e3}
        if ph == "X":
            out["dur"] = dur_ns / 1e3
            if args:
                out["args"] = args
        elif ph == "i":
            out["s"] = "t"  # thread-scoped instant
            if args:
                out["args"] = args
        elif ph == "C":
            out["args"] = args if isinstance(args, dict) else {"value": args}
        return out

    def drain(self) -> list[dict]:
        """Pop and render every buffered event (no file IO) -- the in-memory
        consumer tests and ad-hoc callers use this."""
        out = []
        while True:
            try:
                out.append(self._render(self._events.popleft()))
            except IndexError:
                return out

    def flush(self) -> None:
        """Append buffered events to ``trace_out`` (no-op without a path).
        Every flushed event survives a later crash: the array format needs
        no terminator to parse."""
        events = self.drain()
        if not events:
            return
        with self._lock:
            if self._path is None:
                # no sink configured: drop (recording without an output file
                # is only useful through ``drain``)
                return
            if self._file is None:
                self._path.parent.mkdir(parents=True, exist_ok=True)
                self._file = open(self._path, "w")
                self._file.write("[\n")
            f = self._file
            for ev in events:
                if self._wrote_any:
                    f.write(",\n")
                f.write(json.dumps(ev))
                self._wrote_any = True
            f.flush()

    def _close_file_locked(self):
        if self._file is not None:
            if self._wrote_any:
                self._file.write("\n]\n")
            else:
                self._file.write("]\n")
            self._file.close()
            self._file = None
            self._wrote_any = False

    def close(self) -> Optional[str]:
        """Flush, terminate the JSON array, close the file.  Returns the
        trace path (if any) so launchers can print it.  The tracer stays
        usable: the next flush starts a new file at the same path."""
        self.flush()
        with self._lock:
            path = str(self._path) if self._path else None
            wrote = self._file is not None
            self._close_file_locked()
        return path if wrote else None


# -- the process-global tracer the instrumented paths share -----------------

_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    return _GLOBAL


def configure(*, enabled: bool = True,
              trace_out: str | os.PathLike | None = None) -> Tracer:
    return _GLOBAL.configure(enabled=enabled, trace_out=trace_out)


def enabled() -> bool:
    return _GLOBAL.enabled


def span(name: str, args: Optional[dict] = None):
    return _GLOBAL.span(name, args)


def instant(name: str, args: Optional[dict] = None) -> None:
    _GLOBAL.instant(name, args)


def counter(name: str, value: Any) -> None:
    _GLOBAL.counter(name, value)


def traced(name: Optional[str] = None):
    return _GLOBAL.traced(name)


def flush() -> None:
    _GLOBAL.flush()


def close() -> Optional[str]:
    return _GLOBAL.close()


def load_trace(path: str | os.PathLike) -> list[dict]:
    """Parse a trace file, tolerating a crash-truncated tail: a missing
    closing ``]`` (and a partial final line) is legal per the trace-event
    array format, so recover every complete event instead of raising."""
    text = pathlib.Path(path).read_text()
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        pass
    body = text.strip()
    if body.startswith("["):
        body = body[1:]
    events = []
    for line in body.splitlines():
        line = line.strip().rstrip(",")
        if not line or line == "]":
            continue
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            continue  # the torn final line of a crashed run
    return events
