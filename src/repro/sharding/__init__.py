"""Mesh-axis rules + NamedSharding builders for params, federated state,
batches and serving caches."""
from repro.sharding.specs import (
    batch_shardings,
    cache_shardings,
    client_axes,
    axis_size,
    logical_rules,
    logits_shardings,
    param_shardings,
    replicated,
    spec_to_pspec,
    stacked_shardings,
)

__all__ = [
    "batch_shardings",
    "cache_shardings",
    "client_axes",
    "axis_size",
    "logical_rules",
    "logits_shardings",
    "param_shardings",
    "replicated",
    "spec_to_pspec",
    "stacked_shardings",
]
