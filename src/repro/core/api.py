"""Unified federated-optimiser interface (the paper's technique as a
first-class, model-agnostic JAX module).

Every algorithm is a pair of pure functions:

    init(params, m)                  -> state          (pytree)
    round(state, grad_fn, batch)     -> (state, metrics)

with the conventions:
  * ``params`` is any pytree (a scalar vector for the paper's experiments or a
    full transformer parameter tree);
  * per-client entries in ``state`` are stacked with a leading client dim m;
  * ``grad_fn(params_i, batch_i) -> grad`` is the per-client gradient oracle;
    ``round`` vmaps it over the client dim, so the same code runs the paper's
    least-squares problems and sharded LM training;
  * ``batch`` leaves have leading dim m, or (K, m, ...) when
    ``per_step_batches=True`` (one minibatch per inner gradient step, the
    paper's softmax-regression setup).

The exact (prox-based) PDMM / FedSplit variants instead take a
``prox_fn(v, rho) -> argmin_x f_i(x) + rho/2 ||x - v||^2`` oracle (vmapped the
same way); they live in ``core.pdmm`` / ``core.fedsplit``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig


class FedOpt(NamedTuple):
    name: str
    init: Callable  # (params, m) -> state
    round: Callable  # (state, grad_fn, batch, per_step_batches=False) -> (state, metrics)
    server_params: Callable  # (state) -> params  (current global estimate)


# ---------------------------------------------------------------------------
# gradient-oracle protocol (arena-native fast paths)
# ---------------------------------------------------------------------------
#
# A plain ``grad_fn(params_i, batch_i) -> grad`` works everywhere; the arena
# hot path additionally recognises two OPTIONAL attributes on the callable:
#
#   grad_fn.grad_arena(spec)          -> ga(x_arena, batch) -> g_arena
#       Stacked gradient evaluated DIRECTLY on the packed ``(m, width)``
#       buffer via the spec's slice table.  Padding columns must map to 0.
#       Removes the per-inner-step unpack -> vgrad -> pack boundary round
#       trip (+4 full-state HBM passes/step for multi-leaf trees).
#
#   grad_fn.affine_arena(spec, batch) -> (H, c)   with H (m, W, W), c (m, W)
#       Declares the gradient affine: grad_i(x) = H_i x - c_i in arena
#       coordinates (rows/cols beyond each leaf's size must be zero so the
#       padding invariant survives).  Lets the round run the WHOLE K-step
#       inner loop as one fused kernel (``kernels/inner_loop.py``) that
#       keeps the client row in VMEM across all K steps.  Any AFFINE-OFFSET
#       client correction (SCAFFOLD's ``grad f_i(x) - c_i + c``) stays on
#       this path: the offset folds into the affine constant, so the
#       consumer passes the arena-resident correction buffer straight to the
#       kernel's per-client offset row (``inner_loop_affine(..., off=...)``)
#       -- no extra (m, width) materialisation, no per-step re-read.
#
#   grad_fn.curvature_arena(spec)     -> curv(x_arena, batch) -> L (m,)
#       Per-client smoothness estimates in arena coordinates (the auto-eta
#       stepsize derivation, ``core.autotune``).  ``x_arena`` is the packed
#       (m, width) point the curvature is probed at (affine oracles ignore
#       it).  When absent, ``autotune.estimate_L`` falls back to a power
#       iteration on ``affine_arena``'s H blocks, then to a Hessian-vector
#       power iteration through ``jax.jvp`` of the arena (or plain) grad.
#
# ``make_oracle`` assembles such an annotated callable; ``arena_grad``
# resolves the best available stacked arena gradient for any grad_fn, and
# ``affine_case`` gates the fused K-step kernel (shared by GPDMM/AGPDMM and
# the SCAFFOLD/FedAvg offset variant).


def make_oracle(grad_fn, *, grad_arena=None, affine_arena=None,
                curvature_arena=None):
    """Annotate a per-client ``grad_fn`` with arena-native fast paths."""

    def oracle(x, batch):
        return grad_fn(x, batch)

    if grad_arena is not None:
        oracle.grad_arena = grad_arena
    if affine_arena is not None:
        oracle.affine_arena = affine_arena
    if curvature_arena is not None:
        oracle.curvature_arena = curvature_arena
    return oracle


def arena_grad(grad_fn, spec):
    """Resolve the stacked arena-space gradient for ``grad_fn``.

    Returns ``(ga, native)`` where ``ga((m, width), batch) -> (m, width)``.
    Oracles advertising ``grad_arena`` run entirely in arena space (0 extra
    full-state passes); plain grads are vmapped through the pytree boundary
    (unpack x + pack g: +4 passes per step for multi-leaf trees).
    """
    factory = getattr(grad_fn, "grad_arena", None)
    if factory is not None:
        return factory(spec), True
    vgrad = jax.vmap(grad_fn)

    def ga(xa, b):
        return spec.pack_stacked(vgrad(spec.unpack_stacked(xa), b))

    return ga, False


def use_arena(cfg: FederatedConfig, params=None) -> bool:
    """The shared layout-dispatch policy: does this (config, parameter tree)
    run the round on the flat client-state arena?  Every algorithm consults
    THIS function (it is cross-algorithm config/arena policy, not any one
    optimiser's logic).

    fsdp shards parameters per-leaf; packing would force a re-gather, so
    that layout keeps the per-leaf pytree path.  Mixed-dtype trees (bf16
    weights + f32 norms) also fall back: the single arena buffer would
    promote everything to the widest dtype -- 2x the client-state HBM and a
    numerical divergence from the per-leaf path.  ``use_arena="auto"``
    additionally keeps packed widths below ``arena_min_width`` on the pytree
    path: below the threshold the per-round pack/dispatch overhead outweighs
    the fused kernels (measured in BENCH_round.json).  The decision is
    static (spec = shapes only) and recorded in round metrics as
    ``used_arena``.
    """
    if cfg.use_arena is False or cfg.layout == "fsdp":
        return False
    if params is not None:
        if len({leaf.dtype for leaf in jax.tree.leaves(params)}) > 1:
            return False
    if cfg.use_arena == "auto" and params is not None:
        from repro.core import arena

        return arena.ArenaSpec.from_tree(params).width >= cfg.arena_min_width
    return True


def affine_case(grad_fn, spec, *, per_step=False, vr_snapshot=None):
    """Gate the fused K-step affine kernel for ``grad_fn`` on ``spec``.

    Returns the oracle's ``affine_arena`` factory when the whole inner loop
    can run as ONE kernel -- the oracle declares the affine structure, the
    batch is shared across steps (no per-step minibatches, no SVRG
    correction), and one client's (W, W) H block fits the VMEM budget --
    else None (callers fall back to the step-at-a-time scan).  Static:
    decidable from shapes alone, so it costs nothing inside jit.
    """
    affine = getattr(grad_fn, "affine_arena", None)
    if affine is None or per_step or vr_snapshot is not None:
        return None
    from repro.kernels import ops

    return affine if ops.affine_inner_fits(spec.width) else None


# ---------------------------------------------------------------------------
# cohort-sampled round engine (shared gather/scatter + mask plumbing)
# ---------------------------------------------------------------------------
#
# With ``participation < 1`` the masked round still pays O(m_total): every
# client row runs the fused K-step inner loop and the silent results are
# discarded at the tail.  The cohort engine (ISSUE 5) gathers the round's
# active rows out of the population arena, runs the SAME fused kernels on the
# (m_active, width) cohort buffer, and scatters the updated rows back; the
# server mean is taken over the scattered population buffer, which makes it
# the documented (sum_active uplink + sum_silent u_hat) / m identity and
# keeps it bit-identical to the masked path's mean-of-selected-rows.  The
# helpers below are the cross-algorithm plumbing; the per-algorithm cohort
# rounds live next to their masked siblings in gpdmm/agpdmm/scaffold/fedavg.


# algorithms with a cohort round implementation (the four arena rounds);
# fedsplit and the graph subsystem keep their previous participation
# semantics, so the launchers must never shrink their batches
COHORT_ALGOS = ("gpdmm", "agpdmm", "scaffold", "fedavg")


def use_cohort(cfg: FederatedConfig, m: int) -> bool:
    """Static policy: does this round run the cohort-sampled engine?

    Callers are the ARENA rounds of the four ``COHORT_ALGOS`` (the pytree
    path always masks -- a per-leaf gather/scatter would re-materialise the
    tree per round), plus the launchers deciding batch sizing -- hence the
    algorithm/topology guard lives HERE, not in the callers.  With
    ``cohort="auto"`` the engine engages whenever participation < 1 and the
    cohort is strictly smaller than the population (gathering all rows would
    add two copies for nothing); ``True`` forces it, ``False`` keeps the
    masked full-population path (the conformance oracle)."""
    # truthiness, not identity: validation admits cohort=0/1 (int spellings
    # of the bools, e.g. from a JSON config layer) and 0 must mean False
    if cfg.participation >= 1.0 or not cfg.cohort:
        return False
    if cfg.algorithm not in COHORT_ALGOS or cfg.topology != "star":
        return False
    # the bounded-staleness engine (core.staleness) needs the FULL population
    # each round -- a delayed client outside the cohort still has a slot to
    # age/arrive -- so async rounds pin the masked full-population path
    from repro.core import faults

    if faults.async_on(cfg):
        return False
    if cfg.cohort == "auto":
        from repro.core import tree_util as T

        return T.cohort_count(m, cfg.participation) < m
    return True


def use_popstore(cfg: FederatedConfig, m: int) -> bool:
    """Static policy: does this run keep the population's resident client
    state in the HOST store (``core.popstore``) instead of device arenas?

    The store rides the cohort engine (same participation draw, same
    gather/scatter row contract), so it engages only where ``use_cohort``
    does -- callers additionally gate on ``use_arena`` exactly as they do
    for the cohort engine itself.  ``popstore="auto"`` moves the state off
    device once the population reaches ``popstore_min_clients`` (below
    that the O(m) device buffers are cheap and per-round host<->device
    staging is pure overhead); ``True`` forces the store whenever the
    cohort engine runs, ``False`` never uses it.  The popstore round is a
    HOST-side driver (``popstore.Runner``) -- it cannot run inside an
    outer jit, which is why the launchers dispatch on this policy instead
    of ``FedOpt.round`` doing so internally."""
    if cfg.popstore is False or not use_cohort(cfg, m):
        return False
    if cfg.popstore == "auto":
        return m >= cfg.popstore_min_clients
    return True


def cohort_batch(batch, idx, m: int, per_step: bool):
    """Resolve the cohort's gradient batch.  Population-sized batch leaves
    (client dim == m) are row-gathered by ``idx``; leaves already sized to
    the cohort (a cohort-aware data stream, rows sorted by client id --
    ``tree_util.cohort_indices``'s order) pass through untouched, so at
    population scale no one has to materialise batches for silent clients.
    The client dim is axis 0, or axis 1 for per-step ``(K, m, ...)``
    batches.  Static decision (shapes only)."""
    axis = 1 if per_step else 0
    mc = idx.shape[0]

    def one(x):
        if x.shape[axis] == mc and mc != m:
            return x
        if x.shape[axis] != m:
            # a hard error, not an assert: under python -O an assert
            # vanishes and jnp.take's clamped gather would silently train
            # on duplicated rows
            raise ValueError(
                f"batch leaf client dim {x.shape[axis]} matches neither the "
                f"population ({m}) nor the cohort ({mc})")
        return jnp.take(x, idx, axis=axis)

    return jax.tree.map(one, batch)


def map_cohort_tiles(tile: int, fn, rows: tuple, batch, *, per_step: bool = False):
    """Run ``fn(rows_tile, batch_tile)`` over fixed-size tiles of the cohort
    via ``lax.map`` so peak live inner-loop state (the (tile, W, W) affine H
    blocks, per-step gradient temporaries) is O(tile), not O(m_active).

    ``rows``: tuple of ``(m_active, ...)`` arrays sliced along dim 0 (may be
    empty -- FedAvg carries no per-client rows; the tile count then comes
    from the batch).  ``batch`` leaves carry the client dim at axis 0 (or 1
    when ``per_step``).  ``fn`` returns any pytree of ``(tile, ...)`` arrays;
    outputs come back concatenated to ``(m_active, ...)``.  ``tile`` must
    divide the cohort size (checked; both are static)."""
    lead = [r.shape[0] for r in rows] or [
        jax.tree.leaves(batch)[0].shape[1 if per_step else 0]]
    mc = lead[0]
    if mc % tile:
        raise ValueError(f"cohort_tile={tile} must divide the cohort size {mc}")
    n = mc // tile
    rows_t = tuple(r.reshape((n, tile) + r.shape[1:]) for r in rows)

    def resh_batch(x):
        if per_step:  # (K, mc, ...) -> (n, K, tile, ...)
            k = x.shape[0]
            return jnp.moveaxis(x.reshape((k, n, tile) + x.shape[2:]), 1, 0)
        return x.reshape((n, tile) + x.shape[1:])

    batch_t = jax.tree.map(resh_batch, batch)
    out = jax.lax.map(lambda ab: fn(ab[0], ab[1]), (rows_t, batch_t))
    return jax.tree.map(lambda y: y.reshape((mc,) + y.shape[2:]), out)


def run_cohort_inner(cfg: FederatedConfig, fn, rows: tuple, batch, *,
                     per_step: bool = False):
    """Dispatch the cohort inner loop: tiled (``cfg.cohort_tile``) when the
    knob is set and smaller than the cohort, else one shot."""
    lead = [r.shape[0] for r in rows] or [
        jax.tree.leaves(batch)[0].shape[1 if per_step else 0]]
    tile = cfg.cohort_tile
    if tile is not None and tile < lead[0]:
        return map_cohort_tiles(tile, fn, rows, batch, per_step=per_step)
    return fn(rows, batch)


def resolved_rho(cfg: FederatedConfig) -> float:
    """The paper's default rho = 1/(K * eta) (matched to SCAFFOLD's scaling).

    rho is a SERVER-side quantity -- one penalty shared by the mean and the
    dual refresh -- so under per-client auto-eta (``eta`` resolved to a
    tuple by ``core.autotune``) the default derives from the MEAN of the
    per-client stepsizes.  Deriving it per client would hand every client
    its own penalty while the server still applies one rho in
    ``lam_s' = rho (u - x_s')``, silently desynchronising the dual refresh
    from the clients' inner steps -- pinned by ``tests/test_autotune.py``.
    Always a Python float (jit-static); raises on an unresolved "auto".
    """
    if cfg.rho is not None:
        return cfg.rho
    from repro.core import autotune

    rho = 1.0 / (cfg.inner_steps * autotune.mean_eta(cfg))
    assert rho > 0.0, rho
    return rho


def client_batches(batch, k: int, per_step: bool):
    """Yields the batch for inner step k (shared or per-step)."""
    if not per_step:
        return batch
    return jax.tree.map(lambda x: x[k], batch)


def make_scan_rounds(fed: FedOpt, grad_fn, per_step_batches: bool = False,
                     tol: float = 0.0):
    """Round-batched driver: returns ``run(state, batches) -> (state, metrics)``
    executing R full rounds inside ONE ``lax.scan`` (batch leaves carry a
    leading R dim; metrics come back stacked ``(R, ...)``).

    One jitted dispatch amortises the per-round launch overhead that
    dominates at small state sizes; with the state donated, XLA keeps the
    arena buffers in place across all R rounds.  State-identical to R
    separate ``fed.round`` calls (``tests/test_inner_loop.py``) -- the
    participation RNG is folded from the carried round counter, so masks
    match the loop-of-rounds schedule exactly.

    ``tol > 0`` (residual-based early termination, ``core.autotune``) adds
    the fused fixed-point residual of every round to the metrics
    (``res_dx2``/``res_x2``); the HOST loop between chunk dispatches applies
    the stopping rule -- the scan itself always runs its full R rounds.
    The gate is a static Python decision: ``tol=0`` compiles the identical
    fixed-budget graph, with no snapshot of the pre-round state alive.
    """

    def run(state, batches):
        def body(s, b):
            if tol > 0.0:
                from repro.core import autotune

                s2, metrics = fed.round(s, grad_fn, b, per_step_batches)
                return s2, {**metrics, **autotune.state_residual(s, s2)}
            return fed.round(s, grad_fn, b, per_step_batches)

        return jax.lax.scan(body, state, batches)

    return run


def make(cfg: FederatedConfig) -> FedOpt:
    from repro.core import agpdmm, fedavg, fedsplit, gpdmm, pdmm_graph, scaffold

    algos = {
        "gpdmm": gpdmm.make,
        "agpdmm": agpdmm.make,
        "scaffold": scaffold.make,
        "fedavg": fedavg.make,
        "fedsplit": fedsplit.make_inexact,
        # decentralized graph-PDMM (core.pdmm_graph over core.topology);
        # explicit names run the graph subsystem on ANY topology incl. star
        # (the conformance oracle), while plain "gpdmm" on a non-star
        # topology reroutes below
        "pdmm_graph": pdmm_graph.make_exact,
        "gpdmm_graph": pdmm_graph.make,
    }
    if cfg.algorithm not in algos:
        raise KeyError(f"unknown federated algorithm {cfg.algorithm!r}")
    if isinstance(cfg.eta, str):
        raise ValueError(
            "eta='auto' must be resolved host-side before the round is "
            "built: call core.autotune.resolve(cfg, grad_fn, params, m, "
            "batch) to derive the per-client stepsizes")
    if cfg.topology != "star" and cfg.algorithm not in ("pdmm_graph", "gpdmm_graph"):
        if cfg.algorithm == "gpdmm":
            # GPDMM over a general network IS graph-PDMM with the gradient
            # inner loop; route it rather than silently ignoring the topology
            return pdmm_graph.make(cfg)
        raise ValueError(
            f"algorithm {cfg.algorithm!r} has no decentralized analogue over "
            f"topology={cfg.topology!r}; use 'gpdmm' (rerouted to graph-PDMM), "
            f"'gpdmm_graph', or 'pdmm_graph'"
        )
    return algos[cfg.algorithm](cfg)
