"""recurrentgemma-9b -- Griffin-style hybrid: RG-LRU + local attn, 1:2
[arXiv:2402.19427].

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000.  Pattern is two
recurrent blocks followed by one local-attention block (window 2048).
38 = 12 full (rec,rec,local) units + 2 trailing rec blocks.
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "local"),
    attn_kind="gqa",
    window=2048,
    rec_d_state=4096,
    conv_width=4,
    norm_kind="rmsnorm",
    act="gelu",
    subquadratic=True,  # local attention window + O(1) recurrence
    fed=FederatedConfig(algorithm="gpdmm", layout="client_axis"),
    microbatch=4,  # grad-accum chunks per inner step (activation memory)
    source="arXiv:2402.19427 (RecurrentGemma / Griffin)",
)
