"""rwkv6-1.6b -- Finch, data-dependent decay, attention-free [arXiv:2404.05892].

24L d_model=2048 d_ff=7168 vocab=65536.  Pure SSM-style recurrence: runs
long_500k natively (O(1) decode state).
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,  # wkv heads = d_model / wkv_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("rwkv",),
    attn_kind="none",
    wkv_head_dim=64,
    norm_kind="layernorm",
    subquadratic=True,
    fed=FederatedConfig(algorithm="gpdmm", layout="client_axis"),
    microbatch=4,  # grad-accum chunks per inner step (activation memory)
    source="arXiv:2404.05892 (RWKV-6 Finch)",
)
