"""RWKV-6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Faithful pieces: token-shift interpolation, low-rank *data-dependent* decay
w_t = exp(-exp(w0 + tanh(x A) B)) (the headline Finch feature), per-head wkv
state S in R^{K x V} with bonus u, group-norm on the wkv output, squared-relu
channel mix.  Simplification (noted in DESIGN.md): the r/k/v/g token-shift
mixes are static (mu) rather than LoRA-dynamic; the decay is fully dynamic.

State per layer (decode): {"tm_last": (B,D), "cm_last": (B,D),
"s": (B,H,K,V)} -- O(1) in sequence length, which is what makes long_500k run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import layers as L

DECAY_LORA = 64


def rwkv_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    hd = cfg.wkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 12)
    p, s = {}, {}

    def add(name, val, spec):
        p[name] = val
        s[name] = spec

    for i, nm in enumerate(["mu_r", "mu_k", "mu_v", "mu_g", "mu_w"]):
        add(nm, jnp.full((d,), 0.5, dtype), ("embed",))
    add("wr", L.dense_init(ks[0], (d, d), ("embed", "heads"), dtype)[0], ("embed", "heads"))
    add("wk", L.dense_init(ks[1], (d, d), ("embed", "heads"), dtype)[0], ("embed", "heads"))
    add("wv", L.dense_init(ks[2], (d, d), ("embed", "heads"), dtype)[0], ("embed", "heads"))
    add("wg", L.dense_init(ks[3], (d, d), ("embed", "heads"), dtype)[0], ("embed", "heads"))
    add("wo", L.dense_init(ks[4], (d, d), ("heads", "embed"), dtype)[0], ("heads", "embed"))
    # data-dependent decay: w = exp(-exp(w0 + tanh(xw @ A) @ B))
    add("w0", jnp.full((d,), -6.0, jnp.float32), ("embed",))
    add("decay_a", L.dense_init(ks[5], (d, DECAY_LORA), ("embed", None), dtype)[0], ("embed", None))
    add("decay_b", (jax.random.normal(ks[6], (DECAY_LORA, d)) * 0.01).astype(dtype), (None, "heads"))
    add("u", (jax.random.normal(ks[7], (h, hd)) * 0.1).astype(jnp.float32), ("heads", None))
    # channel mix
    add("cm_mu_k", jnp.full((d,), 0.5, dtype), ("embed",))
    add("cm_mu_r", jnp.full((d,), 0.5, dtype), ("embed",))
    add("cm_wk", L.dense_init(ks[8], (d, cfg.d_ff), ("embed", "ff"), dtype)[0], ("embed", "ff"))
    add("cm_wv", L.dense_init(ks[9], (cfg.d_ff, d), ("ff", "embed"), dtype)[0], ("ff", "embed"))
    add("cm_wr", L.dense_init(ks[10], (d, d), ("embed", "heads"), dtype)[0], ("embed", "heads"))
    return p, s


def _shift(x, last):
    """Token shift: returns x_{t-1} (with ``last`` for t=0). x: (B,S,D)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def rwkv_time_mix(cfg: ArchConfig, params, x, *, mode: str, state=None):
    """x: (B, S, D) normalized block input. Returns (out, new_state)."""
    B, S, D = x.shape
    hd = cfg.wkv_head_dim
    H = D // hd
    last = state["tm_last"] if state is not None else jnp.zeros((B, D), x.dtype)
    xp = _shift(x, last) if mode != "decode" else last[:, None, :]
    mix = lambda mu: x + (xp - x) * mu  # noqa: E731

    # NB (SSPerf H5, refuted): explicitly pinning the head axis to "model"
    # here changes nothing -- GSPMD already propagates head sharding through
    # the wkv path; the f32 (B,S,D) collectives in the train HLO are the
    # token-shift-mix backward psums + scan-boundary re-materialisations,
    # inherent to the 5-way mix structure.
    r = (mix(params["mu_r"]) @ params["wr"]).reshape(B, S, H, hd)
    k = (mix(params["mu_k"]) @ params["wk"]).reshape(B, S, H, hd)
    v = (mix(params["mu_v"]) @ params["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(mix(params["mu_g"]) @ params["wg"])
    xw = mix(params["mu_w"])
    dec = params["w0"] + jnp.tanh(xw @ params["decay_a"]).astype(jnp.float32) @ params["decay_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(B, S, H, hd)  # in (0,1)

    s0 = state["s"] if state is not None else jnp.zeros((B, H, hd, hd), jnp.float32)
    if mode == "decode":
        y, s_new = ops.wkv6_step(r[:, 0], k[:, 0], v[:, 0], w[:, 0].astype(jnp.float32), params["u"], s0)
        y = y[:, None]
    else:
        y, s_new = ops.wkv6(r, k, v, w, params["u"], s0)
    y = L.groupnorm_heads(y.reshape(B, S, D), H) * g
    out = y @ params["wo"]
    new_state = None
    if mode != "train":
        new_state = {"tm_last": x[:, -1, :], "s": s_new}
    return out, new_state


def rwkv_channel_mix(cfg: ArchConfig, params, x, *, mode: str, state=None):
    B, S, D = x.shape
    last = state["cm_last"] if state is not None else jnp.zeros((B, D), x.dtype)
    xp = _shift(x, last) if mode != "decode" else last[:, None, :]
    xk = x + (xp - x) * params["cm_mu_k"]
    xr = x + (xp - x) * params["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ params["cm_wk"]))
    out = jax.nn.sigmoid(xr @ params["cm_wr"]) * (kk @ params["cm_wv"])
    new_state = {"cm_last": x[:, -1, :]} if mode != "train" else None
    return out, new_state


def rwkv_state_shape(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    hd = cfg.wkv_head_dim
    h = d // hd
    return {
        "tm_last": jax.ShapeDtypeStruct((batch, d), dtype),
        "cm_last": jax.ShapeDtypeStruct((batch, d), dtype),
        "s": jax.ShapeDtypeStruct((batch, h, hd, hd), jnp.float32),
    }


def rwkv_state_spec():
    return {"tm_last": ("batch", None), "cm_last": ("batch", None), "s": ("batch", "heads", None, None)}
