"""Pallas TPU flash attention (causal / sliding-window, GQA-aware).

TPU adaptation: grid (B*H, Sq/QB, Sk/KB) with the key axis innermost and
sequential; the online-softmax accumulators (m, l, acc) live in VMEM scratch
across key steps.  GQA is handled in the *index map* -- the k/v BlockSpecs
map query-head bh to kv-head bh // group -- so grouped keys are never
materialised per query head.

Positions are assumed contiguous (q_pos = arange(Sq) + offset, k_pos =
arange(Sk)): the train/prefill case this kernel serves.  Decode uses
``ops.attend_cache`` (a single-token einsum, not kernel-worthy).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_QB = 128
DEFAULT_KB = 128
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window, q_offset: int, qb: int, kb: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qi = pl.program_id(1)
    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0) + q_offset
    k_pos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)

    valid = jnp.ones((qb, kb), jnp.bool_)
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window is not None:
        valid = valid & (k_pos > q_pos - window)

    q = q_ref[0].astype(jnp.float32)  # (qb, hd)
    k = k_ref[0].astype(jnp.float32)  # (kb, hd)
    v = v_ref[0].astype(jnp.float32)  # (kb, vd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q, k, v, q_pos=None, k_pos=None, *,
    causal: bool = True, window=None,
    q_block: int = DEFAULT_QB, k_block: int = DEFAULT_KB,
    interpret: bool = False,
):
    """q (B,Sq,H,hd); k (B,Sk,Hkv,hd); v (B,Sk,Hkv,vd).  q_pos/k_pos accepted
    for API parity with ops.flash_attention but must be contiguous aranges
    (q offset = Sk - Sq supported for suffix queries)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    G = H // Hkv
    q_offset = 0
    if q_pos is not None:
        q_offset = int(jax.device_get(q_pos[0])) if not isinstance(q_pos, jax.core.Tracer) else 0

    qb = min(q_block, Sq)
    kb = min(k_block, Sk)
    assert Sq % qb == 0 and Sk % kb == 0

    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, Sk, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, Sk, vd)

    grid = (B * H, Sq // qb, Sk // kb)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b = bh // H
        h = bh % H
        return (b * Hkv + h // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=1.0 / math.sqrt(hd),
            causal=causal,
            window=window,
            q_offset=q_offset,
            qb=qb,
            kb=kb,
            nk=Sk // kb,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, hd), q_map),
            pl.BlockSpec((1, kb, hd), kv_map),
            pl.BlockSpec((1, kb, vd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, qb, vd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, vd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, vd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out.reshape(B, H, Sq, vd), 1, 2)
