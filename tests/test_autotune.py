"""Auto-tuned stepsizes + residual-based early termination (core.autotune).

Pins the ISSUE 10 contracts: power-iteration L_i matches eigvalsh on the
quadratic testbed (every oracle-protocol fallback), ``eta="auto"`` resolves
to per-client stepsizes that train at least as well as the hand-tuned
scalar, the residual metrics never perturb the trajectory (bitwise), the
``tol=0`` gate compiles the identical fixed-budget graph, and the launcher
resumes cleanly across an early-exited run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederatedConfig
from repro.core import autotune, make, quadratic
from repro.core.api import make_oracle, make_scan_rounds, resolved_rho


@pytest.fixture(scope="module", params=[24, 144], ids=["narrow", "wide"])
def prob(request):
    # 24 stays inside one 128-lane arena row; 144 forces lane padding, so
    # the padded-coordinate invariants of the power iteration get exercised
    return quadratic.generate(jax.random.key(3), m=6, n=160, d=request.param)


def exact_L(prob):
    return np.asarray(prob.evals[:, -1], np.float64)


# ---------------------------------------------------------------------------
# power iteration == eigvalsh, through every oracle-protocol resolution path
# ---------------------------------------------------------------------------

# The Rayleigh quotient converges as (lambda_2/lambda_1)^(2k): the default
# POWER_ITERS budget pins L to ~0.1% (plenty for a stepsize with a 2x safety
# margin); a longer run converges to f32 precision -- both are pinned.
DEFAULT_RTOL = 5e-3


def test_power_iter_arena_matches_eigvalsh(prob):
    L = np.asarray(autotune.power_iter_arena(prob.AtA), np.float64)
    np.testing.assert_allclose(L, exact_L(prob), rtol=DEFAULT_RTOL)
    L_long = np.asarray(autotune.power_iter_arena(prob.AtA, iters=600),
                        np.float64)
    np.testing.assert_allclose(L_long, exact_L(prob), rtol=1e-4)


def test_estimate_L_curvature_hook(prob):
    # the annotated oracle resolves through its own curvature_arena hook
    L = autotune.estimate_L(prob.oracle(), jnp.zeros((prob.d,)), prob.m,
                            prob.batch())
    np.testing.assert_allclose(L, exact_L(prob), rtol=DEFAULT_RTOL)


def test_estimate_L_affine_fallback(prob):
    o = prob.oracle()
    oracle = make_oracle(prob.grad, affine_arena=o.affine_arena)
    L = autotune.estimate_L(oracle, jnp.zeros((prob.d,)), prob.m, prob.batch(),
                            iters=600)
    np.testing.assert_allclose(L, exact_L(prob), rtol=1e-4)


def test_estimate_L_hvp_fallbacks(prob):
    # grad_arena HVP and the plain-pytree vmapped HVP both recover the same
    # spectrum (the gradient is affine, so the jvp Hessian IS AtA) -- probe
    # at a NONZERO point to catch any accidental dependence on params
    params = jnp.linspace(-1.0, 1.0, prob.d)
    o = prob.oracle()
    via_ga = autotune.estimate_L(
        make_oracle(prob.grad, grad_arena=o.grad_arena),
        params, prob.m, prob.batch(), iters=600)
    via_tree = autotune.estimate_L(prob.grad, params, prob.m, prob.batch(),
                                   iters=600)
    np.testing.assert_allclose(via_ga, exact_L(prob), rtol=1e-4)
    np.testing.assert_allclose(via_tree, exact_L(prob), rtol=1e-4)


# ---------------------------------------------------------------------------
# eta="auto" resolution
# ---------------------------------------------------------------------------

def test_resolve_auto_derives_per_client_eta(prob):
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=5, eta="auto")
    rc = autotune.resolve(cfg, prob.oracle(), jnp.zeros((prob.d,)), prob.m,
                          prob.batch())
    assert isinstance(rc.eta, tuple) and len(rc.eta) == prob.m
    np.testing.assert_allclose(np.asarray(rc.eta),
                               autotune.SAFETY / exact_L(prob),
                               rtol=DEFAULT_RTOL)
    # no-op on an already-concrete eta
    cfg2 = dataclasses.replace(cfg, eta=0.1)
    assert autotune.resolve(cfg2, prob.oracle(), jnp.zeros((prob.d,)),
                            prob.m, prob.batch()) is cfg2


def test_make_rejects_unresolved_auto():
    with pytest.raises(ValueError, match="resolved host-side"):
        make(FederatedConfig(eta="auto"))


def test_config_validation_errors():
    for bad in [dict(eta=-0.1), dict(eta=0.0), dict(eta="bogus"),
                dict(eta=()), dict(eta=(0.1, -0.2)), dict(inner_steps=0),
                dict(tol=-1e-6), dict(patience=0)]:
        with pytest.raises(ValueError):
            FederatedConfig(**bad)
    FederatedConfig(eta="auto")           # the unresolved marker is legal
    FederatedConfig(eta=(0.1, 0.2), tol=1e-5, patience=3)


def test_resolved_rho_uses_mean_eta():
    # rho is ONE server-side penalty: under per-client eta the 1/(K*eta)
    # default derives from the mean stepsize (see core.api.resolved_rho)
    etas = (0.1, 0.2, 0.4)
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=5, eta=etas)
    assert resolved_rho(cfg) == pytest.approx(
        1.0 / (5 * float(np.mean(etas))))
    assert autotune.mean_eta(cfg) == pytest.approx(float(np.mean(etas)))
    with pytest.raises(ValueError, match="resolved host-side"):
        resolved_rho(FederatedConfig(eta="auto"))


def test_client_eta_forms():
    assert autotune.client_eta(FederatedConfig(eta=0.25)) == 0.25
    arr = autotune.client_eta(FederatedConfig(eta=(0.1, 0.2)), m=2)
    assert arr.dtype == np.float32 and arr.shape == (2,)
    with pytest.raises(ValueError, match="2 entries for 3"):
        autotune.client_eta(FederatedConfig(eta=(0.1, 0.2)), m=3)
    with pytest.raises(ValueError, match="resolved host-side"):
        autotune.client_eta(FederatedConfig(eta="auto"))


def test_scale_eta_both_forms():
    c1 = autotune.scale_eta(FederatedConfig(eta=0.4), 0.5)
    assert c1.eta == pytest.approx(0.2)
    c2 = autotune.scale_eta(FederatedConfig(eta=(0.4, 0.8)), 0.5)
    assert c2.eta == pytest.approx((0.2, 0.4))


# ---------------------------------------------------------------------------
# auto-eta trains: at least as well as the hand-tuned global stepsize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm", "scaffold"])
def test_auto_eta_converges_at_least_as_well(prob, algo):
    x0 = jnp.zeros((prob.d,))
    oracle = prob.oracle()

    def dist_after(cfg, rounds=120):
        opt = make(cfg)
        s = opt.init(x0, prob.m)

        @jax.jit
        def rf(s):
            return opt.round(s, oracle, prob.batch())

        for _ in range(rounds):
            s, _ = rf(s)
        return float(prob.dist(opt.server_params(s)))

    base = FederatedConfig(algorithm=algo, inner_steps=5, eta="auto")
    auto = autotune.resolve(base, oracle, x0, prob.m, prob.batch())
    d_auto = dist_after(auto)
    # per-client eta_i = safety/L_i dominates the one-global-stepsize
    # setting eta = safety/max_i L_i coordinate-wise, so the auto run must
    # land at least as close (small slack for f32 trajectory noise)
    d_hand = dist_after(dataclasses.replace(base, eta=autotune.SAFETY / prob.L))
    assert d_auto < 1e-2, d_auto
    assert d_auto <= d_hand * 1.1 + 1e-6, (d_auto, d_hand)


def test_uniform_tuple_matches_scalar_trajectory(prob):
    # a constant per-client tuple takes the operand-stepsize kernels while
    # the scalar bakes the constant -- same f32 math, same trajectory
    x0 = jnp.zeros((prob.d,))
    oracle = prob.oracle()
    eta = 0.5 / prob.L

    def run(cfg):
        opt = make(cfg)
        s = opt.init(x0, prob.m)

        @jax.jit
        def rf(s):
            return opt.round(s, oracle, prob.batch())

        for _ in range(25):
            s, _ = rf(s)
        return opt.server_params(s)

    base = FederatedConfig(algorithm="gpdmm", inner_steps=4, eta=eta)
    xs = run(base)
    xt = run(dataclasses.replace(base, eta=(float(eta),) * prob.m))
    np.testing.assert_allclose(np.asarray(xt), np.asarray(xs),
                               rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# residual metrics: bitwise-invisible to the trajectory; tol=0 == same graph
# ---------------------------------------------------------------------------

def _scan_setup(prob, tol):
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=3, eta=0.5 / prob.L,
                          tol=tol)
    fed = make(cfg)
    oracle = prob.oracle()
    R = 6
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), prob.batch())
    return fed, oracle, batches


def test_residual_metrics_do_not_perturb_trajectory(prob):
    fed, oracle, batches = _scan_setup(prob, tol=1e-6)
    s0 = fed.init(jnp.zeros((prob.d,)), prob.m)
    plain = jax.jit(make_scan_rounds(fed, oracle))
    with_res = jax.jit(make_scan_rounds(fed, oracle, tol=1e-6))
    sp, mp = plain(s0, batches)
    sr, mr = with_res(s0, batches)
    assert "res_dx2" not in mp and "res_dx2" in mr
    assert mr["res_dx2"].shape == (6,)
    for a, b in zip(jax.tree.leaves(sp), jax.tree.leaves(sr)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the metric itself is the true squared step of the round
    s1, m1 = jax.jit(lambda s, b: fed.round(s, oracle, b))(
        s0, prob.batch())
    dx2 = sum(float(jnp.sum(jnp.square(jnp.asarray(q, jnp.float32)
                                       - jnp.asarray(p, jnp.float32))))
              for k in autotune.RESIDUAL_KEYS if k in s0
              for p, q in zip(jax.tree.leaves(s0[k]), jax.tree.leaves(s1[k])))
    np.testing.assert_allclose(float(mr["res_dx2"][0]), dx2, rtol=1e-4)


def test_tol_zero_compiles_identical_graph(prob):
    # tol=0 is a static Python gate: the scan driver must lower to the very
    # same HLO as the pre-autotune fixed-budget driver (no dead residual
    # computation, no snapshot of the pre-round state kept alive)
    fed, oracle, batches = _scan_setup(prob, tol=0.0)
    s0 = fed.init(jnp.zeros((prob.d,)), prob.m)
    legacy = jax.jit(make_scan_rounds(fed, oracle)).lower(s0, batches)
    gated = jax.jit(make_scan_rounds(fed, oracle, tol=0.0)).lower(s0, batches)
    assert legacy.as_text() == gated.as_text()


# ---------------------------------------------------------------------------
# EarlyExit host tracker
# ---------------------------------------------------------------------------

def test_early_exit_tracker_rules():
    ee = autotune.EarlyExit(tol=1e-3, patience=2)
    # one sub-tol round is not enough at patience=2
    assert ee.update(np.float64(1e-8), np.float64(1.0)) is None
    # a bad round resets the consecutive count
    assert ee.update(np.float64(1.0), np.float64(1.0)) is None
    assert ee.update(np.float64(1e-8), np.float64(1.0)) is None
    assert ee.update(np.float64(1e-8), np.float64(1.0)) == 0
    # stacked chunk: fires mid-chunk with the in-chunk index
    ee2 = autotune.EarlyExit(tol=1e-3, patience=2)
    stop = ee2.update(np.array([1e-8, 1e-8, 1.0]), np.ones((3,)))
    assert stop == 1
    assert ee2.last_rel == pytest.approx(1e-4)
    # tol=0 never fires
    ee3 = autotune.EarlyExit(tol=0.0)
    assert ee3.update(np.zeros((4,)), np.ones((4,))) is None


def test_early_exit_is_a_prefix_of_the_fixed_budget_run(prob):
    # the early-exited trajectory IS the fixed-budget trajectory, truncated:
    # replay the same rounds and stop where the tracker fires; states match
    # the full run bitwise at the stop round
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=3, eta=0.5 / prob.L,
                          tol=1e-3, patience=2)
    fed = make(cfg)
    oracle = prob.oracle()
    s0 = fed.init(jnp.zeros((prob.d,)), prob.m)

    @jax.jit
    def rf(s):
        s2, mets = fed.round(s, oracle, prob.batch())
        return s2, {**mets, **autotune.state_residual(s, s2)}

    ee = autotune.EarlyExit(cfg.tol, cfg.patience)
    s, stop_at = s0, None
    for r in range(1, 201):
        s, mets = rf(s)
        if ee.update(mets["res_dx2"], mets["res_x2"]) is not None:
            stop_at = r
            break
    assert stop_at is not None and stop_at < 200, "tracker never fired"
    assert float(prob.dist(fed.server_params(s))) < 1.0

    s_full = s0
    for _ in range(stop_at):
        s_full, _ = rf(s_full)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s_full)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# launcher: --eta auto --tol end-to-end, early exit, resume across it
# ---------------------------------------------------------------------------

def test_launcher_auto_eta_early_exit_and_resume(tmp_path):
    from repro.launch.train import run as train_run

    kw = dict(reduced=True, algorithm="gpdmm", k=2, eta="auto",
              m=2, per_client_batch=2, seq_len=32, log_every=2,
              ckpt_dir=str(tmp_path))
    # a loose tol fires within the budget; the run records rounds_saved
    hist = train_run("olmo-1b", steps=12, tol=0.5, patience=2, **kw)
    assert hist, "no rounds logged"
    stopped = hist[-1]["round"]
    assert stopped < 12, f"early exit never fired (ran to {stopped})"
    assert "res_dx2" in hist[-1]

    # resume continues the SAME trajectory past the early exit: the
    # fingerprint records eta='auto' and re-derives the identical tuple
    hist2 = train_run("olmo-1b", steps=stopped + 2, tol=0.0, resume=True, **kw)
    assert hist2[-1]["round"] == stopped + 2
    assert np.isfinite(hist2[-1]["server_loss"])
