"""Synthetic data pipeline: generators + federated partitioning."""
from repro.data import partition, synthetic

__all__ = ["partition", "synthetic"]
