"""olmo-1b -- dense, non-parametric LayerNorm [arXiv:2402.00838].

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.  OLMo uses non-parametric
LayerNorm (no scale/bias) and tied embeddings.
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    block_pattern=("dense",),
    attn_kind="gqa",
    norm_kind="nonparam_ln",
    tie_embeddings=True,
    act="silu",
    subquadratic=False,  # long_500k skipped (full attention; see DESIGN.md)
    fed=FederatedConfig(algorithm="gpdmm", layout="client_axis"),
    microbatch=4,  # grad-accum chunks per inner step (activation memory)
    source="arXiv:2402.00838 (OLMo)",
)
