"""ISSUE 7: bounded-staleness async rounds -- straggler deadlines, the fused
stale-uplink admission kernel, and the hot-swap serving path.

The load-bearing invariant: at the synchronous point (``max_staleness=0``,
``deadline=inf``) the async engine is BIT-IDENTICAL to the delay-as-silence
masked round, for all four centralised algorithms on both layouts.  The
chain is structural -- the delay draw keeps fold id 2 whether it lands in
``silent`` or ``delayed`` (so the excluded client set is identical), the
``w > 0`` guard in ``ops.stale_mix`` returns the masked select bitwise when
nothing is admitted, and the fresh mask excludes delayed rows exactly as
the silence contract does -- and the tests pin every link: the plan
invariants, the schedule algebra on hand-built slots, the kernel parity,
the whole-round collapse, the deadline demotion, and bitwise --resume
replay of a stale trace through the training launcher.

Also here: the hot-swap serving satellites -- ``checkpoint.steps``,
``load_with_retry`` backoff, and the ``HotSwapWatcher``'s loud rejection of
truncated anchors with degradation to the last good step.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs.base import FaultConfig, FederatedConfig
from repro.core import api, faults, make, quadratic, staleness
from repro.kernels import ops

ALGOS = ["gpdmm", "agpdmm", "scaffold", "fedavg"]
M = 8
D = 24
STALE_KEYS = set(staleness.STATE_KEYS)


def _params():
    return {"w": 0.7 * jnp.ones((D,), jnp.float32)}


def _grad(p, b):
    return jax.tree.map(lambda x: 0.1 * x, p)


def _batch(m=M):
    return {"d": jnp.zeros((m, 1), jnp.float32)}


def _run(cfg, rounds, m=M):
    fed = make(cfg)
    s = fed.init(_params(), m)
    rows = []
    for _ in range(rounds):
        s, mx = fed.round(s, _grad, _batch(m))
        rows.append(mx)
    return s, rows


def _assert_trees_equal(a, b, ignore=()):
    a = {k: v for k, v in a.items() if k not in ignore}
    b = {k: v for k, v in b.items() if k not in ignore}
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for xa, xb in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def _cfg(algo="gpdmm", *, delay=0.4, seed=3, **kw):
    return FederatedConfig(algorithm=algo, inner_steps=2, eta=0.02,
                           faults=FaultConfig(delay=delay, seed=seed), **kw)


# ---------------------------------------------------------------------------
# config surface + async_on policy
# ---------------------------------------------------------------------------

def test_fault_config_parse_back_compatible():
    # pinned pre-ISSUE-7 string still parses; delay_max joins as an int knob
    fc = FaultConfig.parse("dropout=0.1,corrupt=0.05,seed=7")
    assert fc.dropout == 0.1 and fc.corrupt == 0.05 and fc.seed == 7
    fc = FaultConfig.parse("delay=0.3,delay_max=6,seed=2")
    assert fc.delay == 0.3 and fc.delay_max == 6 and isinstance(fc.delay_max, int)
    with pytest.raises(ValueError):
        FaultConfig(delay=0.1, delay_max=0)


def test_staleness_knobs_validated():
    with pytest.raises(ValueError, match="deadline"):
        _cfg(deadline=0.0)
    with pytest.raises(ValueError, match="max_staleness"):
        _cfg(max_staleness=-1)
    with pytest.raises(ValueError, match="stale_gamma"):
        _cfg(stale_gamma=0.0)
    with pytest.raises(ValueError, match="async_rounds"):
        _cfg(async_rounds="maybe")


def test_async_on_policy():
    # auto: off at the synchronous point, on when a knob deviates
    assert not faults.async_on(_cfg())
    assert faults.async_on(_cfg(max_staleness=2))
    assert faults.async_on(_cfg(deadline=3.0))
    # forced on/off override auto
    assert faults.async_on(_cfg(async_rounds=True))
    assert not faults.async_on(_cfg(async_rounds=False, max_staleness=2))
    # no delay schedule, or a graph topology -> never on
    assert not faults.async_on(FederatedConfig(
        algorithm="gpdmm", inner_steps=1, eta=0.1, async_rounds=True,
        faults=FaultConfig(dropout=0.3)))
    assert not faults.async_on(_cfg(async_rounds=True, topology="ring",
                                    use_arena=True))


def test_async_pins_masked_population_path():
    # the cohort engine cannot age/arrive slots for out-of-cohort clients
    cfg = _cfg(max_staleness=2, participation=0.5, num_clients=M, cohort=True)
    assert not api.use_cohort(cfg, M)
    cfg_sync = _cfg(participation=0.5, num_clients=M, cohort=True)
    assert api.use_cohort(cfg_sync, M)


# ---------------------------------------------------------------------------
# the plan: delayed is a soft class, same excluded set either way
# ---------------------------------------------------------------------------

def test_plan_delay_soft_class_invariants():
    cfg = _cfg(delay=0.5, max_staleness=2)
    cfg_off = _cfg(delay=0.5, async_rounds=False)
    for r in range(6):
        p = faults.plan(cfg, r, 16)
        p_off = faults.plan(cfg_off, r, 16)
        d, s, lat = (np.asarray(p.delayed), np.asarray(p.silent),
                     np.asarray(p.lateness))
        # disjoint from silence and corruption
        assert not (d & s).any()
        assert not (d & np.asarray(p.corrupt)).any()
        # lateness in [1, delay_max] exactly on delayed rows
        assert (lat[d] >= 1).all() and (lat[d] <= cfg.faults.delay_max).all()
        assert (lat[~d] == 0).all()
        # SAME excluded client set as the delay-as-silence draw (fold id 2
        # is shared): this is what makes the synchronous collapse bitwise
        np.testing.assert_array_equal(d | s, np.asarray(p_off.silent))
        np.testing.assert_array_equal(np.asarray(p_off.delayed),
                                      np.zeros(16, bool))


def test_plan_deadline_demotes_late_stragglers():
    # deadline below every possible lateness -> all delayed rows demote to
    # silence at plan time; the plan equals the async-off plan exactly
    cfg = _cfg(delay=0.6, max_staleness=3, deadline=0.5)
    cfg_off = _cfg(delay=0.6, async_rounds=False)
    for r in range(4):
        p = faults.plan(cfg, r, 16)
        p_off = faults.plan(cfg_off, r, 16)
        assert not np.asarray(p.delayed).any()
        assert (np.asarray(p.lateness) == 0).all()
        np.testing.assert_array_equal(np.asarray(p.silent),
                                      np.asarray(p_off.silent))
    # a mid-range deadline keeps exactly the lateness <= deadline rows
    cfg_mid = FederatedConfig(
        algorithm="gpdmm", inner_steps=2, eta=0.02, deadline=2.0,
        max_staleness=3, faults=FaultConfig(delay=0.6, delay_max=4, seed=3))
    cfg_inf = FederatedConfig(
        algorithm="gpdmm", inner_steps=2, eta=0.02, max_staleness=3,
        faults=FaultConfig(delay=0.6, delay_max=4, seed=3))
    saw_demotion = False
    for r in range(8):
        p_mid = faults.plan(cfg_mid, r, 16)
        p_inf = faults.plan(cfg_inf, r, 16)
        lat = np.asarray(p_inf.lateness)
        late = np.asarray(p_inf.delayed) & (lat > 2.0)
        saw_demotion |= late.any()
        np.testing.assert_array_equal(
            np.asarray(p_mid.delayed), np.asarray(p_inf.delayed) & ~late)
        np.testing.assert_array_equal(
            np.asarray(p_mid.silent), np.asarray(p_inf.silent) | late)
    assert saw_demotion  # the sweep actually exercised a demotion


# ---------------------------------------------------------------------------
# the schedule algebra on hand-built slots
# ---------------------------------------------------------------------------

def _hand_plan(delayed, lateness):
    m = len(delayed)
    z = jnp.zeros((m,), bool)
    return faults.FaultPlan(
        silent=z, corrupt=z, kind=jnp.zeros((m,), jnp.int32),
        delayed=jnp.asarray(delayed, bool),
        lateness=jnp.asarray(lateness, jnp.int32))


def test_schedule_hand_computed():
    cfg = _cfg(max_staleness=2, stale_gamma=0.5)
    # slots: [empty, in-flight age0/lat1, in-flight age0/lat2,
    #         in-flight age1/lat2, empty+new delayed, busy+new delayed]
    age = jnp.asarray([-1, 0, 0, 1, -1, 0], jnp.int32)
    lat = jnp.asarray([0, 1, 2, 2, 0, 3], jnp.int32)
    fplan = _hand_plan([False, False, False, False, True, True],
                       [0, 0, 0, 0, 2, 1])
    store, w, arriving, admit, age_new, lat_new = staleness._schedule(
        cfg, fplan, age, lat)
    # slot 1: age 0 -> 1 >= lat 1: arrives, admitted at gamma**1
    # slot 2: age 0 -> 1 <  lat 2: still in flight
    # slot 3: age 1 -> 2 >= lat 2: arrives, admitted at gamma**2
    # slot 4: empty + delayed: stores (lat 2)
    # slot 5: busy (not arriving: age 0 -> 1 < lat 3) + delayed: the new
    #         uplink is DROPPED -- one in-flight slot per client
    np.testing.assert_array_equal(np.asarray(arriving),
                                  [False, True, False, True, False, False])
    np.testing.assert_array_equal(np.asarray(admit),
                                  [False, True, False, True, False, False])
    np.testing.assert_array_equal(np.asarray(store),
                                  [False, False, False, False, True, False])
    np.testing.assert_allclose(np.asarray(w), [0.0, 0.5, 0.0, 0.25, 0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(age_new), [-1, -1, 1, -1, 0, 1])
    np.testing.assert_array_equal(np.asarray(lat_new), [0, 0, 2, 0, 2, 3])
    # a lateness past max_staleness arrives but is dropped, not admitted
    cfg0 = _cfg(max_staleness=1, stale_gamma=0.5)
    _, w0, arr0, adm0, _, _ = staleness._schedule(cfg0, fplan, age, lat)
    np.testing.assert_array_equal(np.asarray(arr0),
                                  [False, True, False, True, False, False])
    np.testing.assert_array_equal(np.asarray(adm0),
                                  [False, True, False, False, False, False])
    assert float(w0[3]) == 0.0


def test_step_arena_hand_computed():
    cfg = _cfg(max_staleness=2, stale_gamma=0.5)
    m, w_ = 3, 4
    uplink = jnp.arange(m * w_, dtype=jnp.float32).reshape(m, w_) + 1.0
    cache = -jnp.ones((m, w_), jnp.float32)
    buf = 10.0 * jnp.ones((m, w_), jnp.float32)
    # client 0: fresh; client 1: arriving admitted (age0/lat1);
    # client 2: delayed now (stores into its empty slot)
    state = {"stale_buf": buf,
             "stale_age": jnp.asarray([-1, 0, -1], jnp.int32),
             "stale_lat": jnp.asarray([0, 1, 0], jnp.int32)}
    fplan = _hand_plan([False, False, True], [0, 0, 2])
    mixed, fresh, upd, mx = staleness.step_arena(
        cfg, fplan, uplink, cache, None, state)
    np.testing.assert_array_equal(np.asarray(fresh), [True, True, False])
    # client 0: fresh uplink straight through
    np.testing.assert_array_equal(np.asarray(mixed[0]), np.asarray(uplink[0]))
    # client 1: fresh base mixed half-way toward the buffered row
    np.testing.assert_allclose(
        np.asarray(mixed[1]), np.asarray(0.5 * uplink[1] + 0.5 * buf[1]))
    # client 2: delayed -> cache covers it this round, uplink into the slot
    np.testing.assert_array_equal(np.asarray(mixed[2]), np.asarray(cache[2]))
    np.testing.assert_array_equal(np.asarray(upd["stale_buf"][2]),
                                  np.asarray(uplink[2]))
    np.testing.assert_array_equal(np.asarray(upd["stale_age"]), [-1, -1, 0])
    np.testing.assert_array_equal(np.asarray(upd["stale_lat"]), [0, 0, 2])
    assert float(mx["stale_buffered"]) == 1.0
    assert float(mx["stale_admitted"]) == 1.0
    assert float(mx["stale_dropped"]) == 0.0


# ---------------------------------------------------------------------------
# the fused kernel: interpret parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 128), (6, 384), (5, 130)],
                         ids=["one_block", "multi", "padded_width"])
@pytest.mark.parametrize("per_row", [False, True], ids=["bcast", "per_row"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_stale_mix_kernel_interpret_parity(shape, per_row, dtype):
    m, w_ = shape
    ks = jax.random.split(jax.random.key(0), 3)
    uplink = jax.random.normal(ks[0], (m, w_), jnp.float32).astype(dtype)
    cache = jax.random.normal(
        ks[1], (m, w_) if per_row else (w_,), jnp.float32).astype(dtype)
    buf = jax.random.normal(ks[2], (m, w_), jnp.float32).astype(dtype)
    fresh = jnp.arange(m) % 2 == 0
    store = jnp.arange(m) % 3 == 0
    w = jnp.where(jnp.arange(m) % 2 == 1, 0.5 ** (1 + jnp.arange(m) % 3), 0.0
                  ).astype(jnp.float32)
    mx, bx = ops.stale_mix(uplink, cache, buf, fresh, store, w, impl="xla")
    mp, bp = ops.stale_mix(uplink, cache, buf, fresh, store, w,
                           impl="pallas_interpret")
    # the w == 0 guard is BITWISE (it is what makes the synchronous collapse
    # exact); admitted rows agree to kernel-parity tolerance (FMA contraction
    # inside the fused body is a one-ulp reassociation)
    guarded = np.asarray(w) == 0.0
    np.testing.assert_array_equal(np.asarray(mx)[guarded],
                                  np.asarray(mp)[guarded])
    np.testing.assert_allclose(
        np.asarray(mx, np.float32), np.asarray(mp, np.float32),
        rtol=1e-5, atol=1e-5)
    # the buffer update is a pure select: bitwise everywhere
    np.testing.assert_array_equal(np.asarray(bx), np.asarray(bp))


def test_stale_mix_guard_is_bitwise_select():
    # w == 0 must return the masked select EXACTLY, even against a buffer
    # full of non-finite garbage (0 * inf = nan must never leak in)
    m, w_ = 4, 130
    uplink = jax.random.normal(jax.random.key(0), (m, w_))
    cache = jax.random.normal(jax.random.key(1), (m, w_))
    buf = jnp.full((m, w_), jnp.inf)
    fresh = jnp.asarray([True, False, True, False])
    zero_w = jnp.zeros((m,), jnp.float32)
    expect = jnp.where(fresh[:, None], uplink, cache)
    for impl in ("xla", "pallas_interpret"):
        mixed, _ = ops.stale_mix(uplink, cache, buf, fresh,
                                 jnp.zeros((m,), bool), zero_w, impl=impl)
        np.testing.assert_array_equal(np.asarray(mixed), np.asarray(expect),
                                      err_msg=impl)


# ---------------------------------------------------------------------------
# the tentpole invariant: synchronous collapse, bitwise, all four algorithms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_arena", [True, False], ids=["arena", "pytree"])
@pytest.mark.parametrize("algo", ALGOS)
def test_sync_point_collapses_to_masked_round(algo, use_arena):
    rounds = 5
    kw = dict(delay=0.4, seed=11, use_arena=use_arena)
    # async engine FORCED on at the synchronous point vs delay-as-silence
    s_async, rows_async = _run(_cfg(algo, async_rounds=True,
                                    max_staleness=0, **kw), rounds)
    s_sync, _ = _run(_cfg(algo, async_rounds=False, **kw), rounds)
    assert STALE_KEYS <= set(s_async) and not (STALE_KEYS & set(s_sync))
    _assert_trees_equal(s_async, s_sync, ignore=STALE_KEYS)
    # nothing was ever admitted; delayed rows did buffer
    assert sum(float(r["stale_admitted"]) for r in rows_async) == 0.0
    assert sum(float(r["stale_buffered"]) for r in rows_async) > 0.0


@pytest.mark.parametrize("algo", ALGOS)
def test_deadline_demotes_all_collapses(algo):
    # deadline < 1 demotes every straggler at plan time: even WITH
    # max_staleness > 0 the round is bitwise the delay-as-silence round
    rounds = 4
    s_dead, rows = _run(_cfg(algo, use_arena=True, max_staleness=3,
                             deadline=0.5), rounds)
    s_sync, _ = _run(_cfg(algo, use_arena=True, async_rounds=False), rounds)
    _assert_trees_equal(s_dead, s_sync, ignore=STALE_KEYS)
    assert sum(float(r["stale_buffered"]) for r in rows) == 0.0


def test_stale_trace_replays_bitwise():
    cfg = _cfg("gpdmm", use_arena=True, max_staleness=3, stale_gamma=0.7)
    s1, r1 = _run(cfg, 6)
    s2, r2 = _run(cfg, 6)
    _assert_trees_equal(s1, s2)
    for a, b in zip(r1, r2):
        for k in ("stale_buffered", "stale_admitted", "stale_dropped"):
            assert float(a[k]) == float(b[k])


def test_stale_round_admits_and_covers():
    # a real stale run: rows buffer, age, arrive, and get admitted; drops
    # only happen past max_staleness
    cfg = _cfg("gpdmm", use_arena=True, max_staleness=4, stale_gamma=0.7)
    _, rows = _run(cfg, 12)
    tot = {k: sum(float(r[k]) for r in rows)
           for k in ("stale_buffered", "stale_admitted", "stale_dropped")}
    assert tot["stale_buffered"] > 0
    assert tot["stale_admitted"] > 0
    assert tot["stale_dropped"] == 0.0  # delay_max=4 <= max_staleness
    # in-flight conservation: everything buffered either arrived or is
    # still in flight at the end
    assert tot["stale_admitted"] <= tot["stale_buffered"]


def test_stale_run_converges_on_quadratic():
    # acceptance: a delayed-but-admitted run lands within a factor of the
    # fault-free run on a real objective
    prob = quadratic.generate(jax.random.key(0), m=8, n=60, d=D)
    eta = 0.5 / prob.L
    rounds = 40
    base = dict(algorithm="gpdmm", inner_steps=3, eta=eta, use_arena=True)

    def obj(cfg):
        opt = make(cfg)
        s = opt.init(jnp.zeros((prob.d,)), prob.m)
        for _ in range(rounds):
            s, _ = opt.round(s, prob.oracle(), prob.batch())
        return float(prob.F(opt.server_params(s)))

    clean = obj(FederatedConfig(**base))
    stale = obj(FederatedConfig(
        faults=FaultConfig(delay=0.25, seed=7), max_staleness=3,
        stale_gamma=0.5, **base))
    scale = float(prob.F(jnp.zeros((prob.d,))) - prob.f_star)
    assert math.isfinite(stale)
    assert abs(stale - clean) <= 0.15 * scale


# ---------------------------------------------------------------------------
# bitwise --resume replay of a stale trace through the launcher
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_resume_replays_stale_trace_bitwise(tmp_path):
    from repro.launch.train import run as train_run

    kw = dict(reduced=True, algorithm="gpdmm", k=1, eta=0.05, m=2,
              per_client_batch=2, seq_len=16, seed=0, log_every=2,
              faults="delay=0.5,straggler=0.2,seed=11",
              deadline=3.0, max_staleness=2)
    d_a, d_b = str(tmp_path / "a"), str(tmp_path / "b")
    train_run("olmo-1b", steps=4, ckpt_dir=d_a, **kw)
    train_run("olmo-1b", steps=2, ckpt_dir=d_b, **kw)
    train_run("olmo-1b", steps=4, ckpt_dir=d_b, resume=True, **kw)
    a = ckpt.load(d_a)["fed_state"]
    b = ckpt.load(d_b)["fed_state"]
    assert "stale_buf" in a
    _assert_trees_equal(a, b)


# ---------------------------------------------------------------------------
# hot-swap serving satellites
# ---------------------------------------------------------------------------

def test_checkpoint_steps_listing(tmp_path):
    assert ckpt.steps(tmp_path / "nope") == []
    for s in (3, 1, 7):
        ckpt.save(tmp_path, s, {"x": jnp.arange(2.0)})
    assert ckpt.steps(tmp_path) == [1, 3, 7]
    assert ckpt.latest_step(tmp_path) == 7


def test_load_with_retry_recovers_transient(tmp_path, monkeypatch):
    from repro.launch import serve

    ckpt.save(tmp_path, 5, {"x": jnp.arange(3.0)})
    calls = {"n": 0}
    real_load = ckpt.load

    def flaky(path, step=None):
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return real_load(path, step)

    monkeypatch.setattr(serve.ckpt, "load", flaky)
    out = serve.load_with_retry(str(tmp_path), 5, retries=3, backoff=0.001)
    assert calls["n"] == 3 and int(out["x"][2]) == 2
    # persistent failure propagates after the schedule is exhausted
    calls["n"] = -10**9
    with pytest.raises(OSError):
        serve.load_with_retry(str(tmp_path), 5, retries=2, backoff=0.001)


def test_hot_swap_watcher_rejects_truncation_keeps_last_good(tmp_path):
    from repro.launch.serve import HotSwapWatcher

    pay = {"server": {"w": jnp.arange(3.0)}, "round": 2}
    ckpt.save(tmp_path, 2, pay)
    w = HotSwapWatcher(str(tmp_path), retries=2, backoff=0.001)
    assert int(w.poll()["round"]) == 2 and w.step == 2
    assert w.poll() is None  # nothing newer

    # a truncated file at the NEWEST step: rejected loudly, last-good kept
    (tmp_path / "step_00000009.msgpack").write_bytes(b"\x00" * 17)
    assert w.poll() is None
    assert w.failures == 1 and 9 in w.bad and w.step == 2
    assert w.poll() is None  # bad step is remembered, not retried
    assert w.failures == 1

    # a good NEWER step behind the bad one still swaps in
    ckpt.save(tmp_path, 6, {"server": {"w": jnp.arange(3.0)}, "round": 6})
    got = w.poll()
    assert got is not None and w.step == 6 and int(got["round"]) == 6
    assert w.swaps == 2
