"""Checkpointing: pytrees -> msgpack files with dtype/shape-preserving codecs.

Layout: <dir>/step_<N>.msgpack, atomic writes via tmp+fsync+rename (the file
is durable BEFORE it becomes visible, so a crash mid-save never leaves a
half-written step under the canonical name), ``latest_step`` for resumption,
optional keep-last-N retention so watchdog rollback anchors don't accumulate
unboundedly.  ``load`` rejects truncated or corrupt files loudly, naming the
file, instead of returning a garbage tree.  Handles nested dict/list/tuple
pytrees of jax/numpy arrays and python scalars; bfloat16 round-trips via
ml_dtypes.
"""
from __future__ import annotations

import os
import pathlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)
import msgpack
import numpy as np

_ARR = "__arr__"
_TUP = "__tup__"


def _encode(obj):
    if isinstance(obj, (jax.Array, np.ndarray)):
        arr = np.asarray(obj)
        return {
            _ARR: True,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return obj


def _pack(tree):
    def rec(t):
        if isinstance(t, dict):
            return {k: rec(v) for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            return {_TUP: isinstance(t, tuple), "items": [rec(v) for v in t]}
        return _encode(t)

    return rec(tree)


def _unpack(obj):
    if isinstance(obj, dict):
        if obj.get(_ARR):
            arr = np.frombuffer(obj["data"], dtype=np.dtype(obj["dtype"])).reshape(obj["shape"])
            return jnp.asarray(arr)
        if _TUP in obj:
            items = [_unpack(v) for v in obj["items"]]
            return tuple(items) if obj[_TUP] else items
        return {k: _unpack(v) for k, v in obj.items()}
    return obj


def save(path: str | os.PathLike, step: int, tree: Any, *,
         keep: Optional[int] = None) -> str:
    """Write ``step`` atomically; with ``keep``, prune all but the newest
    ``keep`` checkpoints afterwards (zero-padded names sort numerically)."""
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    final = path / f"step_{step:08d}.msgpack"
    tmp = final.with_suffix(".tmp")
    tree = jax.tree.map(lambda x: jax.device_get(x) if isinstance(x, jax.Array) else x, tree)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_pack(tree), use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    if keep is not None and keep > 0:
        for old in sorted(path.glob("step_*.msgpack"))[:-keep]:
            old.unlink(missing_ok=True)
    return str(final)


def steps(path: str | os.PathLike) -> list[int]:
    """All on-disk checkpoint steps, ascending.  Consumers that must survive
    a bad newest file (the hot-swap serving watcher) walk this list from the
    tail instead of trusting ``latest_step`` alone."""
    path = pathlib.Path(path)
    if not path.exists():
        return []
    return sorted(int(p.stem.split("_")[1]) for p in path.glob("step_*.msgpack"))


def latest_step(path: str | os.PathLike) -> Optional[int]:
    all_steps = steps(path)
    return all_steps[-1] if all_steps else None


def load(path: str | os.PathLike, step: Optional[int] = None) -> Any:
    path = pathlib.Path(path)
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    fp = path / f"step_{step:08d}.msgpack"
    if not fp.exists():
        raise FileNotFoundError(f"no checkpoint file {fp}")
    with open(fp, "rb") as f:
        raw = f.read()
    try:
        return _unpack(msgpack.unpackb(raw, raw=False, strict_map_key=False))
    except Exception as e:
        raise ValueError(
            f"checkpoint {fp} is truncated or corrupt ({len(raw)} bytes): "
            f"{e}; delete it and resume from an earlier step"
        ) from e
