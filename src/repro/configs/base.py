"""Configuration dataclasses for architectures, input shapes and federated runs.

Every assigned architecture gets one ``ArchConfig`` (see ``src/repro/configs/<id>.py``)
with the exact published hyper-parameters, plus a ``reduced()`` variant used by the
CPU smoke tests (2 layers, d_model <= 512, <= 4 experts).

The federated-optimisation technique of the paper (GPDMM / AGPDMM, Zhang et al. 2021)
is configured via ``FederatedConfig`` and applies to *training* only; decode shapes
exercise the serving path, which is pure substrate.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Input shapes (assigned, public pool)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Federated (paper technique) configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultConfig:
    """Deterministic client-fault schedule (the robustness layer, ISSUE 6).

    Every fault is a pure function of ``(seed, round, client)`` --
    ``core.faults.plan`` folds the round counter into ``seed`` -- so a fault
    trace replays EXACTLY across reruns, resumes, and watchdog rollbacks.

    Two hard silence classes -- ``dropout`` (the client crashed) and
    ``straggler`` (missed the round barrier) -- map onto the u_hat silence
    contract: the server reuses its cached uplink for the round, exactly as
    for a participation-masked client.  ``delay`` is the SOFT class: with
    the bounded-staleness engine on (``core.faults.async_on``) a delayed
    client's uplink lands ``s in [1, delay_max]`` rounds late through the
    stale buffer (``core.staleness``); with the engine off -- the default,
    and always on non-star topologies -- ``delay`` degrades to silence,
    bit-identical to the pre-async behaviour.  ``corrupt`` clients DO
    transmit, but the wire mangles the packet (NaN row / Inf row / sign
    flip / ``blowup`` x magnitude; the class is drawn per client) -- the
    faults uplink screening (``FederatedConfig.screen``) exists to catch.
    """

    dropout: float = 0.0    # P(client never returns this round)
    straggler: float = 0.0  # P(client misses the round barrier)
    delay: float = 0.0      # P(uplink delayed s rounds; silence if async off)
    corrupt: float = 0.0    # P(transmitted uplink mangled on the wire)
    blowup: float = 1e6     # magnitude multiplier of the "blowup" corruption
    seed: int = 1234        # fault RNG seed, independent of the data/mask seeds
    delay_max: int = 4      # lateness s drawn uniformly from [1, delay_max]

    def __post_init__(self):
        for name in ("dropout", "straggler", "delay", "corrupt"):
            v = getattr(self, name)
            if not (0.0 <= v <= 1.0):
                raise ValueError(
                    f"fault rate {name} must be in [0, 1], got {v}")
        if self.delay_max < 1:
            raise ValueError(
                f"delay_max must be a positive lateness bound, got "
                f"{self.delay_max}")

    @property
    def any(self) -> bool:
        return (self.dropout > 0 or self.straggler > 0 or self.delay > 0
                or self.corrupt > 0)

    @classmethod
    def parse(cls, spec: str) -> "FaultConfig":
        """Build from a CLI spec string, e.g. ``"dropout=0.1,corrupt=0.05,seed=7"``."""
        kwargs = {}
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            key, _, val = item.partition("=")
            key = key.strip()
            if key not in cls.__dataclass_fields__:
                raise ValueError(
                    f"unknown fault field {key!r} (have "
                    f"{sorted(cls.__dataclass_fields__)})")
            kwargs[key] = int(val) if key in ("seed", "delay_max") else float(val)
        return cls(**kwargs)


@dataclass(frozen=True)
class FederatedConfig:
    """How the paper's centralised-network optimisers map onto the mesh.

    ``layout`` selects the memory layout of the per-client state:

    * ``"client_axis"`` -- one client per slice of the client mesh axis
      (``data`` on the single-pod mesh, ``("pod", "data")`` multi-pod).  The
      stacked client state has leading dim ``m`` sharded over that axis.  This
      is the faithful mapping of the server-client star graph: the server
      update is an all-reduce over the client axis.
    * ``"fsdp"`` -- small ``m`` with the per-client copies replicated along the
      client dim but fully-sharded (FSDP) over ``data`` x ``model`` in the
      parameter dims.  Required for the very large models (llama4-maverick,
      yi-34b) where ``m`` full dual copies would not fit HBM.
    """

    algorithm: str = "gpdmm"  # gpdmm | agpdmm | scaffold | fedavg | fedsplit
    inner_steps: int = 2  # K in the paper
    # Gradient stepsize (eta in Alg. 1/2).  Three forms:
    #   * float          -- one global stepsize, the paper's setting;
    #   * "auto"         -- derive PER-CLIENT stepsizes eta_i = safety / L_i
    #                       from a power-iteration / Hutchinson estimate of
    #                       each client's smoothness L_i (core.autotune).
    #                       MUST be resolved host-side before the round is
    #                       built: ``core.autotune.resolve`` replaces it with
    #                       the tuple form below; ``core.make`` rejects an
    #                       unresolved "auto" loudly.
    #   * tuple[float]   -- resolved per-client stepsizes, one per client row
    #                       (hashable, so the config stays jit-static; the
    #                       kernels take the derived values as a per-client
    #                       stepsize operand instead of a baked scalar).
    eta: float | str | Tuple[float, ...] = 1e-2
    rho: Optional[float] = None  # None -> 1/(K*eta), the paper's default
    #                              (mean eta under per-client auto-eta; see
    #                              core.api.resolved_rho)
    layout: str = "client_axis"
    num_clients: Optional[int] = None  # None -> client axis size
    # algorithm variants
    use_avg: bool = True  # GPDMM dual update: eq (23) x-bar (True, Alg. 1)
    #                       vs eq (24) last iterate (False, Remark 1)
    fedsplit_init: str = "z"  # Inexact FedSplit client init: "z" (faithful,
    #                           the improper init the paper diagnoses) | "xs"
    gamma: Optional[float] = None  # FedSplit prox weight; None -> 1/rho
    eta_g: float = 1.0  # SCAFFOLD server stepsize
    # beyond-paper (SSPerf H3): quantise the client uplink to int<bits> with
    # error feedback before the server mean.  None = exact (paper-faithful).
    # Extends the paper's 1-variable-per-direction claim from 16 to <bits>
    # bits/param on the wire; the SPMD dry-run keeps the bf16 collective (XLA
    # has no sub-byte all-reduce) -- the saving applies to the real
    # server-client deployment and is reported analytically.
    uplink_bits: Optional[int] = None
    # beyond-paper: partial client participation (async PDMM, cf. paper
    # SSIII-A's asynchronous updating).  Each round exactly ceil(frac*m)
    # clients run the K inner steps and transmit; the server reuses its cached
    # view u_hat_i of every silent client, recomputing lam_{s|i} = rho(u_i -
    # x_s) for ALL i from what it holds -- so the KKT invariant (25) survives
    # partial rounds exactly.  1.0 = every client every round (paper-faithful).
    participation: float = 1.0
    # Cohort-sampled round engine (ISSUE 5).  With ``participation < 1`` the
    # masked path still runs the K-step inner loop over ALL m client rows and
    # only discards silent clients at the tail, so compute is O(m) even when
    # 1% of clients fire.  The cohort engine instead GATHERS the round's
    # active rows out of the population arena, runs the fused inner loop and
    # round tail on the (m_active, width) cohort buffer, and SCATTERS the
    # updated rows back -- the server mean becomes
    # (sum_active uplink + sum_silent u_hat) / m, computed as one mean over
    # the scattered population buffer so it matches the masked path
    # row-for-row (tests/test_cohort.py).  "auto" (default) engages whenever
    # the round runs on the arena with participation < 1 and the cohort is
    # strictly smaller than the population; True forces it (when the arena
    # path is taken), False keeps the masked full-population path.  The
    # engine is arena-only: the pytree path always masks.
    cohort: bool | str = "auto"
    # Runs the cohort inner loop in fixed-size tiles via ``lax.map`` so peak
    # live inner-loop state (notably the (tile, W, W) affine H blocks and the
    # per-step gradient temporaries) is O(tile) instead of O(m_active) --
    # what makes ~10^5-10^6-row population arenas with small cohorts feasible
    # on one host.  Must divide the cohort size; None = one shot.
    cohort_tile: Optional[int] = None
    # Host-resident population store (core.popstore, ISSUE 8): keep every
    # resident (m, width) client-state buffer in HOST memory as numpy arrays,
    # stage only the sampled cohort's rows onto device each round (with the
    # next round's gather prefetched while the current round computes), and
    # scatter the updated rows back after the tail -- device memory becomes
    # O(cohort) while the population scales to 10^6 rows.  Server-side O(m)
    # reads are O(cohort) too: the running sum(u_hat) is maintained
    # incrementally (compensated f64) and the dense dual refresh is
    # represented lazily as lam_i = rho*(u_hat_i - x_s).  Requires the
    # cohort engine (arena path, participation < 1, star, no async);
    # "auto" engages when the cohort engine runs and the population is at
    # least ``popstore_min_clients``; True forces it whenever the cohort
    # engine runs; False keeps the device-resident arena.  A popstore round
    # equals the device-arena cohort round row-for-row at f32 on the same
    # participation draw (tests/test_popstore.py).
    popstore: bool | str = "auto"
    # Population size at which "auto" moves the resident state off device.
    # Below this the O(m) device buffers are cheap and the device-arena
    # cohort round avoids per-round host<->device staging.
    popstore_min_clients: int = 65_536
    # Seed for the participation RNG (folded with the round counter).  One
    # config field instead of a constant duplicated per algorithm, so two
    # algorithms under comparison draw IDENTICAL mask sequences by contract
    # when given the same seed.
    seed: int = 17
    # Run the round's elementwise hot path over the flat client-state arena
    # (core.arena): all leaves of a client packed into one contiguous
    # 128-lane-padded row, so the K inner steps and the round tail are a
    # handful of fused whole-buffer kernels instead of per-leaf tree.map
    # chains.  ALL five algorithms dispatch on this flag (GPDMM/AGPDMM/
    # FedSplit since ISSUE 1-2; SCAFFOLD/FedAvg since ISSUE 3, so the
    # paper's cross-algorithm benchmarks compare algorithms, not
    # implementations).  Numerically equivalent (same f32 math, checked in
    # tests/test_arena.py + tests/test_conformance.py); automatically falls
    # back to the pytree path for
    # layout="fsdp" (per-leaf parameter shardings must be preserved) and for
    # mixed-dtype trees (one buffer would promote all client state to the
    # widest leaf dtype).
    #
    # "auto" (the default) additionally falls back when the packed width is
    # below ``arena_min_width`` -- BENCH_round.json shows the pytree path
    # winning at the paper's tiny shapes, where per-round pack/dispatch
    # overhead swamps the fused-kernel savings.  True forces the arena,
    # False forces the pytree path; every round records the decision in its
    # metrics (``used_arena``).
    use_arena: bool | str = "auto"
    arena_min_width: int = 1024
    # Rounds executed inside ONE jitted call: the launcher wraps
    # ``fed.round`` in a ``lax.scan`` over a leading R dim of the batch
    # stream with the state donated in place (metrics come back stacked),
    # amortising the per-round dispatch overhead that dominates wall time at
    # small state sizes.  1 = one dispatch per round (previous behaviour).
    rounds_per_call: int = 1
    # Network topology of the consensus graph.  "star" (the paper's
    # centralised network, the default) keeps every algorithm on its
    # centralised fast path; any other value routes PDMM/GPDMM through the
    # decentralized graph subsystem (``core.pdmm_graph`` over
    # ``core.topology``: node-primal + edge-dual arenas, neighbor-reduce
    # kernels).  Accepted: "star" | "ring" | "complete" | "torus" |
    # "er"/"er:<p>" (Erdos-Renyi, made connected, drawn from ``seed``).
    # Algorithms without a decentralized analogue (scaffold / fedavg /
    # agpdmm / fedsplit) reject non-star topologies loudly in ``core.make``.
    topology: str = "star"
    # Firing schedule of the graph rounds: "color" fires the greedy color
    # classes sequentially within a round (on a star: clients then server --
    # exactly the centralised algorithm, the conformance contract in
    # tests/test_topology.py); "sync" fires every node at once from the
    # round-start duals (Jacobi PDMM).  Stochastic node firing rides
    # ``participation`` < 1 on the shared ``seed`` mask contract.
    graph_schedule: str = "color"
    # beyond-paper: SVRG-style variance reduction for the stochastic setting
    # the paper names as future work (SSVII), following [14]'s PDMM+SVRG for
    # P2P.  "svrg" corrects each per-step minibatch gradient with the
    # snapshot gradient at the round's server estimate.  None = plain
    # stochastic gradients (paper-faithful).
    variance_reduction: Optional[str] = None
    # Deterministic fault injection (core.faults).  None = fault-free rounds
    # (the default, bit-identical to pre-robustness behaviour).
    faults: Optional[FaultConfig] = None
    # Fused uplink screening (kernels/screen.py via ops.screen_uplink): ONE
    # pass over the (m, width) uplink buffer emits per-client finite flags
    # and squared deviations from the downlink reference; the server demotes
    # any non-finite or norm-outlier client to SILENT for the round (its
    # cached u_hat uplink is reused), so a screened round is bit-identical
    # to a participation-masked round.  "auto" screens exactly when a fault
    # schedule is configured; True always screens (also catches NaNs the
    # optimiser itself produces); False never screens -- a corrupted uplink
    # then poisons the server mean (the failure mode docs/robustness.md
    # demonstrates).
    screen: bool | str = "auto"
    # Norm-outlier rule: demote clients whose squared deviation from the
    # reference exceeds screen_mult x the round median.  <= 0 disables the
    # outlier rule (non-finite screening still applies).
    screen_mult: float = 100.0
    # Bounded-staleness async round engine (core.staleness, ISSUE 7): give
    # the ``delay`` fault class real semantics -- a delayed client's uplink
    # lands s rounds late through a stale-buffer arena and is admitted into
    # the server mean with a staleness-discounted weight gamma**s iff
    # s <= max_staleness, the stale-update regime asynchronous PDMM
    # converges under (Sherson et al., arXiv:1706.02654; Zhang & Heusdens,
    # arXiv:1702.00841).  "auto" (default) engages exactly when the knobs
    # deviate from the synchronous point (max_staleness > 0 or a finite
    # deadline) AND a delay schedule is active on a star topology; True
    # forces the engine (at the synchronous knobs it is bitwise-identical
    # to the masked round -- tests/test_staleness.py pins this), False
    # keeps delay = silence.
    async_rounds: bool | str = "auto"
    # Straggler deadline, in rounds: a delayed client whose drawn lateness
    # exceeds it is demoted to the silence contract AT PLAN TIME (its
    # uplink never enters the stale buffer).  inf = wait for any lateness.
    deadline: float = float("inf")
    # Admission bound on arriving stale uplinks: a row that is s rounds
    # late is admitted iff s <= max_staleness, else dropped (the u_hat
    # cache covers the client).  0 = admit nothing (synchronous point).
    max_staleness: int = 0
    # Staleness discount: an admitted row s rounds late is mixed toward the
    # server's cached view with weight stale_gamma**s.
    stale_gamma: float = 0.5
    # Residual-based early termination (core.autotune): the round emits the
    # fused residual norms ||x - x_prev||^2 / ||x||^2 (ops.residual_norm)
    # and the HOST driver stops once the relative fixed-point residual
    # ||x - x_prev|| / ||x|| stays below ``tol`` for ``patience``
    # consecutive rounds (pfb-clean's primal_dual stopping rule).  tol = 0
    # disables the check AND the metric -- the gate is a static Python
    # decision, so a tol=0 round compiles to the identical fixed-budget
    # graph (the same pattern as the async engine's w > 0 guard).
    tol: float = 0.0
    patience: int = 1

    def __post_init__(self):
        # stepsize / inner-loop hyper-parameters fail AT PARSE TIME with the
        # field name -- an eta <= 0 or K < 1 otherwise only surfaces as NaN
        # rounds (or a ZeroDivisionError in resolved_rho) deep inside the
        # jitted driver
        if self.inner_steps < 1:
            raise ValueError(
                f"inner_steps must be >= 1, got {self.inner_steps}")
        if isinstance(self.eta, str):
            if self.eta != "auto":
                raise ValueError(
                    f"eta must be a positive stepsize, a tuple of them, or "
                    f"'auto', got {self.eta!r}")
        elif isinstance(self.eta, tuple):
            if not self.eta or any(
                    not (isinstance(e, (int, float)) and e > 0.0)
                    for e in self.eta):
                raise ValueError(
                    f"eta tuple must hold one positive per-client stepsize "
                    f"per row, got {self.eta!r}")
        elif not (isinstance(self.eta, (int, float)) and self.eta > 0.0):
            raise ValueError(
                f"eta must be a positive stepsize, got {self.eta!r}")
        if self.rho is not None and not self.rho > 0.0:
            raise ValueError(
                f"rho must be a positive penalty (or None for the 1/(K*eta) "
                f"default), got {self.rho}")
        if not self.tol >= 0.0:
            raise ValueError(
                f"tol must be >= 0 (0 disables early termination), got "
                f"{self.tol}")
        if self.patience < 1:
            raise ValueError(
                f"patience must be >= 1 consecutive sub-tol rounds, got "
                f"{self.patience}")
        if not (0.0 < self.participation <= 1.0):
            raise ValueError(
                f"participation must be in (0, 1], got {self.participation}")
        if self.cohort not in (True, False, "auto"):
            raise ValueError(
                f"cohort must be True, False or 'auto', got {self.cohort!r}")
        if self.cohort_tile is not None and self.cohort_tile < 1:
            raise ValueError(
                f"cohort_tile must be a positive tile size or None, got "
                f"{self.cohort_tile}")
        if self.popstore not in (True, False, "auto"):
            raise ValueError(
                f"popstore must be True, False or 'auto', got "
                f"{self.popstore!r}")
        if self.popstore_min_clients < 1:
            raise ValueError(
                f"popstore_min_clients must be >= 1, got "
                f"{self.popstore_min_clients}")
        if self.screen not in (True, False, "auto"):
            raise ValueError(
                f"screen must be True, False or 'auto', got {self.screen!r}")
        if self.async_rounds not in (True, False, "auto"):
            raise ValueError(
                f"async_rounds must be True, False or 'auto', got "
                f"{self.async_rounds!r}")
        if not self.deadline > 0.0:
            raise ValueError(
                f"deadline must be a positive round count (inf = no "
                f"deadline), got {self.deadline}")
        if self.max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {self.max_staleness}")
        if not (0.0 < self.stale_gamma <= 1.0):
            raise ValueError(
                f"stale_gamma must be in (0, 1], got {self.stale_gamma}")
        # cohort_tile must divide the cohort size (core.api.map_cohort_tiles
        # would only raise at trace time, deep inside a jit).  Checkable here
        # whenever the population is known; a tile >= the cohort is fine --
        # the tiled map degenerates to one shot.
        if (self.cohort_tile is not None and self.num_clients is not None
                and self.participation < 1.0):
            # the engine's single source of truth for the cohort size --
            # duplicating the ceil here once overcounted by one on exact
            # products like 0.07*100 (local import: core imports configs)
            from repro.core.tree_util import cohort_count
            mc = cohort_count(self.num_clients, self.participation)
            if self.cohort_tile < mc and mc % self.cohort_tile:
                raise ValueError(
                    f"cohort_tile={self.cohort_tile} does not divide the "
                    f"cohort size {mc} (= ceil(participation="
                    f"{self.participation} * num_clients={self.num_clients}))")


# ---------------------------------------------------------------------------
# Architecture configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # None -> d_model // n_heads

    # Repeating block pattern.  Entries: "dense" (attn+mlp), "moe" (attn+moe),
    # "rwkv" (rwkv6 time-mix + channel-mix), "rec" (RG-LRU block + mlp),
    # "local" (local/sliding-window attn + mlp).
    block_pattern: Tuple[str, ...] = ("dense",)

    # --- attention ---
    attn_kind: str = "gqa"  # gqa | mla | none
    rope_theta: float = 10_000.0
    window: Optional[int] = None  # sliding-window size for "local" blocks /
    #                               sw-variant of dense archs (long_500k)

    # --- MLA (deepseek v2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None  # per-expert hidden; None -> d_ff
    first_dense_layers: int = 0  # leading dense layers (deepseek v2)
    moe_fused_dispatch: bool = False  # one dispatch for all top-k slots + a
    #   single bf16 expert-combine psum instead of k f32 ones (SSPerf H1)

    # --- norm / misc ---
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    tie_embeddings: bool = False
    act: str = "silu"  # silu (swiglu) | gelu (geglu)

    # --- recurrent ---
    rec_d_state: int = 0  # RG-LRU recurrent width (0 -> d_model)
    conv_width: int = 4  # temporal conv width in RG-LRU block
    wkv_head_dim: int = 64  # rwkv6 head size

    # --- modality frontends (STUBS: precomputed embeddings by input_specs) ---
    frontend: Optional[str] = None  # vision | audio | None
    n_prefix_tokens: int = 0  # image patches / audio frames per sample
    frontend_dim: int = 0  # ViT / codec feature dim
    n_codebooks: int = 1  # musicgen parallel codebooks

    # --- serving ---
    shard_cache_seq: bool = False  # SSPerf H2: shard the KV-cache seq dim over
    #   "model" when the head dim cannot (GQA kv < model axis, or MLA)
    subquadratic: bool = False  # eligible for long_500k as-is
    sw_variant_window: Optional[int] = None  # if set, long_500k runs with this
    #                                          sliding window (dense archs)

    # --- distribution ---
    fed: FederatedConfig = field(default_factory=FederatedConfig)
    remat: bool = True
    scan_layers: bool = True
    microbatch: Optional[int] = None  # split the per-client batch into this
    #   many grad-accumulation chunks inside each inner step (activation
    #   memory / microbatch, same FLOPs; see EXPERIMENTS.md SSPerf)
    dtype: str = "bfloat16"
    source: str = ""  # citation

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_units(self) -> int:
        return self.n_layers // self.pattern_len

    @property
    def tail_blocks(self) -> Tuple[str, ...]:
        """Blocks for layers beyond the last full pattern unit."""
        rem = self.n_layers % self.pattern_len
        return self.block_pattern[:rem]

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs are decoder-style

    def supports_shape(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k":
            return self.subquadratic or self.sw_variant_window is not None
        return True

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        pat = self.block_pattern
        # keep one full pattern unit (or 2 layers for singleton patterns)
        n_layers = max(2, len(pat))
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        # keep the GQA ratio flavour when possible
        if self.n_kv_heads < self.n_heads:
            n_kv = max(1, n_heads // max(1, self.n_heads // self.n_kv_heads))
        head_dim = d_model // n_heads
        return replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            moe_d_ff=None if self.moe_d_ff is None else min(self.moe_d_ff, 128),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            first_dense_layers=min(self.first_dense_layers, 1),
            kv_lora_rank=min(self.kv_lora_rank, 64),
            q_lora_rank=min(self.q_lora_rank, 64),
            rope_head_dim=min(self.rope_head_dim, 32) if self.kv_lora_rank else self.rope_head_dim,
            nope_head_dim=min(self.nope_head_dim, 32),
            v_head_dim=min(self.v_head_dim, 32),
            rec_d_state=min(self.rec_d_state, 256) if self.rec_d_state else 0,
            wkv_head_dim=min(self.wkv_head_dim, 32),
            window=min(self.window, 64) if self.window else None,
            sw_variant_window=min(self.sw_variant_window, 64) if self.sw_variant_window else None,
            n_prefix_tokens=min(self.n_prefix_tokens, 16) if self.n_prefix_tokens else 0,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            dtype="float32",
            remat=False,
            scan_layers=True,
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and memory napkin math)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        if self.n_codebooks > 1:
            total += (self.n_codebooks - 1) * 2 * v * d
        if self.frontend == "vision":
            total += self.frontend_dim * d + d * d  # 2-layer projector
        per_block: dict[str, int] = {}
        attn_p = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.attn_kind == "mla":
            qd = self.q_lora_rank or d
            attn_p = 0
            if self.q_lora_rank:
                attn_p += d * self.q_lora_rank
            attn_p += qd * self.n_heads * (self.nope_head_dim + self.rope_head_dim)
            attn_p += d * (self.kv_lora_rank + self.rope_head_dim)
            attn_p += self.kv_lora_rank * self.n_heads * (self.nope_head_dim + self.v_head_dim)
            attn_p += self.n_heads * self.v_head_dim * d
        mlp_p = 3 * d * self.d_ff
        per_block["dense"] = attn_p + mlp_p
        per_block["local"] = attn_p + mlp_p
        moe_ff = self.moe_d_ff or self.d_ff
        per_block["moe"] = (
            attn_p
            + self.n_experts * 3 * d * moe_ff
            + self.n_shared_experts * 3 * d * moe_ff
            + d * self.n_experts  # router
        )
        # rwkv6 block: r,k,v,g,w,o projections + channel mix
        per_block["rwkv"] = 6 * d * d + 3 * d * self.d_ff
        # rg-lru block: in/out proj x2 branches + conv + recurrent gates + mlp
        d_rnn = self.rec_d_state or d
        per_block["rec"] = 2 * d * d_rnn + d_rnn * d + self.conv_width * d_rnn + 2 * d_rnn * d_rnn // 8 + mlp_p
        for i in range(self.n_layers):
            blk = self.block_pattern[i % self.pattern_len]
            if blk in ("dense", "moe") and i < self.first_dense_layers:
                total += per_block["dense"]
            else:
                total += per_block[blk]
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        moe_ff = self.moe_d_ff or self.d_ff
        n_moe_layers = sum(
            1
            for i in range(self.n_layers)
            if self.block_pattern[i % self.pattern_len] == "moe" and i >= self.first_dense_layers
        )
        inactive = n_moe_layers * (self.n_experts - self.top_k) * 3 * self.d_model * moe_ff
        return full - inactive


def validate(cfg: ArchConfig) -> None:
    assert cfg.n_heads % cfg.n_kv_heads == 0, (cfg.name, "GQA ratio")
    if cfg.family == "moe":
        assert cfg.n_experts > 0 and cfg.top_k > 0, cfg.name
    if cfg.attn_kind == "mla":
        assert cfg.kv_lora_rank > 0, cfg.name
    for b in cfg.block_pattern:
        assert b in ("dense", "moe", "rwkv", "rec", "local"), b
