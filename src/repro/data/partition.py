"""Client partitioning strategies for federated data."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def by_class(x, y, n_classes: int):
    """The paper's split: client i carries exactly class i (maximum
    heterogeneity).  Returns stacked (m, n_i, ...) with equal n_i (truncated
    to the smallest class)."""
    xs, ys = [], []
    counts = [int((y == c).sum()) for c in range(n_classes)]
    n = min(counts)
    for c in range(n_classes):
        idx = jnp.nonzero(y == c, size=n)[0]
        xs.append(x[idx])
        ys.append(y[idx])
    return jnp.stack(xs), jnp.stack(ys)


def iid(key, x, y, m: int):
    n = (x.shape[0] // m) * m
    perm = jax.random.permutation(key, x.shape[0])[:n]
    return x[perm].reshape(m, n // m, *x.shape[1:]), y[perm].reshape(m, n // m)


def dirichlet(key, x, y, m: int, n_classes: int, alpha: float = 0.3):
    """Dirichlet(alpha) label-skew partition (standard FL benchmark recipe).
    Returns ragged lists (numpy) -- callers batch per client."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    y_np = np.asarray(y)
    client_idx = [[] for _ in range(m)]
    for c in range(n_classes):
        idx = np.nonzero(y_np == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * m)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].extend(part.tolist())
    return [np.asarray(ci, dtype=np.int64) for ci in client_idx]


def minibatch_schedule(n_per_client: int, batch_size: int, n_steps: int):
    """The paper's deterministic mini-batch order (no randomness): step k
    takes samples [k*B, (k+1)*B) mod n."""
    starts = (np.arange(n_steps) * batch_size) % max(1, n_per_client - batch_size + 1)
    return starts.astype(np.int64)
