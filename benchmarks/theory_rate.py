"""Theorem 1 instrumented: measured Q^{r+1}/Q^r ratio vs the analytic beta
bound along a real GPDMM trajectory (strongly convex least squares)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.base import FederatedConfig
from repro.core import make, quadratic, theory
from repro.core import tree_util as T
from repro.core.api import resolved_rho


def run():
    prob = quadratic.generate(jax.random.key(3), m=10, n=400, d=64)
    K, eta = 5, 0.5 / prob.L
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=eta)
    rho = resolved_rho(cfg)
    beta = theory.gpdmm_beta(prob.L, prob.mu, eta, rho)
    opt = make(cfg)
    s = opt.init(jnp.zeros((prob.d,)), prob.m)

    lam_star = prob.lam_star()
    qs = []
    # x_i^{0,K} = x0; the state's client half is arena-resident by default
    x_c_prev = T.tree_broadcast(jnp.zeros((prob.d,)), prob.m)
    t_round = None
    for r in range(40):
        s, metrics = opt.round(s, prob.grad, prob.batch(), return_trace=True)
        tr = metrics["trace"]
        q = theory.q_functional(
            cfg, x_c_prev=x_c_prev, x_bar=tr["x_bar"], lam_is=tr["lam_is"],
            x_star=prob.x_star, lam_star=lam_star, L=prob.L, mu=prob.mu,
        )
        qs.append(float(q))
        x_c_prev = tr["x_K"]
    qs = np.asarray(qs)
    ratios = qs[1:] / np.maximum(qs[:-1], 1e-30)
    emit("theory_rate_gpdmm", 0.0,
         f"beta_bound={beta:.6f} worst_measured_ratio={ratios.max():.6f} "
         f"median_ratio={np.median(ratios):.6f} bound_holds={bool((ratios <= beta + 1e-3).all())}")
    assert (ratios <= beta + 1e-3).all()
    agpdmm_empirical_rate(prob, K, eta, beta)


def agpdmm_empirical_rate(prob, K, eta, beta_gpdmm):
    """The paper leaves AGPDMM's K>1 convergence analysis open (SSVII).
    Empirical instrument: the per-round contraction of ||x_s - x*|| along an
    AGPDMM trajectory, reported against GPDMM's Theorem-1 beta.  Finding:
    AGPDMM's measured contraction is faster (smaller factor) than GPDMM's
    analytic bound -- evidence the open analysis should yield a rate at
    least as good as Theorem 1."""
    rates = {}
    for algo in ("gpdmm", "agpdmm"):
        opt = make(FederatedConfig(algorithm=algo, inner_steps=K, eta=eta))
        s = opt.init(jnp.zeros((prob.d,)), prob.m)
        dists = []
        for _ in range(30):
            s, _ = opt.round(s, prob.grad, prob.batch())
            dists.append(float(prob.dist(opt.server_params(s))))
        d = np.asarray(dists)
        # geometric-mean contraction over the pre-f32-floor segment
        seg = d[d > 1e-5]
        c = (seg[-1] / seg[0]) ** (1.0 / max(1, len(seg) - 1))
        rates[algo] = c
    emit("theory_agpdmm_empirical", 0.0,
         f"gpdmm_contraction={rates['gpdmm']:.4f} "
         f"agpdmm_contraction={rates['agpdmm']:.4f} beta_bound={beta_gpdmm:.4f} "
         f"agpdmm_beats_bound={bool(rates['agpdmm'] <= beta_gpdmm)}")
    assert rates["agpdmm"] <= rates["gpdmm"] + 1e-6  # AGPDMM at least as fast
    assert rates["agpdmm"] <= beta_gpdmm  # and inside the GPDMM guarantee


if __name__ == "__main__":
    run()
