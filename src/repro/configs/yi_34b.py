"""yi-34b -- llama-arch dense GQA [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Federated layout: ``fsdp`` with m=4 clients -- 16 full dual copies of 34B
params exceed v5e HBM; see DESIGN.md SS Arch-applicability.
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    block_pattern=("dense",),
    attn_kind="gqa",
    rope_theta=5_000_000.0,
    norm_kind="rmsnorm",
    shard_cache_seq=True,  # SSPerf H2: kv=8 can't divide the 16-way model axis (215->15.8 GiB/dev)
    subquadratic=False,  # long_500k skipped (full attention; see DESIGN.md)
    fed=FederatedConfig(algorithm="gpdmm", layout="fsdp", num_clients=4),
    microbatch=64,  # grad-accum chunks per inner step (activation memory)
    source="arXiv:2403.04652 (Yi)",
)
