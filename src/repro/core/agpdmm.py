"""AGPDMM (Algorithm 2, Zhang et al. 2021): accelerated GPDMM.

Differences from GPDMM (Alg. 1):
  * client init x_i^{r,0} = x_s^r (the fresher global estimate), so no
    per-client primal carry is stored;
  * the dual update uses the LAST iterate x_i^{r,K} (eq. 24), not the average.

The paper states AGPDMM transmits two variables server->client (x_s and
lam_{s|i}).  In the SPMD mapping the downlink lam_{s|i}^{r+1} =
rho (x_i^{r,K} - x_s^{r+1}) - lam_{i|s}^{r+1} is recomputed client-locally
from x_s^{r+1} and client-resident quantities, so the realised collective
traffic equals GPDMM's (one all-reduce per round).  This implementation
observation is recorded in EXPERIMENTS.md SSPerf.

When K == 1 and rho = 1/eta, the round reduces exactly to vanilla gradient
descent with stepsize eta (paper eq. (27)); ``tests/test_core.py`` asserts
this identity numerically.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig
from repro.core import arena, faults, staleness
from repro.core import tree_util as T
from repro.core.api import (
    FedOpt, cohort_batch, resolved_rho, run_cohort_inner, use_arena,
    use_cohort,
)
from repro.core.gpdmm import (
    _eta_val, arena_metrics, arena_tail, cohort_tail, inner_steps,
    inner_steps_arena, participation_key, popstore_tail,
)
from repro.kernels import ops


def popstore_body(cfg: FederatedConfig, spec, m: int, grad_fn, per_step):
    """Device half of a host-popstore AGPDMM round (see gpdmm.popstore_body):
    only the ``u_hat`` rows stage -- the client init is the fresh server row
    (no primal carry), and the dual rows reconstruct lazily from the staged
    uplink cache via lam_{s|i} = rho (u_hat_i - x_s)."""
    rho = resolved_rho(cfg)
    K = cfg.inner_steps
    f32 = jnp.float32

    eta_v = _eta_val(cfg.eta)
    per_client = np.ndim(eta_v) > 0

    def body(server, staged, idx, round_idx, batch):
        x_s_row = spec.pack(server["x_s"])
        u_hat_c = staged["u_hat"]
        lam_c = ops.dual_from_uplink(u_hat_c, x_s_row, rho)  # lazy dual
        batch_c = cohort_batch(batch, idx, m, per_step)

        def inner(rows, b):
            lam_t = rows[0]
            eta_t = rows[1] if per_client else eta_v  # tiled with the rows
            x0 = jnp.broadcast_to(x_s_row[None], lam_t.shape)
            return inner_steps_arena(
                spec, grad_fn, x0, x_s_row, lam_t, b, K=K, eta=eta_t,
                rho=rho, per_step=per_step,
                vr_snapshot=x0 if cfg.variance_reduction == "svrg" else None,
            )

        rows = (lam_c,) + ((jnp.asarray(eta_v)[idx],) if per_client else ())
        x_K, _ = run_cohort_inner(cfg, inner, rows, batch_c,
                                  per_step=per_step)
        _, uplink = ops.round_tail(x_K, lam_c, x_s_row, rho,
                                   with_lam_is=False)
        uplink, keep_c, fm = popstore_tail(cfg, spec, x_s_row, u_hat_c,
                                           uplink, idx, round_idx, m)
        metrics = {
            "client_drift": T.masked_client_mean(
                jnp.sum(jnp.square((x_K - x_s_row[None]).astype(f32)),
                        axis=1), keep_c),
            "used_arena": jnp.ones((), f32),
        } | fm
        return {"u_hat": uplink}, {}, metrics

    return body


def _round_arena_cohort(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches):
    """AGPDMM round over the sampled cohort (see gpdmm._round_arena_cohort):
    only lam_s rows gather/scatter -- the client init is the fresh server
    row, so there is no primal carry to move at all."""
    rho = resolved_rho(cfg)
    K = cfg.inner_steps
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    lam = state["lam_s"]
    m = lam.shape[0]
    x_s_row = spec.pack(state["x_s"])
    idx, _mask = T.cohort_indices(
        participation_key(cfg, state["round"]), m, cfg.participation
    )
    lam_c = ops.row_gather(lam, idx)
    batch_c = cohort_batch(batch, idx, m, per_step_batches)
    eta_v = _eta_val(cfg.eta)
    per_client = np.ndim(eta_v) > 0

    def inner(rows, b):
        lam_t = rows[0]
        eta_t = rows[1] if per_client else eta_v  # tiled with the state rows
        x0 = jnp.broadcast_to(x_s_row[None], lam_t.shape)
        return inner_steps_arena(
            spec, grad_fn, x0, x_s_row, lam_t, b, K=K, eta=eta_t, rho=rho,
            per_step=per_step_batches,
            vr_snapshot=x0 if cfg.variance_reduction == "svrg" else None,
        )

    rows = (lam_c,) + ((jnp.asarray(eta_v)[idx],) if per_client else ())
    x_K, _ = run_cohort_inner(cfg, inner, rows, batch_c,
                              per_step=per_step_batches)

    _, uplink = ops.round_tail(x_K, lam_c, x_s_row, rho, with_lam_is=False)
    fplan = faults.plan(cfg, state["round"], m)
    new_state, keep_c, fm = cohort_tail(cfg, spec, state, uplink, idx, fplan)
    new_state |= {"round": state["round"] + 1}
    return new_state, arena_metrics(new_state["lam_s"], x_K, x_s_row, keep_c) | fm


def _round_arena(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches):
    """AGPDMM round over the flat arena (see gpdmm._round_arena): lam_s and
    u_hat are arena-resident (m, width) buffers; the client init is the
    fresher server row, so no primal carry is stored at all."""
    rho = resolved_rho(cfg)
    K = cfg.inner_steps
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    lam = state["lam_s"]
    m = lam.shape[0]
    if use_cohort(cfg, m):
        return _round_arena_cohort(cfg, state, grad_fn, batch, per_step_batches)
    x_s_row = spec.pack(state["x_s"])
    x0 = jnp.broadcast_to(x_s_row[None], (m, spec.width))

    x_K, _ = inner_steps_arena(
        spec, grad_fn, x0, x_s_row, lam, batch, K=K, eta=cfg.eta, rho=rho,
        per_step=per_step_batches,
        vr_snapshot=x0 if cfg.variance_reduction == "svrg" else None,
    )

    _, uplink = ops.round_tail(x_K, lam, x_s_row, rho, with_lam_is=False)
    new_state, x_s_new, lam_s_new, mask, fm = arena_tail(cfg, spec, state, uplink, m)
    new_state |= {
        "x_s": spec.unpack(x_s_new),
        "lam_s": lam_s_new,
        "round": state["round"] + 1,
    }
    return new_state, arena_metrics(lam_s_new, x_K, x_s_row, mask) | fm


def _round(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches=False):
    if use_arena(cfg, state["x_s"]):
        return _round_arena(cfg, state, grad_fn, batch, per_step_batches)
    rho = resolved_rho(cfg)
    K = cfg.inner_steps
    x_s, lam_s = state["x_s"], state["lam_s"]
    m = jax.tree.leaves(lam_s)[0].shape[0]
    x_s_b = T.tree_broadcast(x_s, m)

    x_K, _ = inner_steps(
        grad_fn, x_s_b, x_s_b, lam_s, batch, K=K, eta=cfg.eta, rho=rho,
        per_step=per_step_batches,
        vr_snapshot=x_s_b if cfg.variance_reduction == "svrg" else None,
    )

    lam_is = T.tmap(lambda s, xk, l: rho * (s - xk) - l, x_s_b, x_K, lam_s)
    uplink = T.tmap(lambda xk, l: xk - l / rho, x_K, lam_is)
    new_state = {}
    if cfg.uplink_bits is not None:  # beyond-paper: EF21 delta-quantised uplink
        uplink = T.tree_quantize_delta(uplink, state["u_hat"], cfg.uplink_bits)
    # robustness layer: inject -> participation -> screen -> combined select
    fplan = faults.plan(cfg, state["round"], m)
    uplink = faults.inject_tree(cfg.faults, fplan, uplink)
    pmask = None
    if cfg.participation < 1.0:  # beyond-paper: async PDMM (partial rounds)
        pmask = T.participation_mask(
            participation_key(cfg, state["round"]), m, cfg.participation
        )
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep_tree(cfg, uplink, x_s)
    mask = faults.combine_mask(pmask, fplan, keep)
    sm = {}
    if faults.async_on(cfg):
        # bounded-staleness engine: delayed rows buffer, arrivals mix
        uplink, mask, stale_up, sm = staleness.step_tree(
            cfg, fplan, uplink, state["u_hat"], mask, state)
        new_state |= stale_up
    elif mask is not None:
        uplink = T.tree_select(mask, uplink, state["u_hat"])
    if "u_hat" in state:
        new_state["u_hat"] = uplink
    x_s_new = T.tree_client_mean(uplink)
    x_s_new_b = T.tree_broadcast(x_s_new, m)
    # rho (u_i - x_s): reconstructed from the transmitted uplink (see gpdmm)
    lam_s_new = T.tmap(lambda u, s: rho * (u - s), uplink, x_s_new_b)

    new_state |= {"x_s": x_s_new, "lam_s": lam_s_new, "round": state["round"] + 1}
    metrics = {
        "lam_sum_norm": T.tree_norm(T.tree_client_sum(lam_s_new)),
        # silent clients' x_K never enters the state: average the active set
        "client_drift": T.masked_client_mean(
            T.tree_client_sqnorms(T.tree_sub(x_K, x_s_b)), mask),
        "used_arena": jnp.zeros((), jnp.float32),
    }
    if fplan is not None or keep is not None:
        tx = faults.combine_mask(pmask, fplan, None)
        if faults.async_on(cfg):
            tx = staleness.fresh_mask(tx, fplan)
        metrics |= faults.fault_metrics(fplan, tx, keep) | sm
    return new_state, metrics


def make(cfg: FederatedConfig) -> FedOpt:
    def init(params, m):
        if use_arena(cfg, params):
            spec = arena.ArenaSpec.from_tree(params)
            st = {
                "x_s": params,
                "lam_s": arena.zeros(spec, m),
                "round": jnp.zeros((), jnp.int32),
            }
            if (cfg.uplink_bits is not None or cfg.participation < 1.0
                    or faults.needs_cache(cfg)):
                row = spec.pack(params)
                st["u_hat"] = jnp.broadcast_to(row[None], (m, spec.width))
            if faults.async_on(cfg):
                st |= staleness.init_arena(spec, m)
            return st
        st = {
            "x_s": params,
            "lam_s": T.tree_zeros_like(T.tree_broadcast(params, m)),
            "round": jnp.zeros((), jnp.int32),
        }
        if (cfg.uplink_bits is not None or cfg.participation < 1.0
                or faults.needs_cache(cfg)):
            st["u_hat"] = T.tree_broadcast(params, m)  # EF21/async server view
        if faults.async_on(cfg):
            st |= staleness.init_tree(params, m)
        return st

    return FedOpt(
        name="agpdmm",
        init=init,
        round=partial(_round, cfg),
        server_params=lambda s: s["x_s"],
    )
