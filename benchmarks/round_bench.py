"""Whole-round benchmark: per-leaf pytree path vs flat-arena + fused
round-tail path (ISSUE 1 tentpole acceptance), extended with the ISSUE 2
inner-loop rework: arena-native gradient oracles (0 boundary passes per
step), and the round-batched ``lax.scan`` driver (one dispatch per R rounds).

The federated round is memory-bound elementwise math over the stacked
``(m, params)`` client state, so the figure of merit is full-state HBM
passes (one pass = reading or writing every element of one (m, N) state
tensor once).  The analytic counts below follow the op chains in
``core/gpdmm.py`` literally: per-leaf tree.map chains each re-read their
operands; a fused kernel is counted as its actual reads+writes; the
arena-resident state never repacks per round (only the server-sized x_s
row, 1/m of the state, excluded as O(1/m)).

Three problem shapes:
  * ``small``   -- the paper's least-squares scale (one tiny leaf).
  * ``lm_flat`` -- LM-scale flat parameter buffer (one (2^20,) leaf, m x N
                   = 8M f32).  The arena layout is exactly this flat view.
  * ``lm_tree`` -- the same 1M params as a multi-leaf transformer-ish tree.
                   With a plain pytree grad each inner step pays an
                   unpack(x)/pack(g) round trip at the gradient-oracle
                   boundary (+4 passes/step, ``oracle=boundary``); an
                   arena-native oracle (``oracle=native``) evaluates on the
                   packed buffer and pays 0.

Record columns beyond ISSUE 1: ``oracle`` ("tree" = per-leaf pytree grad,
"boundary" = arena via the unpack/pack wrapper, "native" = arena-native
grad oracle) and ``driver`` ("per_round" = one dispatch per round,
"scan8" = 8 rounds per dispatch via core.make_scan_rounds; us_per_round is
the per-round share).  Gradient math itself is identical on all paths (a
trivial linear grad keeps the round tail visible).  Emits the
``BENCH_round.json`` trajectory consumed by ``benchmarks/regression_gate.py``
(the CI wall-time gate) plus the CSV lines the other benches use.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.base import FederatedConfig
from repro.core import make, make_oracle, make_scan_rounds

PROBLEMS = {
    "small": {"m": 8, "shapes": {"w": (24,)}},
    "lm_flat": {"m": 8, "shapes": {"w": (1 << 20,)}},
    "lm_tree": {
        "m": 8,
        "shapes": {
            "embed": (512, 384),
            "blk0_w1": (768, 512),
            "blk0_w2": (512, 768),
            "blk1_w1": (768, 512),
            "blk1_w2": (512, 768),
            "bias": (768,),
        },
    },
}

VARIANTS = {
    "plain": {},
    "ef21": {"uplink_bits": 8},
    "partial": {"participation": 0.5},
}

SCAN_R = 8  # rounds per dispatch for the scan-driver records


def _params(shapes):
    k = jax.random.key(0)
    return {
        name: jax.random.normal(jax.random.fold_in(k, i), shape)
        for i, (name, shape) in enumerate(sorted(shapes.items()))
    }


def _tree_grad(p, _b):
    # grad of 0.15||x||^2: memory-bound, so the round tail stays visible
    return jax.tree.map(lambda x: 0.3 * x, p)


# the same linear grad as an arena-native oracle: evaluated directly on the
# packed (m, width) buffer -- zero boundary passes per inner step
_native_grad = make_oracle(_tree_grad, grad_arena=lambda spec: (lambda xa, b: 0.3 * xa))

ORACLES = {"tree": _tree_grad, "boundary": _tree_grad, "native": _native_grad}


def round_passes(algo: str, variant: str, K: int, *, arena: bool,
                 multi_leaf: bool, oracle: str) -> int:
    """Full-(m, N) elementwise HBM passes per round (reads + writes), grad
    math excluded (identical on all paths).  One fused_update = 4r + 1w."""
    if not arena:
        n = 1  # x_s broadcast to (m, N), materialised once per round
        n += 5 * K  # per-leaf fused updates
        n += 4 + 3  # lam_is (3r+1w) + uplink (2r+1w)
        if variant == "ef21":
            n += 3 + 3 + 3  # tree_sub + _qdq (reduce 1r, apply 1r+1w) + tree_add
        if variant == "partial":
            n += 3  # tree_select uplink
            if algo == "gpdmm":
                n += 3  # x_c carry select
        n += 1 + 3  # client mean (1r) + lam_s_new (2r+1w)
        return n
    n = 5 * K  # arena-wide fused updates; server row broadcasts in-kernel
    if multi_leaf and oracle == "boundary":
        # pytree gradient-oracle boundary: unpack x (1r+1w) + pack g (1r+1w)
        # per inner step; an arena-native oracle (or a flat/single-leaf
        # tree, where the boundary is a pure reshape) pays ZERO
        n += 4 * K
    n += 4  # fused round_tail, uplink-only (lam_is skipped off-trace): 3r + 1w
    if variant == "ef21":
        n += 2 + 4  # rowmax reduce (2r) + fused qdq apply (3r+1w)
    if variant == "partial":
        n += 3
        if algo == "gpdmm":
            n += 3
    n += 1 + 3  # client mean + fused dual_from_uplink (2r+1w)
    return n


def _record(problem, algo, variant, path, oracle, driver, m, n, K, us, passes):
    state_bytes = m * n * 4
    eff_gbps = passes * state_bytes / (us * 1e-6) / 1e9
    emit(f"round_{problem}_{algo}_{variant}_{path}_{oracle}_{driver}", us,
         f"passes={passes},effective_GBps={eff_gbps:.2f}")
    return {
        "problem": problem, "algo": algo, "variant": variant, "path": path,
        "oracle": oracle, "driver": driver,
        "m": m, "n_params": n, "K": K,
        "us_per_round": round(us, 1),
        "hbm_passes": passes,
        "state_bytes": state_bytes,
        "effective_GBps": round(eff_gbps, 2),
    }


def bench_round(problem: str, algo: str, variant: str, K: int = 4):
    # fresh compilation caches per cell: accumulated executables and live
    # buffers from earlier cells otherwise skew the later timings by 2x+
    # (recompilation happens inside time_fn's warmup, not the timed iters)
    jax.clear_caches()
    spec = PROBLEMS[problem]
    m = spec["m"]
    params = _params(spec["shapes"])
    multi_leaf = len(spec["shapes"]) > 1
    n = sum(int(jnp.size(v)) for v in params.values())
    batch = {"dummy": jnp.zeros((m, 1))}
    records = []

    # (path, oracle) cells: the pytree path has no arena boundary; on the
    # arena the native oracle is the new hot path, and lm_tree keeps a
    # "boundary" record to show what the unpack/pack wrapper still costs
    cells = [(False, "tree"), (True, "native")]
    if multi_leaf:
        cells.append((True, "boundary"))
    for arena, oracle in cells:
        cfg = FederatedConfig(algorithm=algo, inner_steps=K, eta=0.1,
                              use_arena=arena, **VARIANTS[variant])
        opt = make(cfg)
        state = opt.init(params, m)
        grad = ORACLES[oracle]

        fn = jax.jit(lambda s: opt.round(s, grad, batch)[0])
        us = time_fn(fn, state)
        passes = round_passes(algo, variant, K, arena=arena,
                              multi_leaf=multi_leaf, oracle=oracle)
        path = "arena" if arena else "pytree"
        records.append(_record(problem, algo, variant, path, oracle,
                               "per_round", m, n, K, us, passes))

        if variant == "plain" and algo == "gpdmm":
            # round-batched scan driver: R rounds per dispatch, reported as
            # the per-round share -- isolates what dispatch overhead costs
            scan = make_scan_rounds(opt, grad)
            batches = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (SCAN_R,) + x.shape), batch)
            sfn = jax.jit(lambda s, b: scan(s, b)[0])
            us_scan = time_fn(sfn, state, batches) / SCAN_R
            records.append(_record(problem, algo, variant, path, oracle,
                                   f"scan{SCAN_R}", m, n, K, us_scan, passes))

    pyt = next(r for r in records if r["path"] == "pytree" and r["driver"] == "per_round")
    arn = next(r for r in records if r["path"] == "arena" and r["oracle"] == "native"
               and r["driver"] == "per_round")
    dp = (pyt["hbm_passes"] - arn["hbm_passes"]) / pyt["hbm_passes"]
    print(f"  -> {problem}/{algo}/{variant}: passes {pyt['hbm_passes']} -> "
          f"{arn['hbm_passes']} ({dp:+.0%}), time {pyt['us_per_round']:.0f} -> "
          f"{arn['us_per_round']:.0f} us")
    return records


def run(out_path: str = "BENCH_round.json"):
    trajectory = []
    for problem in PROBLEMS:
        for algo in ["gpdmm", "agpdmm"]:
            for variant in VARIANTS:
                trajectory.extend(bench_round(problem, algo, variant))
    payload = {
        "bench": "round_bench",
        "note": "hbm_passes are analytic full-(m,N) elementwise passes per "
                "round (grad math excluded, identical on all paths); "
                "effective_GBps = passes * state_bytes / wall_time.  oracle: "
                "tree = per-leaf pytree grad, boundary = arena via the "
                "unpack/pack wrapper (+4 passes/step on multi-leaf trees), "
                "native = arena-native grad oracle (0 boundary passes).  "
                "driver: per_round = one dispatch per round, scan8 = 8 "
                "rounds per lax.scan dispatch (us_per_round is the "
                "per-round share).",
        "trajectory": trajectory,
    }
    pathlib.Path(out_path).write_text(json.dumps(payload, indent=2))
    print(f"[round_bench] wrote {len(trajectory)} records to {out_path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_round.json")
    args = ap.parse_args()
    run(args.out)
