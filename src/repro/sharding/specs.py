"""Logical-axis -> mesh-axis rules and NamedSharding builders.

Logical axes produced by the model init specs:
  embed | embed2 | ff | heads | kv | vocab | experts | rnn | unit | None

Two federated layouts (see FederatedConfig.layout):

* ``client_axis`` -- the faithful star-graph mapping: stacked per-client state
  (leading dim m == product of client axes) is sharded over ("data",) /
  ("pod","data"); parameter dims use tensor parallelism over "model".
  The server aggregation is ONE all-reduce over the client axes.

* ``fsdp`` -- for models whose duals cannot fit at m == |client axes|
  (llama4-maverick, yi-34b): small m, client dim replicated, and the
  "embed" logical axis additionally sharded over the data axes
  (fully-sharded parameters; XLA inserts the FSDP all-gathers).

Serving has no clients: batch over the data axes, TP over "model".
A dim is only sharded when its size divides the mesh-axis product (e.g.
kv=8 heads stay replicated on a 16-way model axis -- standard GQA practice).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def client_axes(mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def logical_rules(mesh, *, layout: str) -> dict:
    """logical axis name -> mesh axes (or None)."""
    fsdp = client_axes(mesh) if layout == "fsdp" else None
    return {
        "embed": fsdp,
        "embed2": None,
        "ff": "model",
        "heads": "model",
        "kv": "model",
        "vocab": "model",
        "experts": "model",
        "rnn": "model",
        "unit": None,
        "clients": client_axes(mesh) if layout == "client_axis" else None,
        None: None,
    }


def spec_to_pspec(mesh, spec: tuple, shape: tuple, rules: dict) -> P:
    """Drops shardings that don't divide the dim size; a mesh axis is used at
    most once per tensor (first logical axis wins -- e.g. MoE (experts, embed,
    ff) keeps experts on "model" and leaves ff replicated)."""
    out = []
    used: set = set()
    for ax_name, dim in zip(spec, shape):
        mesh_axes = rules.get(ax_name)
        cand = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes or ())
        if (
            not cand
            or (set(cand) & used)
            or dim % axis_size(mesh, mesh_axes) != 0
        ):
            out.append(None)
        else:
            out.append(mesh_axes)
            used.update(cand)
    return P(*out)


def param_shardings(mesh, specs, shapes, *, layout: str):
    """specs: logical-axis pytree from model.specs(); shapes: matching
    ShapeDtypeStruct pytree.  Returns a NamedSharding pytree."""
    rules = logical_rules(mesh, layout=layout)

    def one(spec, sds):
        return NamedSharding(mesh, spec_to_pspec(mesh, spec, sds.shape, rules))

    return jax.tree.map(one, specs, shapes, is_leaf=lambda t: isinstance(t, tuple))


def stacked_shardings(mesh, server_shardings, *, layout: str):
    """Sharding for per-client stacked state (leading dim m): prepend the
    client axes (client_axis layout) or None (fsdp layout)."""
    cax = client_axes(mesh) if layout == "client_axis" else None

    def one(ns: NamedSharding):
        return NamedSharding(mesh, P(cax, *ns.spec))

    return jax.tree.map(one, server_shardings)


def batch_shardings(mesh, batch_shapes, *, stacked: bool, layout: str = "client_axis"):
    """Token/target/patch arrays: leading client dim (if stacked) over the
    client axes, then the per-client batch dim over the data axes in fsdp
    layout (client dim is not a mesh axis there)."""
    cax = client_axes(mesh)

    def one(sds):
        dims: list = [None] * len(sds.shape)
        if stacked:
            if layout == "client_axis":
                if sds.shape[0] % axis_size(mesh, cax) == 0:
                    dims[0] = cax
            else:  # fsdp: shard the per-client batch dim instead
                if len(sds.shape) > 1 and sds.shape[1] % axis_size(mesh, cax) == 0:
                    dims[1] = cax
        else:
            if sds.shape[0] % axis_size(mesh, cax) == 0:
                dims[0] = cax
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(one, batch_shapes)


def cache_rules(mesh, seq_axis: Optional[str] = None) -> dict:
    """Logical axes emitted by model.cache_specs().

    ``seq_axis``: opt-in sequence sharding of the KV cache (SSPerf H2) --
    used when the kv-head dim cannot divide the model axis (GQA kv=8 on a
    16-way axis) or the cache has no head dim at all (MLA compressed KV);
    the decode softmax then runs over a sharded key dim (GSPMD inserts the
    small score gather, ~MiBs, to save GiBs of cache per device).
    """
    return {
        "batch": client_axes(mesh),
        "kv": "model",
        "heads": "model",
        "rnn": "model",
        "unit": None,
        "seq": seq_axis,
        None: None,
    }


def cache_shardings(mesh, cache_shapes, cache_specs, seq_axis: Optional[str] = None):
    """Spec-driven cache sharding (specs from ``model.cache_specs()``)."""
    rules = cache_rules(mesh, seq_axis=seq_axis)

    def one(sds, spec):
        return NamedSharding(mesh, spec_to_pspec(mesh, spec, sds.shape, rules))

    return jax.tree.map(
        lambda sds, spec: one(sds, spec),
        cache_shapes,
        cache_specs,
        is_leaf=lambda t: isinstance(t, jax.ShapeDtypeStruct),
    )


def logits_shardings(mesh, sds):
    """Last-token logits (B, ..., V): batch over the data axes, vocab over
    "model" (both gated on divisibility)."""
    cax = client_axes(mesh)
    dims: list = [None] * len(sds.shape)
    if sds.shape[0] % axis_size(mesh, cax) == 0:
        dims[0] = cax
    if sds.shape[-1] % axis_size(mesh, "model") == 0:
        dims[-1] = "model"
    return NamedSharding(mesh, P(*dims))


def replicated(mesh):
    return NamedSharding(mesh, P())
