"""ISSUE 9: the telemetry subsystem -- span tracing, the metrics registry,
and the sinks.

Contracts pinned here:

  * span nesting and ordering survive into valid Chrome trace-event JSON
    (plain ``json.load``-able once closed; Perfetto wants exactly this);
  * a DISABLED tracer is a true no-op: per-span allocations do not scale
    with call count (the ``_NULL_SPAN`` singleton / fixed-arity
    ``__exit__`` design);
  * the registry's counter totals from a short FAULTED train run equal the
    hand-computed sum over the per-round rows the same run streamed to the
    JSONL sink -- i.e. the registry matches the launcher's own
    ``--expect-demotions`` accounting rather than double- or
    under-counting;
  * the JSONL sink tolerates a crash-torn final line but refuses mid-file
    corruption; ``load_trace`` recovers every event flushed before a
    crash that never wrote the closing ``]``;
  * ``write_prometheus`` emits the textfile-collector format (sanitised
    names, ``_total`` counters, histogram moments) atomically.
"""
import json
import sys
import threading

import pytest

from repro import telemetry as tel
from repro.launch.train import run as train_run
from repro.telemetry.spans import _NULL_SPAN, Tracer, load_trace


# -- spans -------------------------------------------------------------------


def test_span_nesting_and_chrome_trace_json(tmp_path):
    path = tmp_path / "trace.json"
    tr = Tracer().configure(enabled=True, trace_out=path)
    with tr.span("outer", {"round": 1}):
        with tr.span("inner"):
            pass
        tr.instant("mark", {"k": 3})
    tr.counter("ring", {"hit": 2, "miss": 1})
    tr.flush()
    assert tr.close() == str(path)

    # a CLOSED trace is a plain JSON array -- exactly what Perfetto loads
    events = json.loads(path.read_text())
    by_name = {e["name"]: e for e in events}
    assert set(by_name) == {"outer", "inner", "mark", "ring"}

    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["ph"] == inner["ph"] == "X"
    assert outer["args"] == {"round": 1}
    # spans record on exit, so the INNER event precedes the outer in the
    # stream; nesting is recovered from the timestamps (ts microseconds)
    assert events.index(inner) < events.index(outer)
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert all(e["pid"] == outer["pid"] for e in events)

    mark = by_name["mark"]
    assert (mark["ph"], mark["s"]) == ("i", "t")
    assert mark["args"] == {"k": 3}
    ring = by_name["ring"]
    assert ring["ph"] == "C" and ring["args"] == {"hit": 2, "miss": 1}


def test_scalar_counter_and_traced_decorator():
    tr = Tracer().configure(enabled=True)
    tr.counter("hits", 7)

    @tr.traced("work/fn")
    def fn(x):
        return x + 1

    assert fn(1) == 2
    events = tr.drain()
    assert {"ph": "C", "args": {"value": 7}}.items() <= events[0].items()
    assert events[1]["name"] == "work/fn" and events[1]["ph"] == "X"
    tr.configure(enabled=False)
    assert fn(2) == 3  # decorator bypasses the span when disabled
    assert tr.drain() == []


def test_disabled_tracer_is_allocation_free():
    tr = Tracer()  # enabled=False
    assert tr.span("x") is _NULL_SPAN
    assert tr.span("y", {"a": 1}) is _NULL_SPAN

    def burn(n):
        for _ in range(n):
            with tr.span("hot/phase", None):
                pass
            tr.instant("i")
            tr.counter("c", 1)

    def blocks(n):
        burn(64)  # warm up any lazy interpreter state
        before = sys.getallocatedblocks()
        burn(n)
        return sys.getallocatedblocks() - before

    # ambient interpreter noise is a few blocks and CONSTANT; a single
    # allocation per disabled call would show up as >= n
    small, large = blocks(100), blocks(20_000)
    assert large - small < 64, (small, large)


def test_tracer_threads_get_own_tid():
    tr = Tracer().configure(enabled=True)

    def work():
        with tr.span("t/span"):
            pass

    th = threading.Thread(target=work)
    th.start()
    th.join()
    with tr.span("main/span"):
        pass
    tids = {e["tid"] for e in tr.drain()}
    assert len(tids) == 2


def test_load_trace_recovers_crash_truncated_file(tmp_path):
    path = tmp_path / "trace.json"
    tr = Tracer().configure(enabled=True, trace_out=path)
    for i in range(3):
        with tr.span(f"s{i}"):
            pass
    tr.flush()  # no close(): simulates a killed run (no closing "]")
    text = path.read_text()
    assert not text.rstrip().endswith("]")
    with pytest.raises(json.JSONDecodeError):
        json.loads(text)
    events = load_trace(path)
    assert [e["name"] for e in events] == ["s0", "s1", "s2"]

    # torn final line on top of the missing terminator
    path.write_text(text[: len(text) - 7])
    assert [e["name"] for e in load_trace(path)] == ["s0", "s1"]
    tr.close()


# -- registry ----------------------------------------------------------------


def test_registry_kinds_and_absorb():
    reg = tel.Registry()
    reg.counter("n").inc(2)
    reg.counter("n").inc(3)
    assert reg.counter("n").value == 5
    with pytest.raises(ValueError):
        reg.counter("n").inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("n")  # kind collision is loud

    reg.absorb({"server_loss": 2.0, "faults_injected": 3, "note": "text"})
    reg.absorb({"server_loss": 4.0, "faults_injected": 1})
    snap = reg.snapshot()
    assert snap["faults_injected"] == 4.0  # COUNTER_KEYS sum
    assert snap["server_loss"] == 4.0  # gauge keeps the last value
    h = snap["server_loss_hist"]
    assert (h["count"], h["sum"], h["min"], h["max"]) == (2, 6.0, 2.0, 4.0)
    assert "note" not in snap

    # counters=() defers counter-semantic keys to a caller with a more
    # complete stream: they must be SKIPPED, not re-registered as gauges
    reg.absorb({"faults_injected": 99.0, "server_loss": 1.0}, counters=())
    assert reg.snapshot()["faults_injected"] == 4.0


def test_registry_totals_match_faulted_train_accounting(tmp_path):
    """End-to-end: a short faulted train run streams per-round rows to the
    JSONL sink; the summary row's fault counters must equal the hand-summed
    per-round counts (log_every=1 and R=1 make the logged rows a complete
    cover of the dispatches, so the sum IS the launcher's accounting)."""
    metrics_path = tmp_path / "metrics.jsonl"
    train_run("olmo-1b", reduced=True, steps=4, m=8, per_client_batch=2,
              seq_len=32, k=1, eta=0.05, participation=0.5,
              popstore_mode=True, faults="corrupt=0.3,seed=7",
              log_every=1, metrics_out=str(metrics_path))
    rows = tel.read_jsonl(metrics_path)
    rounds = [r for r in rows if r["kind"] == "round"]
    (summary,) = [r for r in rows if r["kind"] == "summary"]
    assert len(rounds) == 4
    assert summary["faults_injected"] == sum(
        r["faults_injected"] for r in rounds) > 0
    assert summary["faults_demoted"] == sum(
        r["faults_demoted"] for r in rounds)
    # histogram of the logged loss covers every logged row
    assert summary["server_loss_hist_count"] == 4
    assert summary["server_loss"] == rounds[-1]["server_loss"]
    # the global tracer must be left OFF for the rest of the session
    assert not tel.enabled()


def test_train_telemetry_off_leaves_global_tracer_alone(tmp_path):
    train_run("olmo-1b", reduced=True, steps=2, m=4, per_client_batch=2,
              seq_len=32, k=1, eta=0.05, log_every=1)
    assert not tel.enabled()


# -- sinks -------------------------------------------------------------------


def test_jsonl_sink_torn_tail_tolerated_midfile_corruption_raises(tmp_path):
    path = tmp_path / "m.jsonl"
    with tel.JsonlSink(path) as sink:
        sink.write({"a": 1})
        sink.write({"a": 2})
    with open(path, "a") as f:
        f.write('{"a": 3, "tor')  # crash mid-row
    assert [r["a"] for r in tel.read_jsonl(path)] == [1, 2]

    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"a": 1}\n{torn}\n{"a": 3}\n')
    with pytest.raises(json.JSONDecodeError):
        tel.read_jsonl(bad)  # mid-file corruption is NOT truncation


def test_prometheus_textfile_format(tmp_path):
    reg = tel.Registry()
    reg.counter("serve/tokens").inc(128)
    reg.gauge("eta_scale").set(0.5)
    h = reg.histogram("swap_latency_s")
    h.observe(0.1)
    h.observe(0.3)
    out = tmp_path / "metrics.prom"
    assert tel.write_prometheus(reg, out) == str(out)
    text = out.read_text()
    lines = text.splitlines()
    assert "# TYPE repro_serve_tokens_total counter" in lines
    assert "repro_serve_tokens_total 128.0" in lines  # name sanitised: / -> _
    assert "repro_eta_scale 0.5" in lines
    assert "repro_swap_latency_s_count 2.0" in lines
    assert any(ln.startswith("repro_swap_latency_s_mean 0.2") for ln in lines)
    assert text.endswith("\n")
    # every sample line parses as "name value" with a legal metric name
    for ln in lines:
        if ln.startswith("#"):
            continue
        name, val = ln.split(" ")
        assert tel.metrics._NAME_OK.match(name), name
        float(val)
    assert not out.with_suffix(out.suffix + ".tmp").exists()  # atomic write
