"""The paper's contribution: (A)GPDMM -- inexact PDMM for centralised
networks -- plus the exact PDMM/FedSplit pair and the SCAFFOLD/FedAvg
baselines, all as model-agnostic pytree transformations.

    from repro.core import make, FedOpt
    fed = make(FederatedConfig(algorithm="agpdmm", inner_steps=5, eta=1e-4))
    state = fed.init(params, m)
    state, metrics = fed.round(state, grad_fn, batch)
"""
from repro.core.api import FedOpt, make, make_oracle, make_scan_rounds, resolved_rho
from repro.core import (
    agpdmm, faults, fedavg, fedsplit, gpdmm, pdmm, pdmm_graph, quadratic,
    scaffold, softmax, theory, topology, tree_util,
)

__all__ = [
    "FedOpt",
    "make",
    "make_oracle",
    "make_scan_rounds",
    "resolved_rho",
    "agpdmm",
    "faults",
    "fedavg",
    "fedsplit",
    "gpdmm",
    "pdmm",
    "pdmm_graph",
    "quadratic",
    "scaffold",
    "softmax",
    "theory",
    "topology",
    "tree_util",
]
