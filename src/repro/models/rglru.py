"""Griffin/RecurrentGemma recurrent block: input/gate branches, short causal
depthwise conv, and the RG-LRU (real-gated linear recurrent unit):

    i_t = sigmoid(blockdiag(W_x) x_t)            (input gate)
    r_t = sigmoid(blockdiag(W_a) x_t)            (recurrence gate)
    log a_t = -c * softplus(Lambda) * r_t         (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)

Train/prefill uses an associative scan; decode is a single step with carried
state {"h": (B, d_rnn), "conv": (B, conv_width-1, d_rnn)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import layers as L

RG_C = 8.0


def rglru_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    dr = cfg.rec_d_state or d
    h = cfg.n_heads
    bd = dr // h  # block-diagonal gate width
    ks = jax.random.split(key, 7)
    p, s = {}, {}
    p["w_in"], s["w_in"] = L.dense_init(ks[0], (d, dr), ("embed", "rnn"), dtype)
    p["w_gate"], s["w_gate"] = L.dense_init(ks[1], (d, dr), ("embed", "rnn"), dtype)
    p["w_out"], s["w_out"] = L.dense_init(ks[2], (dr, d), ("rnn", "embed"), dtype)
    p["conv_k"], s["conv_k"] = L.dense_init(ks[3], (cfg.conv_width, dr), (None, "rnn"), dtype, scale=0.5)
    p["gx"], s["gx"] = L.dense_init(ks[4], (h, bd, bd), ("heads", None, None), dtype)
    p["ga"], s["ga"] = L.dense_init(ks[5], (h, bd, bd), ("heads", None, None), dtype)
    # Lambda parameterised so a ~ U(0.9, 0.999) at init
    lam = jax.random.uniform(ks[6], (dr,), minval=2.5, maxval=5.0)
    p["lam"], s["lam"] = lam.astype(jnp.float32), ("rnn",)
    return p, s


def _causal_conv(x, kernel, state):
    """Depthwise causal conv.  x: (B,S,Dr), kernel: (W,Dr), state: (B,W-1,Dr)."""
    W = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+W-1, Dr)
    out = sum(xp[:, i : i + x.shape[1]] * kernel[i] for i in range(W))
    return out, xp[:, -(W - 1) :]


def rglru_apply(cfg: ArchConfig, params, x, *, mode: str, state=None):
    """x: (B,S,D) normalized block input -> (out, new_state)."""
    B, S, D = x.shape
    dr = cfg.rec_d_state or D
    h = cfg.n_heads
    bd = dr // h
    xin = x @ params["w_in"]  # (B,S,Dr)
    gate = jax.nn.gelu(x @ params["w_gate"])

    conv_state = state["conv"] if state is not None else None
    xc, conv_new = _causal_conv(xin, params["conv_k"], conv_state)

    xh = xc.reshape(B, S, h, bd)
    i_t = jax.nn.sigmoid(jnp.einsum("bshd,hde->bshe", xh, params["gx"])).reshape(B, S, dr)
    r_t = jax.nn.sigmoid(jnp.einsum("bshd,hde->bshe", xh, params["ga"])).reshape(B, S, dr)
    log_a = (-RG_C * jax.nn.softplus(params["lam"]) * r_t.astype(jnp.float32))
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    b_scale = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = b_scale * (i_t.astype(jnp.float32) * xc.astype(jnp.float32))

    h0 = state["h"] if state is not None else jnp.zeros((B, dr), jnp.float32)
    if mode == "decode":
        hs = a[:, 0] * h0 + b[:, 0]
        y = hs[:, None].astype(x.dtype)
        h_last = hs
    else:
        y, h_last = ops.lru_scan(a, b, h0)
        y = y.astype(x.dtype)

    out = (y * gate) @ params["w_out"]
    new_state = None
    if mode != "train":
        new_state = {"h": h_last, "conv": conv_new}
    return out, new_state


def rglru_state_shape(cfg: ArchConfig, batch: int, dtype):
    dr = cfg.rec_d_state or cfg.d_model
    return {
        "h": jax.ShapeDtypeStruct((batch, dr), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, dr), dtype),
    }


def rglru_state_spec():
    return {"h": ("batch", "rnn"), "conv": ("batch", None, "rnn")}
