"""Batched serving demo: prefill a prompt batch, decode tokens with each
cache type (full KV for a dense arch, O(1) recurrent state for RWKV-6).

    PYTHONPATH=src python examples/serve_demo.py
"""
from repro.launch.serve import run

for arch in ["olmo-1b", "rwkv6-1.6b", "recurrentgemma-9b"]:
    print(f"\n=== {arch} (reduced config) ===")
    run(arch, reduced=True, batch=4, prompt_len=32, new_tokens=8)
