"""The paper's Fig. 1 story in one script: why Inexact FedSplit fails, and
how GPDMM/AGPDMM fix it.

    PYTHONPATH=src python examples/fedsplit_vs_pdmm.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import fedsplit, make, pdmm, quadratic

prob = quadratic.generate(jax.random.key(0), m=25, n=1000, d=200)
x0 = jnp.zeros((prob.d,))

# --- 1. Exact PDMM == exact FedSplit (SSIII-B) ------------------------------
cfg = FederatedConfig(rho=prob.L / 10)
p, f = pdmm.make_exact(cfg), fedsplit.make_exact(cfg)
sp, sf = p.init(x0, prob.m), f.init(x0, prob.m)
prox = prob.make_client_prox()
for _ in range(10):
    sp, _ = p.round(sp, prox)
    sf, _ = f.round(sf, prox)
print(f"exact PDMM vs FedSplit trajectory diff: "
      f"{float(jnp.max(jnp.abs(sp['x_s'] - sf['x_s']))):.2e}  (identical)")

# --- 2. Inexact FedSplit: improper init stalls ------------------------------
eta = 1.0 / prob.L
for init, label in [("z", "z_{s|i} init (paper: improper)"),
                    ("xs", "x_s init (fixed)")]:
    opt = make(FederatedConfig(algorithm="fedsplit", inner_steps=3, eta=eta,
                               fedsplit_init=init, rho=prob.L / 10))
    s = opt.init(x0, prob.m)
    rf = jax.jit(lambda s: opt.round(s, prob.grad, prob.batch())[0])
    for _ in range(300):
        s = rf(s)
    print(f"Inexact FedSplit, {label:32s} gap = {float(prob.gap(s['x_s'])):.3e}")

# --- 3. GPDMM / AGPDMM converge -----------------------------------------
for algo in ["gpdmm", "agpdmm"]:
    opt = make(FederatedConfig(algorithm=algo, inner_steps=3, eta=0.5 / prob.L))
    s = opt.init(x0, prob.m)
    rf = jax.jit(lambda s: opt.round(s, prob.grad, prob.batch())[0])
    for _ in range(300):
        s = rf(s)
    print(f"{algo.upper():8s} (paper's fix)                   gap = "
          f"{float(prob.gap(opt.server_params(s))):.3e}")
