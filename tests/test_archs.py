"""Per-architecture smoke tests (assignment requirement): a REDUCED variant of
each family runs one forward + one federated train round on CPU, asserting
output shapes and finiteness; plus the decode-vs-full-forward consistency
check that exercises every cache type (KV, ring, MLA-compressed, wkv state,
RG-LRU state)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import FederatedConfig
from repro.core import make as make_fed
from repro.models import build
from repro.models.model import forward

ARCH_NAMES = list(ARCHS)


def _batch(cfg, key, B=2, S=24, with_targets=True):
    if cfg.n_codebooks > 1:
        toks = jax.random.randint(key, (B, cfg.n_codebooks, S), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks}
    if with_targets:
        b["targets"] = toks
    if cfg.frontend == "vision":
        b["patches"] = jax.random.normal(
            jax.random.fold_in(key, 9), (B, cfg.n_prefix_tokens, cfg.frontend_dim)
        )
    return b


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_shapes(name, key):
    cfg = ARCHS[name].reduced()
    assert cfg.n_layers <= 4 and cfg.d_model <= 512 and cfg.n_experts <= 4
    m = build(cfg)
    params = m.init(key)
    b = _batch(cfg, key, with_targets=False)
    logits = m.apply(params, b)
    B, S = 2, 24
    S_total = S + (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_round(name, key):
    """One GPDMM federated round on the reduced config: loss finite, state
    structurally stable, dual-sum invariant holds."""
    cfg = ARCHS[name].reduced()
    fed_cfg = FederatedConfig(algorithm="gpdmm", inner_steps=2, eta=0.05)
    model = build(cfg)
    params = model.init(key)
    m = 2
    fed = make_fed(fed_cfg)
    state = fed.init(params, m)
    batch = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        _batch(cfg, jax.random.fold_in(key, 1)),
        _batch(cfg, jax.random.fold_in(key, 2)),
    )

    def grad_fn(p, b):
        return jax.grad(lambda q: model.loss(q, b)[0])(p)

    new_state, metrics = jax.jit(lambda s, b: fed.round(s, grad_fn, b))(state, batch)
    assert float(metrics["lam_sum_norm"]) < 1e-2, name
    for leaf in jax.tree.leaves(new_state):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), name
    # loss at the new server params is finite
    loss, _ = model.loss(fed.server_params(new_state), jax.tree.map(lambda x: x[0], batch))
    assert bool(jnp.isfinite(loss)), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_full_forward(name, key):
    cfg = ARCHS[name].reduced()
    wo = 16 if cfg.sw_variant_window else None
    m = build(cfg, window_override=wo)
    params = m.init(key)
    B, S = 2, 24
    full_b = _batch(cfg, key, B, S, with_targets=False)
    if cfg.n_codebooks > 1:
        pre_tokens = full_b["tokens"][:, :, : S - 1]
        last = full_b["tokens"][:, :, S - 1 :]
    else:
        pre_tokens = full_b["tokens"][:, : S - 1]
        last = full_b["tokens"][:, S - 1 :]
    pre_b = dict(full_b, tokens=pre_tokens)
    pre_b.pop("targets", None)

    P = cfg.n_prefix_tokens if cfg.frontend == "vision" else 0
    # exact-inference reference: prefill of the FULL sequence with drop-free
    # MoE routing (same math as a teacher-forced forward), so the check
    # isolates cache correctness.  The whole pipeline (ref, cache-building
    # prefill, decode) uses exact routing -- capacity drops in any stage would
    # legitimately change hidden states and poison the comparison.
    ref, _ = m.prefill(params, full_b, P + S + 2, exact_moe=True)
    _, cache = m.prefill(params, pre_b, P + S + 2, exact_moe=True)
    lg_dec, new_cache = m.decode(params, cache, last)
    rel = float(jnp.max(jnp.abs(lg_dec - ref))) / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-2, (name, rel)
    assert int(new_cache["pos"]) == int(cache["pos"]) + 1
    if not any(k == "moe" for k in cfg.block_pattern):
        # without routed experts, train forward == inference forward exactly
        logits_full, _, _ = forward(cfg, params, full_b, mode="train", window_override=wo)
        ref_t = logits_full[:, -1]
        rel_t = float(jnp.max(jnp.abs(lg_dec - ref_t))) / (float(jnp.max(jnp.abs(ref_t))) + 1e-9)
        assert rel_t < 2e-2, (name, rel_t)


@pytest.mark.parametrize("name", ["deepseek-v2-lite-16b", "llama4-maverick-400b-a17b"])
def test_moe_fused_dispatch_matches_loop(name, key):
    """The H1 fused dispatch must agree with the per-slot loop whenever no
    token is dropped (full capacity): identical routing, one combine."""
    from repro.models.moe import moe_apply, moe_init

    cfg = ARCHS[name].reduced()
    cfg_f = dataclasses.replace(cfg, moe_fused_dispatch=True)
    params, _ = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    out_loop, aux_loop = moe_apply(cfg, params, x, full_capacity=True)
    out_fused, aux_fused = moe_apply(cfg_f, params, x, full_capacity=True)
    np.testing.assert_allclose(np.asarray(out_fused), np.asarray(out_loop),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux_fused), float(aux_loop), rtol=1e-6)
    # under capacity pressure both still produce finite outputs & equal aux
    out_c, _ = moe_apply(cfg_f, params, x, full_capacity=False)
    assert bool(jnp.isfinite(out_c).all())


def test_param_counts_match_published():
    expect = {
        "llama3-8b": 8.0e9,
        "yi-34b": 34.4e9,
        "olmo-1b": 1.18e9,
        "deepseek-v2-lite-16b": 15.7e9,
        "musicgen-large": 3.3e9,
        "stablelm-12b": 12.1e9,
    }
    for name, n in expect.items():
        got = ARCHS[name].param_count()
        assert abs(got - n) / n < 0.06, (name, got, n)
    # llama4: total ~400B, active ~17B (a17b)
    l4 = ARCHS["llama4-maverick-400b-a17b"]
    assert 3.5e11 < l4.param_count() < 4.5e11
    assert 1.0e10 < l4.active_param_count() < 2.0e10


def test_long_500k_policy():
    from repro.configs import SHAPES
    long = SHAPES["long_500k"]
    runs = {n for n, c in ARCHS.items() if c.supports_shape(long)}
    assert runs == {"rwkv6-1.6b", "recurrentgemma-9b", "llama3-8b"}


def test_ring_cache_wraparound(key):
    """Sliding-window decode must stay consistent with the full forward after
    the ring buffer wraps (pos > W): recurrentgemma's local blocks with W=8,
    decoding 12 tokens beyond an 8-token prefill."""
    cfg = dataclasses.replace(ARCHS["recurrentgemma-9b"].reduced(), window=8)
    m = build(cfg)
    params = m.init(key)
    B, S_pre, S_total = 1, 8, 20
    toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)

    _, cache = m.prefill(params, {"tokens": toks[:, :S_pre]}, S_total + 2)
    for t in range(S_pre, S_total):
        lg_dec, cache = m.decode(params, cache, toks[:, t : t + 1])

    logits_full, _, _ = forward(cfg, params, {"tokens": toks}, mode="train")
    ref = logits_full[:, -1]
    rel = float(jnp.max(jnp.abs(lg_dec - ref))) / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-2, rel
