"""ISSUE 6: fault-tolerant rounds -- deterministic fault injection, fused
uplink screening, and the demotion == silence contract.

The load-bearing invariant: a faulted + screened round is BIT-IDENTICAL to a
participation-masked round with the same effective mask.  Because
``faults.plan`` is a pure function of (fault seed, round, client), the tests
precompute each round's draw, derive the mask a perfect screen would
produce (active & ~silent & ~corrupt), monkeypatch
``tree_util.participation_mask`` in a fault-free reference run to return
exactly that mask, and assert whole-state bitwise equality across all four
centralised algorithms (arena AND pytree paths) plus the dropout-only graph
analogue.  Identical clients make the demotion guarantee exact: honest
deviations are bitwise equal, so the round median is exact and every
corrupted row (NaN/Inf by the finite flag; sign/blowup by deviation) is
demoted while no honest row ever is.

Also here: interpret-mode + hypothesis parity for the fused screen kernel,
the all-silent round as a well-defined no-op, same-seed fault-trace
determinism, checkpoint retention/truncation, and the config validators.
"""
import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.checkpoint import msgpack_ckpt as ckpt
from repro.configs.base import FaultConfig, FederatedConfig
from repro.core import arena, faults, make, quadratic
from repro.core import tree_util as T
from repro.kernels import ops

ALGOS = ["gpdmm", "agpdmm", "scaffold", "fedavg"]
M = 8
D = 24  # packs to one 128-lane arena row


def _params():
    return {"w": 0.7 * jnp.ones((D,), jnp.float32)}


def _grad(p, b):
    # identical linear clients: every honest uplink is bitwise equal, so the
    # screen's round median is exact and demotion is all-or-nothing
    return jax.tree.map(lambda x: 0.1 * x, p)


def _batch():
    return {"d": jnp.zeros((M, 1), jnp.float32)}


def _run(cfg, rounds, grad=_grad, m=M, params=None, batch=None):
    fed = make(cfg)
    s = fed.init(params if params is not None else _params(), m)
    rows = []
    for _ in range(rounds):
        s, mx = fed.round(s, grad, batch if batch is not None else _batch())
        rows.append(mx)
    return fed, s, rows


def _assert_trees_equal(a, b, ignore=("round",)):
    a = {k: v for k, v in a.items() if k not in ignore}
    b = {k: v for k, v in b.items() if k not in ignore}
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for xa, xb in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_fault_config_parse_round_trips():
    fc = FaultConfig.parse("dropout=0.1,corrupt=0.05,seed=7")
    assert fc.dropout == 0.1 and fc.corrupt == 0.05 and fc.seed == 7
    assert fc.any
    assert not FaultConfig().any
    with pytest.raises(ValueError, match="unknown"):
        FaultConfig.parse("dropuot=0.1")
    with pytest.raises(ValueError):
        FaultConfig(dropout=1.5)


def test_screen_flag_validated():
    with pytest.raises(ValueError, match="screen"):
        FederatedConfig(algorithm="gpdmm", inner_steps=1, eta=0.1,
                        screen="maybe")


def test_cohort_tile_must_divide_cohort():
    # cohort = ceil(0.5 * 8) = 4; tile 3 does not divide it
    with pytest.raises(ValueError) as ei:
        FederatedConfig(algorithm="gpdmm", inner_steps=1, eta=0.1,
                        participation=0.5, num_clients=8, cohort_tile=3)
    assert "3" in str(ei.value) and "4" in str(ei.value)
    # divisors (and tiles >= the cohort, clamped by the engine) stay legal
    FederatedConfig(algorithm="gpdmm", inner_steps=1, eta=0.1,
                    participation=0.5, num_clients=8, cohort_tile=2)
    FederatedConfig(algorithm="gpdmm", inner_steps=1, eta=0.1,
                    participation=0.5, num_clients=8, cohort_tile=4)


# ---------------------------------------------------------------------------
# the fault plan: deterministic, pure, disjoint
# ---------------------------------------------------------------------------

def test_plan_is_deterministic_and_disjoint():
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=1, eta=0.1,
                          faults=FaultConfig(dropout=0.3, corrupt=0.4, seed=5))
    p1 = faults.plan(cfg, 3, 16)
    p2 = faults.plan(cfg, 3, 16)
    np.testing.assert_array_equal(np.asarray(p1.silent), np.asarray(p2.silent))
    np.testing.assert_array_equal(np.asarray(p1.corrupt), np.asarray(p2.corrupt))
    np.testing.assert_array_equal(np.asarray(p1.kind), np.asarray(p2.kind))
    # a client never both drops AND corrupts: it either returns or it doesn't
    assert not bool(jnp.any(p1.silent & p1.corrupt))
    # different rounds draw different schedules (generically)
    others = [faults.plan(cfg, r, 16) for r in range(8)]
    assert any(not np.array_equal(np.asarray(p1.silent), np.asarray(o.silent))
               for o in others)
    # no schedule -> no plan
    assert faults.plan(FederatedConfig(algorithm="gpdmm", inner_steps=1,
                                       eta=0.1), 0, 4) is None


def test_rate_one_means_everyone():
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=1, eta=0.1,
                          faults=FaultConfig(dropout=1.0))
    p = faults.plan(cfg, 0, 5)
    assert bool(jnp.all(p.silent))


# ---------------------------------------------------------------------------
# the fused screen kernel: xla vs interpret parity
# ---------------------------------------------------------------------------

def _corrupted_buffer(key, m, w, dtype=jnp.float32):
    u = jax.random.normal(key, (m, w), jnp.float32)
    u = u.at[0].set(jnp.nan).at[1, :1].set(jnp.inf)
    if m > 3:
        u = u.at[3].multiply(1e6)
    return u.astype(dtype)


@pytest.mark.parametrize("shape", [(4, 128), (6, 384), (3, 640), (5, 130)],
                         ids=["one_block", "multi", "wide", "padded_width"])
@pytest.mark.parametrize("per_row", [False, True], ids=["bcast", "per_row"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_screen_kernel_interpret_parity(shape, per_row, dtype):
    m, w = shape
    u = _corrupted_buffer(jax.random.key(0), m, w, dtype)
    ref = jax.random.normal(jax.random.key(1), (m, w) if per_row else (w,),
                            jnp.float32).astype(dtype)
    fin_x, sq_x = ops.screen_uplink(u, ref, impl="xla")
    fin_p, sq_p = ops.screen_uplink(u, ref, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(fin_x), np.asarray(fin_p))
    np.testing.assert_allclose(np.asarray(sq_x), np.asarray(sq_p),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 6), w=st.integers(1, 300), seed=st.integers(0, 2**16),
       per_row=st.booleans())
def test_screen_kernel_parity_property(m, w, seed, per_row):
    k0, k1 = jax.random.split(jax.random.key(seed))
    u = jax.random.normal(k0, (m, w), jnp.float32)
    if seed % 3 == 0:
        u = u.at[seed % m].set(jnp.nan)
    ref = jax.random.normal(k1, (m, w) if per_row else (w,), jnp.float32)
    fin_x, sq_x = ops.screen_uplink(u, ref, impl="xla")
    fin_p, sq_p = ops.screen_uplink(u, ref, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(fin_x), np.asarray(fin_p))
    np.testing.assert_allclose(np.asarray(sq_x), np.asarray(sq_p),
                               rtol=1e-5, atol=1e-4)


def test_screen_tree_matches_packed_arena():
    # the per-leaf tree screen and the packed-arena screen agree on the same
    # state: zero padding contributes zero deviation and a finite flag
    tree = {"a": jax.random.normal(jax.random.key(0), (5, 7)),
            "b": jax.random.normal(jax.random.key(1), (5, 130))}
    ref = {"a": jnp.ones((7,)), "b": 0.5 * jnp.ones((130,))}
    tree["a"] = tree["a"].at[2].set(jnp.nan)
    tree["b"] = tree["b"].at[4].multiply(1e7)
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=1, eta=0.1,
                          screen=True)
    keep_tree = faults.screen_keep_tree(cfg, tree, ref)
    spec = arena.ArenaSpec.from_tree(ref)
    keep_arena = faults.screen_keep(
        cfg, spec.pack_stacked(tree), spec.pack(ref))
    np.testing.assert_array_equal(np.asarray(keep_tree), np.asarray(keep_arena))
    assert not bool(keep_tree[2]) and not bool(keep_tree[4])


# ---------------------------------------------------------------------------
# the tentpole invariant: screened == participation-masked, bitwise
# ---------------------------------------------------------------------------

def _expected_masks(cfg, rounds, m):
    """The mask a perfect screen produces: active & ~silent & ~corrupt."""
    out = []
    for r in range(rounds):
        p = faults.plan(cfg, r, m)
        out.append(np.asarray(~(p.silent | p.corrupt)))
    return out


@pytest.mark.parametrize("use_arena", [True, False], ids=["arena", "pytree"])
@pytest.mark.parametrize("algo", ALGOS)
def test_screened_round_equals_masked_round(algo, use_arena, monkeypatch):
    rounds = 4
    fc = FaultConfig(dropout=0.25, corrupt=0.3, seed=11)
    cfg_f = FederatedConfig(algorithm=algo, inner_steps=2, eta=0.02,
                            use_arena=use_arena, faults=fc, screen=True)
    _, state_f, rows_f = _run(cfg_f, rounds)

    masks = _expected_masks(cfg_f, rounds, M)
    # identical clients => every corrupted row is demoted, no honest row is
    for r, mx in enumerate(rows_f):
        p = faults.plan(cfg_f, r, M)
        assert float(mx["faults_demoted"]) == float(np.asarray(p.corrupt).sum())
        assert float(mx["faults_injected"]) == float(
            np.asarray(p.silent | p.corrupt).sum())

    # reference: NO faults, NO screen -- just the PR 5 participation-mask
    # path, fed the exact mask the screen produced (rounds run eagerly, one
    # participation_mask call per round)
    it = iter(masks)
    monkeypatch.setattr(T, "participation_mask",
                        lambda key, m, frac: jnp.asarray(next(it)))
    cfg_m = FederatedConfig(algorithm=algo, inner_steps=2, eta=0.02,
                            use_arena=use_arena, participation=0.5,
                            cohort=False)
    _, state_m, _ = _run(cfg_m, rounds)
    _assert_trees_equal(state_f, state_m)


def test_graph_fault_silence_equals_masked(monkeypatch):
    # graph engine: dropout-only faults == stochastic firing with the same
    # per-round mask (screen off isolates the silence path)
    n = 6
    params = {"w": 0.7 * jnp.ones((D,), jnp.float32)}
    batch = {"d": jnp.zeros((n, 1), jnp.float32)}
    rounds = 3
    fc = FaultConfig(dropout=0.4, seed=13)
    cfg_f = FederatedConfig(algorithm="gpdmm", inner_steps=2, eta=0.02,
                            use_arena=True, topology="ring", faults=fc,
                            screen=False)
    _, state_f, rows_f = _run(cfg_f, rounds, m=n, params=params, batch=batch)
    assert all(math.isfinite(float(mx["faults_injected"])) for mx in rows_f)

    masks = [np.asarray(~faults.plan(cfg_f, r, n).silent)
             for r in range(rounds)]
    it = iter(masks)
    monkeypatch.setattr(T, "participation_mask",
                        lambda key, m, frac: jnp.asarray(next(it)))
    cfg_m = FederatedConfig(algorithm="gpdmm", inner_steps=2, eta=0.02,
                            use_arena=True, topology="ring",
                            participation=0.5)
    _, state_m, _ = _run(cfg_m, rounds, m=n, params=params, batch=batch)
    _assert_trees_equal(state_f, state_m)


def test_cohort_screened_equals_masked_population():
    # cohort engine with faults+screen vs the masked full-population oracle
    # with the same effective mask: the cohort gather/scatter must preserve
    # the demotion contract row-for-row (cf. tests/test_cohort.py)
    rounds = 3
    fc = FaultConfig(dropout=0.2, corrupt=0.3, seed=17)
    common = dict(algorithm="gpdmm", inner_steps=2, eta=0.02, use_arena=True,
                  participation=0.5, num_clients=M, faults=fc, screen=True)
    cfg_c = FederatedConfig(cohort=True, **common)
    cfg_m = FederatedConfig(cohort=False, **common)
    _, state_c, rows_c = _run(cfg_c, rounds)
    _, state_m, rows_m = _run(cfg_m, rounds)
    _assert_trees_equal(state_c, state_m)
    for mc, mm in zip(rows_c, rows_m):
        assert float(mc["faults_demoted"]) == float(mm["faults_demoted"])


# ---------------------------------------------------------------------------
# all-silent round: a well-defined no-op
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["arena", "pytree", "cohort"])
@pytest.mark.parametrize("algo", ALGOS)
def test_all_silent_round_is_noop(algo, mode):
    kw = dict(algorithm=algo, inner_steps=2, eta=0.02,
              use_arena=mode != "pytree",
              faults=FaultConfig(dropout=1.0, seed=3))
    if mode == "cohort":
        kw.update(participation=0.5, num_clients=4, cohort=True)
    cfg = FederatedConfig(**kw)
    fed = make(cfg)
    s = fed.init({"w": 0.7 * jnp.ones((D,))}, 4)
    b = {"d": jnp.zeros((4, 1), jnp.float32)}
    # one round reaches the all-silent fixed point (x_s -> mean of the
    # cached uplinks); every later round must leave the state bitwise alone
    s, _ = fed.round(s, _grad, b)
    before = jax.tree.map(lambda x: np.asarray(x), s)
    s, mx = fed.round(s, _grad, b)
    _assert_trees_equal(before, s)
    for v in jax.tree.leaves(mx):
        assert bool(jnp.all(jnp.isfinite(jnp.asarray(v, jnp.float32))))


# ---------------------------------------------------------------------------
# same-seed replay: the fault trace is part of the trajectory
# ---------------------------------------------------------------------------

def test_fault_trace_replays_bitwise():
    cfg = FederatedConfig(algorithm="scaffold", inner_steps=2, eta=0.02,
                          use_arena=True,
                          faults=FaultConfig(dropout=0.25, corrupt=0.25,
                                             straggler=0.1, seed=23),
                          screen=True)
    _, s1, r1 = _run(cfg, 5)
    _, s2, r2 = _run(cfg, 5)
    _assert_trees_equal(s1, s2, ignore=())
    for a, b in zip(r1, r2):
        assert float(a["faults_injected"]) == float(b["faults_injected"])
        assert float(a["faults_demoted"]) == float(b["faults_demoted"])


def test_screened_run_tracks_fault_free_run():
    # ISSUE acceptance: with a 10% dropout + 5% corrupt schedule the
    # screened trajectory lands near the fault-free one on a real objective
    prob = quadratic.generate(jax.random.key(0), m=8, n=60, d=D)
    eta = 0.5 / prob.L
    rounds = 40
    base = dict(algorithm="gpdmm", inner_steps=3, eta=eta, use_arena=True)

    def obj(cfg):
        opt = make(cfg)
        s = opt.init(jnp.zeros((prob.d,)), prob.m)
        for _ in range(rounds):
            s, _ = opt.round(s, prob.oracle(), prob.batch())
        return float(prob.F(opt.server_params(s)))

    clean = obj(FederatedConfig(**base))
    faulted = obj(FederatedConfig(
        faults=FaultConfig(dropout=0.1, corrupt=0.05, seed=7), **base))
    # "within 10%" on the scale of the total descent from the zero init
    scale = float(prob.F(jnp.zeros((prob.d,))) - prob.f_star)
    assert math.isfinite(faulted)
    assert abs(faulted - clean) <= 0.1 * scale


# ---------------------------------------------------------------------------
# checkpoint satellite: retention, durability, loud rejection
# ---------------------------------------------------------------------------

def test_checkpoint_keeps_last_n_and_rejects_truncation(tmp_path):
    for s in range(5):
        ckpt.save(tmp_path, s, {"x": jnp.arange(3.0), "s": s}, keep=2)
    names = sorted(p.name for p in pathlib.Path(tmp_path).glob("*.msgpack"))
    assert names == ["step_00000003.msgpack", "step_00000004.msgpack"]
    assert ckpt.latest_step(tmp_path) == 4
    t = ckpt.load(tmp_path)
    assert t["s"] == 4

    fp = tmp_path / "step_00000004.msgpack"
    fp.write_bytes(fp.read_bytes()[:10])
    with pytest.raises(ValueError, match="step_00000004.*truncated or corrupt"):
        ckpt.load(tmp_path, 4)
    with pytest.raises(FileNotFoundError, match="step_00000099"):
        ckpt.load(tmp_path, 99)
