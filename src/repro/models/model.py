"""Top-level language model: embeddings (incl. the multimodal stub frontends),
decoder stack, head(s), loss, and the serve (prefill/decode) paths.

Batch dict:
  tokens   (B, S) int32        -- or (B, K, S) for musicgen's K codebooks
  targets  same shape as tokens (train only)
  patches  (B, P, frontend_dim) -- VLM prefix embeddings (stub frontend)
  loss_mask optional (B, S_pred) f32

``build(cfg)`` returns a ``Model`` with pure functions; params are plain
nested dicts so the federated core can treat them as opaque pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp  # noqa: F401

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import stack as S
from repro.sharding.constraints import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def model_init(key, cfg: ArchConfig):
    dtype = L._dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p: dict[str, Any] = {}
    sp: dict[str, Any] = {}

    if cfg.n_codebooks > 1:
        emb = jax.random.normal(ks[0], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model)) * 0.02
        p["embed"] = {"w": emb.astype(dtype)}
        sp["embed"] = {"w": (None, "vocab", "embed")}
        head = jax.random.normal(ks[1], (cfg.n_codebooks, cfg.d_model, cfg.vocab_size)) * 0.02
        p["head"] = {"w": head.astype(dtype)}
        sp["head"] = {"w": (None, "embed", "vocab")}
    else:
        p["embed"], sp["embed"] = L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype)
        for k in p["embed"]:
            p["embed"][k] = p["embed"][k] * 0.02
        if not cfg.tie_embeddings:
            w, s = L.dense_init(ks[1], (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dtype)
            p["head"] = {"w": w}
            sp["head"] = {"w": s}

    if cfg.frontend == "vision":
        w1, s1 = L.dense_init(ks[2], (cfg.frontend_dim, cfg.d_model), (None, "embed"), dtype)
        w2, s2 = L.dense_init(ks[3], (cfg.d_model, cfg.d_model), ("embed", "embed2"), dtype)
        p["projector"] = {"w1": w1, "w2": w2}
        sp["projector"] = {"w1": s1, "w2": s2}

    p["stack"], sp["stack"] = S.stack_init(ks[4], cfg, dtype)
    p["final_norm"], sp["final_norm"] = L.norm_init(cfg.norm_kind, cfg.d_model)
    return p, sp


# ---------------------------------------------------------------------------
# embed / head
# ---------------------------------------------------------------------------

def _embed(cfg: ArchConfig, params, tokens, patches=None):
    if cfg.n_codebooks > 1:
        # tokens (B, K, S): summed codebook embeddings
        x = 0.0
        for kb in range(cfg.n_codebooks):
            x = x + jnp.take(params["embed"]["w"][kb], tokens[:, kb], axis=0)
        return x
    x = L.embed_apply(params["embed"], tokens)
    if cfg.frontend == "vision" and patches is not None:
        pj = params["projector"]
        pre = jax.nn.gelu(patches.astype(x.dtype) @ pj["w1"]) @ pj["w2"]
        x = jnp.concatenate([pre, x], axis=1)
    return x


def _head(cfg: ArchConfig, params, x):
    if cfg.n_codebooks > 1:
        logits = jnp.einsum("bsd,kdv->bskv", x, params["head"]["w"]).astype(jnp.float32)
        return constrain(logits, *([None] * (logits.ndim - 1)), "model")
    if cfg.tie_embeddings:
        logits = L.head_apply(params["embed"]["w"], x)
    else:
        logits = (x @ params["head"]["w"]).astype(jnp.float32)
    # keep the vocab dim sharded: unsharded logits are the single largest
    # activation in LM training (B*S*V*4 bytes)
    return constrain(logits, *([None] * (logits.ndim - 1)), "model")


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(cfg: ArchConfig, params, batch, *, mode="train", cache=None, pos=None,
            cache_cap: int = 0, window_override: Optional[int] = None,
            exact_moe: bool = False):
    x = _embed(cfg, params, batch["tokens"], batch.get("patches"))
    x, new_cache, aux = S.stack_apply(
        cfg, params["stack"], x, mode=mode, cache=cache, pos=pos,
        cache_cap=cache_cap, window_override=window_override, exact_moe=exact_moe,
    )
    x = L.norm_apply(cfg.norm_kind, params["final_norm"], x)
    logits = _head(cfg, params, x)
    return logits, new_cache, aux


def _xent(logits, targets, mask=None):
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: a gather over the
    # vocab-sharded dim would force GSPMD to all-gather the full logits
    # (observed +13 GiB/device on olmo-1b); the one-hot einsum contracts
    # shard-locally and psums a scalar.
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    onehot = constrain(onehot, *([None] * (onehot.ndim - 1)), "model")
    tgt = jnp.einsum("...v,...v->...", logits, onehot)
    nll = lse - tgt
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def loss_fn(cfg: ArchConfig, params, batch):
    """Causal LM loss; returns (loss, aux_dict)."""
    logits, _, aux = forward(cfg, params, batch, mode="train")
    if cfg.n_codebooks > 1:
        # logits (B,S,K,V) vs targets (B,K,S)
        tgt = jnp.moveaxis(batch["targets"], 1, 2)  # (B,S,K)
        loss = _xent(logits, tgt, batch.get("loss_mask"))
    elif cfg.frontend == "vision":
        n_text = batch["tokens"].shape[1]
        text_logits = logits[:, -n_text:]
        loss = _xent(text_logits, batch["targets"], batch.get("loss_mask"))
    else:
        loss = _xent(logits, batch["targets"], batch.get("loss_mask"))
    total = loss + 0.01 * aux
    return total, {"xent": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(cfg: ArchConfig, params, batch, *, cache_cap: int,
            window_override: Optional[int] = None, exact_moe: bool = False):
    """Returns (last_token_logits, cache). cache carries a scalar "pos"."""
    logits, new_cache, _ = forward(
        cfg, params, batch, mode="prefill", cache_cap=cache_cap,
        window_override=window_override, exact_moe=exact_moe,
    )
    seq = batch["tokens"].shape[-1]
    if cfg.frontend == "vision" and batch.get("patches") is not None:
        seq = seq + batch["patches"].shape[1]
    cache = {"layers": new_cache, "pos": jnp.asarray(seq, jnp.int32)}
    return logits[:, -1], cache


def decode_step(cfg: ArchConfig, params, cache, tokens, *,
                window_override: Optional[int] = None):
    """tokens: (B, 1) int32 (or (B, K, 1) musicgen). Returns (logits, cache)."""
    pos = cache["pos"]
    batch = {"tokens": tokens}
    logits, new_layers, _ = forward(
        cfg, params, batch, mode="decode", cache=cache["layers"], pos=pos,
        window_override=window_override,
    )
    return logits[:, -1] if cfg.n_codebooks == 1 else logits[:, -1], {
        "layers": new_layers,
        "pos": pos + 1,
    }


def cache_shapes(cfg: ArchConfig, batch: int, cap: int, *,
                 window_override: Optional[int] = None):
    dtype = L._dtype(cfg.dtype)
    layers = S.stack_cache_shapes(cfg, batch, cap, dtype, window_override)
    return {"layers": layers, "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_specs(cfg: ArchConfig, *, window_override: Optional[int] = None):
    """Logical-axis pytree parallel to cache_shapes (sharding rules input)."""
    return {"layers": S.stack_cache_specs(cfg, window_override), "pos": ()}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable  # (key) -> params
    specs: Callable  # () -> logical-axis pytree (same structure as params)
    loss: Callable  # (params, batch) -> (loss, aux)
    apply: Callable  # (params, batch) -> logits
    prefill: Callable  # (params, batch, cache_cap) -> (logits, cache)
    decode: Callable  # (params, cache, tokens) -> (logits, cache)
    cache_shapes: Callable  # (batch, cap) -> ShapeDtypeStruct pytree
    cache_specs: Callable  # () -> logical-axis pytree (parallel to cache_shapes)


def build(cfg: ArchConfig, *, window_override: Optional[int] = None) -> Model:
    _specs_cache: list = []

    def init(key):
        p, sp = model_init(key, cfg)
        if not _specs_cache:
            _specs_cache.append(sp)
        return p

    def specs():
        if not _specs_cache:
            box = {}

            def f(key):
                p, sp = model_init(key, cfg)
                box["sp"] = sp  # static metadata captured during trace
                return p

            jax.eval_shape(f, jax.random.key(0))
            _specs_cache.append(box["sp"])
        return _specs_cache[0]

    return Model(
        cfg=cfg,
        init=init,
        specs=specs,
        loss=lambda p, b: loss_fn(cfg, p, b),
        apply=lambda p, b: forward(cfg, p, b, mode="train", window_override=window_override)[0],
        prefill=lambda p, b, cap, **kw: prefill(cfg, p, b, cache_cap=cap, window_override=window_override, **kw),
        decode=lambda p, c, t: decode_step(cfg, p, c, t, window_override=window_override),
        cache_shapes=lambda b, cap: cache_shapes(cfg, b, cap, window_override=window_override),
        cache_specs=lambda: cache_specs(cfg, window_override=window_override),
    )
