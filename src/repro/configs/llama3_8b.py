"""llama3-8b -- dense GQA, 128k vocab [arXiv:2407.21783].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  Full attention;
long_500k runs via the documented sliding-window variant (window 8192).
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=("dense",),
    attn_kind="gqa",
    rope_theta=500_000.0,
    norm_kind="rmsnorm",
    shard_cache_seq=True,  # SSPerf H2: kv=8 can't divide the 16-way model axis
    subquadratic=False,
    sw_variant_window=8192,  # long_500k uses the SW variant
    fed=FederatedConfig(algorithm="gpdmm", layout="client_axis"),
    microbatch=16,  # grad-accum chunks per inner step (activation memory)
    source="arXiv:2407.21783 (Llama 3)",
)
