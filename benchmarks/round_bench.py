"""Whole-round benchmark: per-leaf pytree path vs flat-arena + fused
round-tail path (ISSUE 1 tentpole acceptance), extended with the ISSUE 2
inner-loop rework (arena-native gradient oracles, 0 boundary passes per
step; the round-batched ``lax.scan`` driver, one dispatch per R rounds), the
ISSUE 3 cross-algorithm rows (SCAFFOLD and FedAvg on the same arena fast
path, so every paper figure comparing them against GPDMM/AGPDMM measures the
ALGORITHM, not a per-leaf-pytree implementation tax), and the ISSUE 4
topology rows: decentralized graph-PDMM (ring vs star vs complete) at the
lm_flat shape plus the neighbor-reduce kernel cell, and the ISSUE 7 async
rows: the fused stale_mix admission kernel plus whole-round gpdmm cells
under a delay schedule at max_staleness in {0, 2, 4}.

The federated round is memory-bound elementwise math over the stacked
``(m, params)`` client state, so the figure of merit is full-state HBM
passes (one pass = reading or writing every element of one (m, N) state
tensor once).  The analytic counts below follow the op chains in
``core/gpdmm.py`` literally: per-leaf tree.map chains each re-read their
operands; a fused kernel is counted as its actual reads+writes; the
arena-resident state never repacks per round (only the server-sized x_s
row, 1/m of the state, excluded as O(1/m)).

Three problem shapes:
  * ``small``   -- the paper's least-squares scale (one tiny leaf).
  * ``lm_flat`` -- LM-scale flat parameter buffer (one (2^20,) leaf, m x N
                   = 8M f32).  The arena layout is exactly this flat view.
  * ``lm_tree`` -- the same 1M params as a multi-leaf transformer-ish tree.
                   With a plain pytree grad each inner step pays an
                   unpack(x)/pack(g) round trip at the gradient-oracle
                   boundary (+4 passes/step, ``oracle=boundary``); an
                   arena-native oracle (``oracle=native``) evaluates on the
                   packed buffer and pays 0.

Record columns beyond ISSUE 1: ``oracle`` ("tree" = per-leaf pytree grad,
"boundary" = arena via the unpack/pack wrapper, "native" = arena-native
grad oracle) and ``driver`` ("per_round" = one dispatch per round,
"scan8" = 8 rounds per dispatch via core.make_scan_rounds; us_per_round is
the per-round share).  Gradient math itself is identical on all paths (a
trivial linear grad keeps the round tail visible).  Emits the
``BENCH_round.json`` trajectory consumed by ``benchmarks/regression_gate.py``
(the CI wall-time gate) plus the CSV lines the other benches use.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, time_round_donated
from repro.configs.base import FaultConfig, FederatedConfig
from repro.core import arena, make, make_oracle, make_scan_rounds, pdmm_graph
from repro.core import popstore
from repro.core.tree_util import cohort_count
from repro.kernels import ops

PROBLEMS = {
    "small": {"m": 8, "shapes": {"w": (24,)}},
    "lm_flat": {"m": 8, "shapes": {"w": (1 << 20,)}},
    "lm_tree": {
        "m": 8,
        "shapes": {
            "embed": (512, 384),
            "blk0_w1": (768, 512),
            "blk0_w2": (512, 768),
            "blk1_w1": (768, 512),
            "blk1_w2": (512, 768),
            "bias": (768,),
        },
    },
}

VARIANTS = {
    "plain": {},
    "ef21": {"uplink_bits": 8},
    # cohort=False pins the matrix's partial cells to the MASKED
    # full-population round (cohort='auto' would silently reroute the arena
    # cells onto the ISSUE 5 cohort engine, mislabeling path/hbm_passes);
    # the cohort engine has its own bench_cohort rows at path=arena_cohort
    "partial": {"participation": 0.5, "cohort": False},
}

# ISSUE 3: SCAFFOLD/FedAvg join the matrix so the paper's cross-algorithm
# comparison is apples-to-apples on wall time.  SCAFFOLD has no EF21 variant
# (two coupled uplink variables, core.scaffold rejects the combination).
ALGO_VARIANTS = {
    "gpdmm": ("plain", "ef21", "partial"),
    "agpdmm": ("plain", "ef21", "partial"),
    "scaffold": ("plain", "partial"),
    "fedavg": ("plain", "ef21", "partial"),
}

# scan-driver records: one per gated algorithm (the arena hot paths CI guards)
SCAN_ALGOS = ("gpdmm", "scaffold")
SCAN_R = 8  # rounds per dispatch for the scan-driver records


def _params(shapes):
    k = jax.random.key(0)
    return {
        name: jax.random.normal(jax.random.fold_in(k, i), shape)
        for i, (name, shape) in enumerate(sorted(shapes.items()))
    }


def _tree_grad(p, _b):
    # grad of 0.15||x||^2: memory-bound, so the round tail stays visible
    return jax.tree.map(lambda x: 0.3 * x, p)


# the same linear grad as an arena-native oracle: evaluated directly on the
# packed (m, width) buffer -- zero boundary passes per inner step
_native_grad = make_oracle(_tree_grad, grad_arena=lambda spec: (lambda xa, b: 0.3 * xa))

ORACLES = {"tree": _tree_grad, "boundary": _tree_grad, "native": _native_grad}


def _passes_pdmm(algo: str, variant: str, K: int, *, arena: bool,
                 multi_leaf: bool, oracle: str) -> int:
    if not arena:
        n = 1  # x_s broadcast to (m, N), materialised once per round
        n += 5 * K  # per-leaf fused updates
        n += 4 + 3  # lam_is (3r+1w) + uplink (2r+1w)
        if variant == "ef21":
            n += 3 + 3 + 3  # tree_sub + _qdq (reduce 1r, apply 1r+1w) + tree_add
        if variant == "partial":
            n += 3  # tree_select uplink
            if algo == "gpdmm":
                n += 3  # x_c carry select
        n += 1 + 3  # client mean (1r) + lam_s_new (2r+1w)
        return n
    n = 5 * K  # arena-wide fused updates; server row broadcasts in-kernel
    if multi_leaf and oracle == "boundary":
        # pytree gradient-oracle boundary: unpack x (1r+1w) + pack g (1r+1w)
        # per inner step; an arena-native oracle (or a flat/single-leaf
        # tree, where the boundary is a pure reshape) pays ZERO
        n += 4 * K
    n += 4  # fused round_tail, uplink-only (lam_is skipped off-trace): 3r + 1w
    if variant == "ef21":
        n += 2 + 4  # rowmax reduce (2r) + fused qdq apply (3r+1w)
    if variant == "partial":
        n += 3
        if algo == "gpdmm":
            n += 3
    n += 1 + 3  # client mean + fused dual_from_uplink (2r+1w)
    return n


def _passes_scaffold(variant: str, K: int, *, arena: bool, multi_leaf: bool,
                     oracle: str) -> int:
    if not arena:
        n = 1 + 1  # x_s and c broadcasts, materialised once per round
        n += 3  # lam = c_b - c_i (2r+1w)
        n += 5 * K  # per-leaf fused updates (lam-carried, rho = 0)
        n += 5  # c_i_new tmap over (c_i, c_b, x_s_b, x_K): 4r+1w
        if variant == "partial":
            n += 3 + 3  # select c_i_new + select x_up
        n += 3 + 1  # dx: tree_sub (2r+1w) + client mean (1r)
        n += 3 + 1  # dc: tree_sub + client mean
        return n
    n = 2  # lam = c - c_i materialised ONCE (1r+1w; server row in-kernel)
    n += 5 * K  # arena-wide fused updates
    if multi_leaf and oracle == "boundary":
        n += 4 * K
    n += 3  # fused scaffold_cv: 2r + 1w (both server rows broadcast in-kernel)
    if variant == "partial":
        n += 3 + 3  # where(c_i_new) + where(x_up)
    n += 1  # x-mean (all-reduce #1)
    n += 2  # dc mean over (c_i_new - c_i) (all-reduce #2)
    return n


def _passes_fedavg(variant: str, K: int, *, arena: bool, multi_leaf: bool,
                   oracle: str) -> int:
    if not arena:
        n = 1  # x_s broadcast
        n += 4 * K  # lam-free per-leaf fused updates
        if variant == "ef21":
            n += 3 + 3 + 3
        if variant == "partial":
            n += 3
        n += 1  # client mean
        return n
    n = 4 * K  # lam-free arena-wide fused updates
    if multi_leaf and oracle == "boundary":
        n += 4 * K
    if variant == "ef21":
        n += 2 + 4
    if variant == "partial":
        n += 3
    n += 1  # client mean
    return n


def round_passes(algo: str, variant: str, K: int, *, arena: bool,
                 multi_leaf: bool, oracle: str) -> int:
    """Full-(m, N) elementwise HBM passes per round (reads + writes), grad
    math excluded (identical on all paths).  One fused eq.-(20) update =
    4r + 1w with the dual operand, 3r + 1w without (SCAFFOLD/FedAvg)."""
    if algo in ("gpdmm", "agpdmm"):
        return _passes_pdmm(algo, variant, K, arena=arena,
                            multi_leaf=multi_leaf, oracle=oracle)
    if algo == "scaffold":
        return _passes_scaffold(variant, K, arena=arena,
                                multi_leaf=multi_leaf, oracle=oracle)
    assert algo == "fedavg", algo
    return _passes_fedavg(variant, K, arena=arena,
                          multi_leaf=multi_leaf, oracle=oracle)


def _record(problem, algo, variant, path, oracle, driver, m, n, K, us, passes):
    state_bytes = m * n * 4
    eff_gbps = passes * state_bytes / (us * 1e-6) / 1e9
    emit(f"round_{problem}_{algo}_{variant}_{path}_{oracle}_{driver}", us,
         f"passes={passes},effective_GBps={eff_gbps:.2f}")
    return {
        "problem": problem, "algo": algo, "variant": variant, "path": path,
        "oracle": oracle, "driver": driver,
        "m": m, "n_params": n, "K": K,
        "us_per_round": round(us, 1),
        "hbm_passes": passes,
        "state_bytes": state_bytes,
        "effective_GBps": round(eff_gbps, 2),
    }


def bench_round(problem: str, algo: str, variant: str, K: int = 4):
    # fresh compilation caches per cell: accumulated executables and live
    # buffers from earlier cells otherwise skew the later timings by 2x+
    # (recompilation happens inside time_fn's warmup, not the timed iters)
    jax.clear_caches()
    spec = PROBLEMS[problem]
    m = spec["m"]
    params = _params(spec["shapes"])
    multi_leaf = len(spec["shapes"]) > 1
    n = sum(int(jnp.size(v)) for v in params.values())
    batch = {"dummy": jnp.zeros((m, 1))}
    records = []

    # (path, oracle) cells: the pytree path has no arena boundary; on the
    # arena the native oracle is the new hot path, and lm_tree keeps a
    # "boundary" record to show what the unpack/pack wrapper still costs
    cells = [(False, "tree"), (True, "native")]
    if multi_leaf:
        cells.append((True, "boundary"))
    for arena, oracle in cells:
        cfg = FederatedConfig(algorithm=algo, inner_steps=K, eta=0.1,
                              use_arena=arena, **VARIANTS[variant])
        opt = make(cfg)
        state = opt.init(params, m)
        grad = ORACLES[oracle]

        fn = jax.jit(lambda s: opt.round(s, grad, batch)[0])
        us = time_fn(fn, state)
        passes = round_passes(algo, variant, K, arena=arena,
                              multi_leaf=multi_leaf, oracle=oracle)
        path = "arena" if arena else "pytree"
        records.append(_record(problem, algo, variant, path, oracle,
                               "per_round", m, n, K, us, passes))

        if variant == "plain" and algo in SCAN_ALGOS:
            # round-batched scan driver: R rounds per dispatch, reported as
            # the per-round share -- isolates what dispatch overhead costs
            scan = make_scan_rounds(opt, grad)
            batches = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (SCAN_R,) + x.shape), batch)
            sfn = jax.jit(lambda s, b: scan(s, b)[0])
            us_scan = time_fn(sfn, state, batches) / SCAN_R
            records.append(_record(problem, algo, variant, path, oracle,
                                   f"scan{SCAN_R}", m, n, K, us_scan, passes))

    pyt = next(r for r in records if r["path"] == "pytree" and r["driver"] == "per_round")
    arn = next(r for r in records if r["path"] == "arena" and r["oracle"] == "native"
               and r["driver"] == "per_round")
    dp = (pyt["hbm_passes"] - arn["hbm_passes"]) / pyt["hbm_passes"]
    print(f"  -> {problem}/{algo}/{variant}: passes {pyt['hbm_passes']} -> "
          f"{arn['hbm_passes']} ({dp:+.0%}), time {pyt['us_per_round']:.0f} -> "
          f"{arn['us_per_round']:.0f} us")
    return records


# ISSUE 5: cohort-sampled round engine rows -- us/round vs participation at
# fixed m on the lm_flat shape.  The masked path (path=arena) runs the K-step
# inner loop over ALL m rows and discards the silent results at the tail, so
# its wall time is flat in the participation; the cohort engine
# (path=arena_cohort) gathers the active rows, runs the same fused kernels on
# the (m_active, width) cohort buffer, and scatters back -- its inner-loop
# cost scales with the cohort.  The participation=0.5 cohort cell keys as
# variant=partial (joining the regression gate next to the masked cells the
# matrix above times); the sweep cells key as partial25 / partial10.
COHORT_PARTS = (0.5, 0.25, 0.1)


def cohort_round_passes(K: int, m: int, m_active: int) -> float:
    """Analytic full-(m, N) passes of the gpdmm cohort round: cohort-sized
    work at fraction f = m_active / m (2x2 row gathers of lam/x_c, 5K fused
    inner steps, the 4-pass round tail, 2x2 row scatters of u_hat/x_c), plus
    the inherent O(m) tail -- the server mean over the scattered u_hat (1r)
    and the full dual refresh (1r + 1w)."""
    f = m_active / m
    return f * (2 * 2 + 5 * K + 4 + 2 * 2) + 3


def bench_cohort(problem: str = "lm_flat", K: int = 4):
    jax.clear_caches()
    spec = PROBLEMS[problem]
    m = spec["m"]
    params = _params(spec["shapes"])
    n = sum(int(jnp.size(v)) for v in params.values())
    batch = {"dummy": jnp.zeros((m, 1))}
    records = []
    for part in COHORT_PARTS:
        variant = "partial" if part == 0.5 else f"partial{int(part * 100)}"
        mc = cohort_count(m, part)
        cell_us = {}
        for cohort, path in ((False, "arena"), (True, "arena_cohort")):
            if part == 0.5 and not cohort:
                # the main matrix already times (gpdmm, partial, arena)
                continue
            cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.1,
                                  use_arena=True, participation=part,
                                  cohort=cohort)
            opt = make(cfg)
            # fresh param copy per cell: state["x_s"] aliases params, and
            # the donated round chain consumes its state
            state = opt.init(jax.tree.map(jnp.copy, params), m)
            # donated steady-state timing for BOTH paths: donation is what
            # lets the cohort scatter alias the population buffer in place
            # (the launchers donate; time_fn cannot), and the masked round
            # must be timed under the same contract for the ratio to mean
            # anything
            us = time_round_donated(
                lambda s: opt.round(s, _native_grad, batch)[0], state)
            cell_us[path] = us
            passes = (cohort_round_passes(K, m, mc) if cohort else
                      round_passes("gpdmm", "partial", K, arena=True,
                                   multi_leaf=len(spec["shapes"]) > 1,
                                   oracle="native"))
            rec = _record(problem, "gpdmm", variant, path, "native",
                          "per_round", m, n, K, us, passes)
            rec["participation"] = part
            rec["m_active"] = mc
            records.append(rec)
        if "arena" in cell_us:
            print(f"  -> {problem}/gpdmm/{variant}: cohort {mc}/{m} rows, "
                  f"masked {cell_us['arena']:.0f} -> cohort "
                  f"{cell_us['arena_cohort']:.0f} us/round "
                  f"(x{cell_us['arena'] / cell_us['arena_cohort']:.1f})")
    return records


# PR 8: host-resident population store (core.popstore) -- the resident
# (m, width) client buffers live in host numpy and only the sampled cohort
# stages to device, so device memory is O(cohort), not O(m).  Two kinds of
# rows: (1) a gated (lm_flat, gpdmm, partial, popstore) cell at the matrix
# shape, directly comparable to the path=arena / path=arena_cohort cells at
# the same key -- it prices the host driver (gather/scatter + prefetch ring
# + device_put) against the all-device cohort round; (2) the population
# sweep, m = 10^3 .. 10^6 at a fixed 64-client cohort and the smallest
# LM-scale arena row (width = 1024, the arena_min_width floor -- the full
# lm_flat row at m = 10^6 would be a 4 TB host store).  The store's OWN
# per-round cost (gather/stage/scatter + the f64 running-sum update) is
# O(cohort) and stays flat in m; what still scales with m is the seeded
# participation draw (permutation(key, m) < n -- the contract that keeps
# every layout on the same mask sequence), which EVERY cohort round pays
# regardless of layout, so it is timed separately (draw_us) and reported
# next to the whole-round figure.  device_state_bytes stays O(cohort) while
# host_state_bytes grows 1000x across the sweep.
POP_SWEEP_M = (1_000, 10_000, 100_000, 1_000_000)
POP_WIDTH = 1024
POP_COHORT = 64


def _mem_available_bytes():
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        return None
    return None


def _time_host_round(runner, state, batch, iters: int = 8):
    """Median us/round of the HOST-driver popstore round.  ``time_fn`` /
    ``time_round_donated`` jit their argument, which a host function cannot
    be; the runner's own np.asarray sync already bounds each iteration."""
    state, _ = runner.round(state, batch)  # warmup: compiles the device body
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state, _ = runner.round(state, batch)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def bench_popsweep(K: int = 4):
    jax.clear_caches()
    records = []

    # (1) gated cell: popstore at the matrix shape/key (lm_flat, partial),
    # same m/participation as the arena + arena_cohort cells it sits beside
    spec = PROBLEMS["lm_flat"]
    m = spec["m"]
    params = _params(spec["shapes"])
    n = sum(int(jnp.size(v)) for v in params.values())
    batch = {"dummy": jnp.zeros((m, 1))}
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.1,
                          use_arena=True, participation=0.5, cohort=True,
                          popstore=True, popstore_min_clients=1)
    runner = popstore.Runner(cfg, _native_grad)
    state = runner.init(jax.tree.map(jnp.copy, params), m)
    us = _time_host_round(runner, state, batch)
    mc = cohort_count(m, 0.5)
    rec = _record("lm_flat", "gpdmm", "partial", "popstore", "native",
                  "per_round", m, n, K, us, cohort_round_passes(K, m, mc))
    rec["participation"] = 0.5
    rec["m_active"] = mc
    rec["device_state_bytes"] = popstore.device_bytes(cfg, 1 << 20, m)
    records.append(rec)
    print(f"  -> lm_flat/gpdmm/partial popstore: {rec['us_per_round']:.0f} "
          f"us/round (host store, cohort {mc}/{m})")

    # (2) the population sweep at fixed cohort size
    width = POP_WIDTH
    pp = {"w": jnp.zeros((width,), jnp.float32) + 0.5}
    avail = _mem_available_bytes()
    for m in POP_SWEEP_M:
        part = POP_COHORT / m
        cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.1,
                              use_arena=True, arena_min_width=width,
                              participation=part, cohort=True,
                              popstore=True, popstore_min_clients=1)
        n_buf = len(popstore.POP_BUFFERS["gpdmm"])
        host_bytes = n_buf * m * width * 4
        # loud memory guard, never a silent cap: the 10^6 cell needs ~8 GB
        # of host store (+ transient init), far past a 7 GB CI runner
        if avail is not None and host_bytes * 2 > avail:
            print(f"  -> popsweep m={m}: SKIPPED (host store needs "
                  f"{host_bytes / 1e9:.1f} GB x2, only "
                  f"{avail / 1e9:.1f} GB available)")
            continue
        jax.clear_caches()
        runner = popstore.Runner(cfg, _native_grad)
        state = runner.init(pp, m)
        batch = {"dummy": jnp.zeros((m, 1))}
        us = _time_host_round(runner, state, batch)
        # the participation draw alone: O(m log m) on every cohort layout
        draw_ts = []
        for r in range(4):
            t0 = time.perf_counter()
            jax.block_until_ready(runner._idx_fn(jnp.int32(r)))
            draw_ts.append(time.perf_counter() - t0)
        draw_us = sorted(draw_ts)[len(draw_ts) // 2] * 1e6
        dev_bytes = popstore.device_bytes(cfg, width, m)
        mc = cohort_count(m, part)
        assert mc == POP_COHORT, (m, part, mc)  # the tolerant-ceil contract
        rec = {
            "problem": "popsweep", "algo": "gpdmm", "variant": f"m{m}",
            "path": "popstore", "oracle": "native", "driver": "per_round",
            "m": m, "n_params": width, "K": K,
            "us_per_round": round(us, 1),
            "hbm_passes": 0,
            "state_bytes": host_bytes,
            "effective_GBps": 0.0,
            "participation": part,
            "m_active": mc,
            "draw_us": round(draw_us, 1),
            "host_state_bytes": host_bytes,
            "device_state_bytes": dev_bytes,
        }
        emit(f"round_popsweep_gpdmm_m{m}_popstore", us,
             f"draw_us={draw_us:.0f},host_GB={host_bytes / 1e9:.2f},"
             f"device_MB={dev_bytes / 1e6:.2f}")
        records.append(rec)
        print(f"  -> popsweep m={m}: {us:.0f} us/round (draw {draw_us:.0f} "
              f"us), host {host_bytes / 1e9:.2f} GB, staged device "
              f"{dev_bytes / 1e6:.2f} MB (cohort {mc})")
        del state, runner
    return records


# ISSUE 9: telemetry-on overhead.  The same popstore round as the gated
# (lm_flat, gpdmm, partial, popstore) cell, but with the global span tracer
# live (popstore emits its phase spans + ring counter into a real trace
# file).  Keys as path=popstore_telemetry: a FRESH-ONLY cell the regression
# gate reports but never fails on -- the gate's telemetry-off cells are the
# proof the off path stayed free; this cell prices the ON path.
def bench_telemetry(K: int = 4):
    import tempfile

    from repro.telemetry import spans as tel_spans

    jax.clear_caches()
    spec = PROBLEMS["lm_flat"]
    m = spec["m"]
    params = _params(spec["shapes"])
    n = sum(int(jnp.size(v)) for v in params.values())
    batch = {"dummy": jnp.zeros((m, 1))}
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.1,
                          use_arena=True, participation=0.5, cohort=True,
                          popstore=True, popstore_min_clients=1)
    runner = popstore.Runner(cfg, _native_grad)
    state = runner.init(jax.tree.map(jnp.copy, params), m)
    us_off = _time_host_round(runner, state, batch)

    tracer = tel_spans.get_tracer()
    was = tracer.enabled
    with tempfile.TemporaryDirectory() as td:
        tracer.configure(enabled=True, trace_out=f"{td}/bench_trace.json")
        try:
            us_on = _time_host_round(runner, state, batch)
            tracer.flush()
        finally:
            tracer.close()
            tracer.configure(enabled=was)
    mc = cohort_count(m, 0.5)
    rec = _record("lm_flat", "gpdmm", "partial", "popstore_telemetry",
                  "native", "per_round", m, n, K, us_on,
                  cohort_round_passes(K, m, mc))
    rec["participation"] = 0.5
    rec["m_active"] = mc
    rec["us_per_round_off"] = round(us_off, 1)
    rec["overhead_pct"] = round(100.0 * (us_on - us_off) / us_off, 2)
    print(f"  -> lm_flat/gpdmm/partial popstore_telemetry: {us_on:.0f} "
          f"us/round tracing vs {us_off:.0f} off "
          f"({rec['overhead_pct']:+.1f}%)")
    return [rec]


# ISSUE 4: decentralized graph-PDMM rows -- ring vs star vs complete at the
# LM-scale flat shape.  One graph round = (per firing phase) the fused
# neighbor reduce over the (2E, width) edge-dual arena, the K-step inner
# loop on the firing nodes, and the one-pass directed dual flip.
TOPOLOGIES = ("star", "ring", "complete")


def graph_round_passes(topo, K: int, m: int) -> float:
    """Full-(m, N) elementwise passes per graph round (row ops / m), same
    conventions as ``round_passes``: grad math excluded, row-sized server
    reads excluded, one fused eq.-(20)-style update = 3r + 1w (x, g, s; the
    zero server row is O(1/m)), neighbor reduce = read 2E + write n rows,
    dual flip = read z[rev] + gather x[nbr] + write = 3 x 2E rows."""
    rows = 0
    for members in topo.colors:
        dm = members[members < topo.n_data]
        am = members[members >= topo.n_data]
        rows += topo.n_slots + topo.n  # neighbor reduce
        rows += 4 * K * int(dm.size)  # fused inner steps on firing rows
        rows += 2 * int(am.size)  # aux closed form: read s row + write x row
        rows += 3 * topo.n_slots  # edge flip
    return rows / m


def bench_topology(problem: str = "lm_flat", K: int = 4):
    """gpdmm_graph at the lm_flat shape across topologies, plus the
    neighbor-reduce kernel cell: the Pallas path is timed against the XLA
    segment-sum reference whenever a TPU backend is present (on CPU only the
    XLA reference runs -- interpret mode measures correctness, not speed)."""
    jax.clear_caches()
    spec = PROBLEMS[problem]
    m = spec["m"]
    params = _params(spec["shapes"])
    n = sum(int(jnp.size(v)) for v in params.values())
    batch = {"dummy": jnp.zeros((m, 1))}
    records = []
    for topo_name in TOPOLOGIES:
        cfg = FederatedConfig(algorithm="gpdmm_graph", topology=topo_name,
                              inner_steps=K, eta=0.1)
        opt = make(cfg)
        state = opt.init(params, m)
        fn = jax.jit(lambda s: opt.round(s, _native_grad, batch)[0])
        us = time_fn(fn, state)
        topo = pdmm_graph.topo_for(cfg, m)
        passes = graph_round_passes(topo, K, m)
        rec = _record(problem, "gpdmm_graph", "plain", "arena", "native",
                      "per_round", m, n, K, us, passes)
        rec["topology"] = topo_name
        records.append(rec)
        print(f"  -> {problem}/gpdmm_graph/{topo_name}: "
              f"{rec['us_per_round']:.0f} us/round "
              f"(n={topo.n} nodes, {topo.n_edges} edges)")

    # neighbor-reduce kernel cell at the same shape (ring: 2E = 2m rows)
    cfg = FederatedConfig(algorithm="gpdmm_graph", topology="ring")
    topo = pdmm_graph.topo_for(cfg, m)
    width = arena.ArenaSpec.from_tree(params).width
    z = jax.random.normal(jax.random.key(5), (topo.n_slots, width))
    impls = ["xla"] + (["pallas"] if jax.default_backend() == "tpu" else [])
    for impl in impls:
        fn = jax.jit(lambda zz: ops.neighbor_reduce(
            zz, seg=topo.src, first=topo.first_flags(), sgn=topo.sgn,
            n=topo.n, impl=impl))
        us = time_fn(fn, z)
        gbps = (topo.n_slots + topo.n) * width * 4 / (us * 1e-6) / 1e9
        emit(f"neighbor_reduce_{problem}_ring_{impl}", us,
             f"effective_GBps={gbps:.2f}")
        records.append({
            "problem": problem, "algo": "neighbor_reduce", "variant": "ring",
            "path": f"kernel_{impl}", "oracle": "native", "driver": "per_call",
            "m": m, "n_params": n, "K": 0,
            "us_per_round": round(us, 1),
            "hbm_passes": (topo.n_slots + topo.n) / m,
            "state_bytes": m * n * 4,
            "effective_GBps": round(gbps, 2),
            "topology": "ring",
        })
    return records


# ISSUE 6: the fused uplink screen -- kernel-alone cells plus a whole-round
# screened cell.  Screening is OFF for the gated plain cells (screen="auto"
# engages only with a fault schedule), so the hot paths CI guards pay zero;
# the screened cell shows what a robustness-enabled round costs.
def bench_screen(problem: str = "lm_flat", K: int = 4):
    jax.clear_caches()
    spec = PROBLEMS[problem]
    m = spec["m"]
    params = _params(spec["shapes"])
    n = sum(int(jnp.size(v)) for v in params.values())
    width = arena.ArenaSpec.from_tree(params).width
    u = jax.random.normal(jax.random.key(7), (m, width))
    ref = jax.random.normal(jax.random.key(8), (width,))
    records = []
    impls = ["xla"] + (["pallas"] if jax.default_backend() == "tpu" else [])
    for impl in impls:
        fn = jax.jit(lambda uu: ops.screen_uplink(uu, ref, impl=impl))
        us = time_fn(fn, u)
        # ONE read of the (m, width) uplink arena; the (m,)-sized outputs
        # are O(1/width)
        gbps = m * width * 4 / (us * 1e-6) / 1e9
        emit(f"screen_{problem}_{impl}", us, f"effective_GBps={gbps:.2f}")
        records.append({
            "problem": problem, "algo": "screen_uplink", "variant": "plain",
            "path": f"kernel_{impl}", "oracle": "native", "driver": "per_call",
            "m": m, "n_params": n, "K": 0,
            "us_per_round": round(us, 1),
            "hbm_passes": 1,
            "state_bytes": m * n * 4,
            "effective_GBps": round(gbps, 2),
        })

    batch = {"dummy": jnp.zeros((m, 1))}
    cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.1,
                          use_arena=True,
                          faults=FaultConfig(dropout=0.1, corrupt=0.05,
                                             seed=7),
                          screen=True)
    opt = make(cfg)
    state = opt.init(jax.tree.map(jnp.copy, params), m)
    fn = jax.jit(lambda s: opt.round(s, _native_grad, batch)[0])
    us = time_fn(fn, state)
    # the partial variant already counts the u_hat/x_c silence selects; on
    # top of that: the wire-corruption where (1r + 1w) + the one-pass screen
    passes = round_passes("gpdmm", "partial", K, arena=True,
                          multi_leaf=len(spec["shapes"]) > 1,
                          oracle="native") + 3
    rec = _record(problem, "gpdmm", "screened", "arena", "native",
                  "per_round", m, n, K, us, passes)
    records.append(rec)
    print(f"  -> {problem}/gpdmm/screened: {rec['us_per_round']:.0f} us/round "
          f"(faults 10% dropout + 5% corrupt, screen on)")
    return records


# ISSUE 7: bounded-staleness async rounds -- the fused stale_mix admission
# kernel alone, plus whole-round gpdmm cells under a delay schedule at
# max_staleness in {0, 2, 4} on BOTH the arena and pytree paths.  The
# pytree sibling is what lets the regression gate normalise the gated
# (gpdmm, stale, arena) cell by the same run's reference path; the
# max_staleness=0 cell times the engine at its synchronous collapse point
# (nothing is ever admitted, the mix is the bitwise masked select).
STALE_MAXES = (0, 2, 4)


def stale_round_passes(K: int, *, arena: bool) -> int:
    """Analytic passes of the async gpdmm round: the faulted masked round
    pays the partial-variant selects (uplink covering + x_c carry); on the
    arena the fused stale_mix (3r + 2w: uplink, cache, buffer in; mixed,
    buffer' out) REPLACES the 3-pass uplink select (+2 net), on the pytree
    path the per-leaf mix (2r + 1w) and the buffer-store select (2r + 1w)
    stack on top of it (+6)."""
    base = round_passes("gpdmm", "partial", K, arena=arena,
                        multi_leaf=False, oracle="native" if arena else "tree")
    return base + (2 if arena else 6)


def bench_stale(problem: str = "lm_flat", K: int = 4):
    jax.clear_caches()
    spec = PROBLEMS[problem]
    m = spec["m"]
    params = _params(spec["shapes"])
    n = sum(int(jnp.size(v)) for v in params.values())
    width = arena.ArenaSpec.from_tree(params).width
    records = []

    # kernel-alone cell: ONE fused pass over the uplink/cache/stale-buffer
    # arenas emitting the mixed contribution rows + the updated buffer
    u = jax.random.normal(jax.random.key(9), (m, width))
    cache = jax.random.normal(jax.random.key(10), (m, width))
    buf = jax.random.normal(jax.random.key(11), (m, width))
    fresh = jnp.arange(m) % 3 != 0
    store = jnp.arange(m) % 4 == 0
    w = jnp.where(jnp.arange(m) % 2 == 0, 0.5, 0.0).astype(jnp.float32)
    impls = ["xla"] + (["pallas"] if jax.default_backend() == "tpu" else [])
    for impl in impls:
        fn = jax.jit(lambda uu: ops.stale_mix(uu, cache, buf, fresh, store,
                                              w, impl=impl))
        us = time_fn(fn, u)
        # 3 reads (uplink, cache, buffer) + 2 writes (mixed, buffer')
        gbps = 5 * m * width * 4 / (us * 1e-6) / 1e9
        emit(f"stale_mix_{problem}_{impl}", us, f"effective_GBps={gbps:.2f}")
        records.append({
            "problem": problem, "algo": "stale_mix", "variant": "plain",
            "path": f"kernel_{impl}", "oracle": "native", "driver": "per_call",
            "m": m, "n_params": n, "K": 0,
            "us_per_round": round(us, 1),
            "hbm_passes": 5,
            "state_bytes": m * n * 4,
            "effective_GBps": round(gbps, 2),
        })

    batch = {"dummy": jnp.zeros((m, 1))}
    for ms in STALE_MAXES:
        # the max_staleness=2 cell keys as plain "stale" (regression-gated
        # with its pytree sibling); the sweep cells key as stale0 / stale4
        variant = "stale" if ms == 2 else f"stale{ms}"
        cell_us = {}
        for use_arena in (True, False):
            cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.1,
                                  use_arena=use_arena,
                                  faults=FaultConfig(delay=0.3, seed=9),
                                  max_staleness=ms, async_rounds=True)
            opt = make(cfg)
            state = opt.init(jax.tree.map(jnp.copy, params), m)
            oracle = "native" if use_arena else "tree"
            grad = ORACLES[oracle]
            fn = jax.jit(lambda s: opt.round(s, grad, batch)[0])
            us = time_fn(fn, state)
            path = "arena" if use_arena else "pytree"
            cell_us[path] = us
            records.append(_record(problem, "gpdmm", variant, path, oracle,
                                   "per_round", m, n, K,
                                   us, stale_round_passes(K, arena=use_arena)))
        print(f"  -> {problem}/gpdmm/{variant}: max_staleness={ms}, "
              f"pytree {cell_us['pytree']:.0f} -> arena "
              f"{cell_us['arena']:.0f} us/round")
    return records


# ISSUE 10: auto-tuned stepsizes + residual-based early termination.  Two
# kinds of rows: (1) the fused residual_norm kernel alone -- ONE pass over
# the (m, width) client-state arena and its previous-round snapshot emitting
# per-row dx2/x2 (the early-exit criterion; 2r, the (m,) outputs are
# O(1/width)); (2) the rounds-to-tol comparison the autotune layer exists
# for: heterogeneous diagonal-quadratic clients (per-client curvature a_i
# spread over ~30x, exactly the regime where one global stepsize must be
# tuned to the WORST client), gpdmm driven to the relative fixed-point
# residual tol under (a) auto-derived per-client eta_i = safety / L_i and
# (b) the hand-tuned global eta = safety / max_i L_i.  rounds_auto /
# rounds_fixed / rounds_speedup record the budget saving at EQUAL tol.
def bench_autotune(problem: str = "lm_flat", K: int = 4, tol: float = 1e-5,
                   max_rounds: int = 600):
    from repro.core import autotune

    jax.clear_caches()
    spec = PROBLEMS[problem]
    m = spec["m"]
    params = _params(spec["shapes"])
    n = sum(int(jnp.size(v)) for v in params.values())
    width = arena.ArenaSpec.from_tree(params).width
    records = []

    # (1) kernel-alone cell
    x = jax.random.normal(jax.random.key(12), (m, width))
    prev = x + 0.01 * jax.random.normal(jax.random.key(13), (m, width))
    impls = ["xla"] + (["pallas"] if jax.default_backend() == "tpu" else [])
    for impl in impls:
        fn = jax.jit(lambda a: ops.residual_norm(a, prev, impl=impl))
        us = time_fn(fn, x)
        gbps = 2 * m * width * 4 / (us * 1e-6) / 1e9
        emit(f"residual_norm_{problem}_{impl}", us,
             f"effective_GBps={gbps:.2f}")
        records.append({
            "problem": problem, "algo": "residual_norm", "variant": "plain",
            "path": f"kernel_{impl}", "oracle": "native", "driver": "per_call",
            "m": m, "n_params": n, "K": 0,
            "us_per_round": round(us, 1),
            "hbm_passes": 2,
            "state_bytes": m * n * 4,
            "effective_GBps": round(gbps, 2),
        })

    # (2) rounds-to-tol, auto vs hand-tuned.  grad_i(x) = a_i (x - t_i):
    # curvature a_i log-spaced over ~30x, per-client targets t_i, so the
    # stiffest client caps the one-global-eta setting while auto hands every
    # client its own safety/a_i
    assert width == n, "lm_flat's flat leaf is already lane-aligned"
    a = jnp.logspace(-1.0, 0.5, m, dtype=jnp.float32)
    t = 0.5 * jax.random.normal(jax.random.key(14), (m, width))
    batch = {"a": a, "t": t}

    def _het_tree_grad(p, b):
        (leaf,) = jax.tree.leaves(p)
        g = b["a"] * (leaf - b["t"])
        return jax.tree.unflatten(jax.tree.structure(p), [g])

    het_oracle = make_oracle(
        _het_tree_grad,
        grad_arena=lambda spec_: (
            lambda xa, b: b["a"][:, None] * (xa - b["t"])))

    def rounds_to_tol(cfg):
        opt = make(cfg)
        state = opt.init(jax.tree.map(jnp.copy, params), m)

        @jax.jit
        def rf(s):
            s2, _ = opt.round(s, het_oracle, batch)
            return s2, autotune.state_residual(s, s2)

        ee = autotune.EarlyExit(tol, patience=1)
        us = None
        for r in range(1, max_rounds + 1):
            if r == 2:
                t0 = time.perf_counter()  # round 1 paid the compile
            state, res = rf(state)
            if r >= 2:
                jax.block_until_ready(res["res_dx2"])
                us = (time.perf_counter() - t0) / (r - 1) * 1e6
            if ee.update(res["res_dx2"], res["res_x2"]) is not None:
                return r, us
        return max_rounds, us

    # BOTH cells run the same explicit server penalty rho = 1/(K eta_hand):
    # rho is a server-side quantity the stepsizes don't decide (the mean-eta
    # default would hand the two runs different penalties and confound the
    # comparison); what is measured is purely per-client vs global stepsize
    eta_hand = autotune.SAFETY / float(a.max())
    rho = 1.0 / (K * eta_hand)
    base_cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta="auto",
                               use_arena=True, tol=tol, rho=rho)
    auto_cfg = autotune.resolve(base_cfg, het_oracle, params, m, batch)
    hand_cfg = FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=eta_hand,
                               use_arena=True, tol=tol, rho=rho)
    r_auto, us_auto = rounds_to_tol(auto_cfg)
    r_hand, _ = rounds_to_tol(hand_cfg)
    rec = _record(problem, "gpdmm", "autotune", "arena", "native",
                  "per_round", m, n, K, us_auto,
                  round_passes("gpdmm", "plain", K, arena=True,
                               multi_leaf=len(spec["shapes"]) > 1,
                               oracle="native") + 4)  # + residual_norm reads
    rec["tol"] = tol
    rec["rounds_auto"] = r_auto
    rec["rounds_fixed"] = r_hand
    rec["rounds_speedup"] = round(r_hand / max(r_auto, 1), 2)
    records.append(rec)
    print(f"  -> {problem}/gpdmm/autotune: tol={tol:g} reached in "
          f"{r_auto} rounds (auto per-client eta) vs {r_hand} "
          f"(hand-tuned global eta): x{rec['rounds_speedup']:.1f} fewer")
    return records


def run(out_path: str = "BENCH_round.json"):
    trajectory = []
    for problem in PROBLEMS:
        for algo, variants in ALGO_VARIANTS.items():
            for variant in variants:
                trajectory.extend(bench_round(problem, algo, variant))
    trajectory.extend(bench_cohort())
    trajectory.extend(bench_popsweep())
    trajectory.extend(bench_telemetry())
    trajectory.extend(bench_topology())
    trajectory.extend(bench_screen())
    trajectory.extend(bench_stale())
    trajectory.extend(bench_autotune())
    payload = {
        "bench": "round_bench",
        "autotune_note": "residual_norm rows (ISSUE 10) time the fused "
                "early-termination kernel alone -- ONE pass over the "
                "(m, width) client-state arena and its previous-round "
                "snapshot emitting per-row dx2/x2 (kernel_pallas appears "
                "when a TPU is present); the kernel_xla cell is "
                "regression-gated.  The gpdmm autotune row drives "
                "heterogeneous diagonal-quadratic clients (30x curvature "
                "spread) to the relative fixed-point residual tol: "
                "rounds_auto is the budget under auto-derived per-client "
                "eta_i = safety/L_i, rounds_fixed under the hand-tuned "
                "global eta = safety/max L_i, rounds_speedup their ratio -- "
                "fewer rounds at EQUAL tol is the claim the autotune layer "
                "ships.",
        "popstore_note": "path=popstore rows (PR 8) run the host-resident "
                "population store (core.popstore): client buffers live in "
                "host numpy, only the sampled cohort stages to device "
                "(prefetch-overlapped), and the server mean is maintained "
                "incrementally in compensated f64.  The (lm_flat, gpdmm, "
                "partial, popstore) cell is regression-gated beside the "
                "arena/arena_cohort cells at the same key; the "
                "problem=popsweep rows sweep m = 10^3..10^6 at a FIXED "
                "64-client cohort and width 1024 -- host_state_bytes grows "
                "1000x while device_state_bytes stays O(cohort), and the "
                "store's own staging cost stays flat (us_per_round minus "
                "draw_us, the O(m log m) seeded participation draw every "
                "cohort layout pays).  Sweep cells whose host store would "
                "not fit in available memory are SKIPPED with a printed "
                "notice (never silently).",
        "telemetry_note": "the path=popstore_telemetry row (ISSUE 9) "
                "re-times the gated popstore cell with the global span "
                "tracer LIVE (phase spans + the ring counter written to a "
                "real trace file); us_per_round_off / overhead_pct record "
                "the paired telemetry-off timing from the same process.  "
                "Fresh-only: the gate's own cells all run telemetry-off, "
                "which is the regression proof that the disabled path adds "
                "no per-round host work.",
        "stale_note": "stale_mix rows (ISSUE 7) time the fused bounded-"
                "staleness admission kernel alone -- ONE pass over the "
                "uplink/cache/stale-buffer arenas (3r + 2w) emitting the "
                "mixed contribution rows and the updated buffer "
                "(kernel_pallas appears when a TPU is present).  The gpdmm "
                "stale / stale0 / stale4 rows run the whole async round "
                "under a 30% delay schedule at max_staleness = 2 / 0 / 4 on "
                "both layouts; stale0 is the synchronous collapse point "
                "(nothing admitted, the mix is the bitwise masked select), "
                "and the (gpdmm, stale, arena) cell is regression-gated "
                "against its same-run pytree sibling.",
        "screen_note": "screen_uplink rows (ISSUE 6) time the fused "
                "robustness screen alone -- ONE pass over the (m, width) "
                "uplink arena emitting per-client finite flags + squared "
                "deviations from the downlink row (kernel_pallas appears "
                "when a TPU is present).  The gpdmm screened row runs the "
                "whole arena round with a 10% dropout + 5% corrupt fault "
                "schedule and the screen on; the gated plain cells run with "
                "screen='auto' and no schedule, so they pay nothing for the "
                "robustness layer.",
        "cohort_note": "gpdmm partial/partial25/partial10 rows at "
                "path=arena_cohort (ISSUE 5) run the cohort-sampled round "
                "engine (gather active rows -> fused cohort inner loop -> "
                "scatter back); the paired path=arena rows are the masked "
                "full-population rounds at the same participation, so the "
                "ratio shows compute scaling with the cohort, not the "
                "population.  participation / m_active columns record the "
                "sweep; the partial (0.5) cohort cell is regression-gated.",
        "topology_note": "gpdmm_graph rows (ISSUE 4) run the decentralized "
                "graph-PDMM round (core.pdmm_graph) at the lm_flat shape; "
                "the topology column names the consensus graph.  The "
                "neighbor_reduce rows time the kernel alone on the ring's "
                "edge-dual arena (path=kernel_xla is the segment-sum "
                "reference; kernel_pallas appears when a TPU is present and "
                "must be no slower).",
        "note": "hbm_passes are analytic full-(m,N) elementwise passes per "
                "round (grad math excluded, identical on all paths); "
                "effective_GBps = passes * state_bytes / wall_time.  oracle: "
                "tree = per-leaf pytree grad, boundary = arena via the "
                "unpack/pack wrapper (+4 passes/step on multi-leaf trees), "
                "native = arena-native grad oracle (0 boundary passes).  "
                "driver: per_round = one dispatch per round, scan8 = 8 "
                "rounds per lax.scan dispatch (us_per_round is the "
                "per-round share).",
        "trajectory": trajectory,
    }
    pathlib.Path(out_path).write_text(json.dumps(payload, indent=2))
    print(f"[round_bench] wrote {len(trajectory)} records to {out_path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_round.json")
    args = ap.parse_args()
    run(args.out)
