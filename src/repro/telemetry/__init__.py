"""Telemetry subsystem (ISSUE 9): span tracing, metrics, profiler capture.

Three layers, all off by default and near-free when off:

  * ``spans``   -- round-phase span tracer emitting Chrome trace-event JSON
                   (Perfetto-loadable); the global tracer instruments the
                   round driver, the popstore prefetch ring, the hot-swap
                   server, and the watchdog.
  * ``metrics`` -- Counter/Gauge/Histogram registry absorbing the device
                   round-metrics dicts and host-side counters, flushed to a
                   crash-safe JSONL sink and an optional Prometheus
                   textfile exporter.
  * ``jaxprof`` -- opt-in ``jax.profiler`` device-trace capture for an
                   exact round window (``--profile-rounds A:B``).

See docs/telemetry.md for the span taxonomy and metric names.
"""
from repro.telemetry.jaxprof import RoundProfiler
from repro.telemetry.metrics import (
    COUNTER_KEYS,
    Counter,
    Gauge,
    Histogram,
    JsonlSink,
    Registry,
    read_jsonl,
    write_prometheus,
)
from repro.telemetry.spans import (
    Tracer,
    close,
    configure,
    counter,
    enabled,
    flush,
    get_tracer,
    instant,
    load_trace,
    span,
    traced,
)

__all__ = [
    "COUNTER_KEYS", "Counter", "Gauge", "Histogram", "JsonlSink", "Registry",
    "RoundProfiler", "Tracer", "close", "configure", "counter", "enabled",
    "flush", "get_tracer", "instant", "load_trace", "read_jsonl", "span",
    "traced", "write_prometheus",
]
