"""Render the EXPERIMENTS.md tables from the experiments/{dryrun,roofline}
JSON artifacts.

    PYTHONPATH=src python -m benchmarks.report_tables [--which dryrun|roofline]
"""
from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1] / "experiments"

ARCH_ORDER = [
    "rwkv6-1.6b", "recurrentgemma-9b", "deepseek-v2-lite-16b", "llama3-8b",
    "olmo-1b", "stablelm-12b", "llama4-maverick-400b-a17b",
    "llava-next-mistral-7b", "musicgen-large", "yi-34b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _gib(b):
    return b / 2**30


def dryrun_table() -> str:
    rows = [
        "| arch | shape | mesh | step | mem/dev GiB | HLO GFLOP/dev | coll ops | coll MiB/dev |",
        "|---|---|---|---|---:|---:|---:|---:|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                f = ROOT / "dryrun" / f"{a}_{s}_{mesh}.json"
                if not f.exists():
                    rows.append(f"| {a} | {s} | {mesh} | MISSING | | | | |")
                    continue
                d = json.loads(f.read_text())
                if d["status"] == "skipped":
                    rows.append(f"| {a} | {s} | {mesh} | skip | — | — | — | — |")
                    continue
                if d["status"] != "ok":
                    rows.append(f"| {a} | {s} | {mesh} | FAILED | | | | |")
                    continue
                c = d["collectives"]["total"]
                rows.append(
                    f"| {a} | {s} | {mesh} | {d['step']} "
                    f"| {_gib(d['memory']['peak_bytes_est']):.1f} "
                    f"| {d['hlo_flops_per_device']/1e9:.1f} "
                    f"| {c['count']} | {c['bytes']/2**20:.1f} |"
                )
    return "\n".join(rows)


def roofline_table() -> str:
    rows = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant | useful FLOPs |",
        "|---|---|---:|---:|---:|---|---:|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            f = ROOT / "roofline" / f"{a}_{s}.json"
            if not f.exists():
                rows.append(f"| {a} | {s} | | | | MISSING | |")
                continue
            d = json.loads(f.read_text())
            if d["status"] == "skipped":
                rows.append(f"| {a} | {s} | — | — | — | skip | — |")
                continue
            if d["status"] != "ok":
                rows.append(f"| {a} | {s} | | | | FAILED: {d.get('error','')[:40]} | |")
                continue
            t = d["terms_seconds"]
            rows.append(
                f"| {a} | {s} | {t['compute']*1e3:.2f} | {t['memory']*1e3:.2f} "
                f"| {t['collective']*1e3:.2f} | **{d['dominant']}** "
                f"| {d['useful_flops_ratio']:.1%} |"
            )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", default="both", choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    if args.which in ("dryrun", "both"):
        print("### Dry-run matrix\n")
        print(dryrun_table())
    if args.which in ("roofline", "both"):
        print("\n### Roofline terms (single-pod 16x16)\n")
        print(roofline_table())


if __name__ == "__main__":
    main()
