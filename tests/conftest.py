"""Shared fixtures. NOTE: no XLA_FLAGS device-count forcing here -- smoke
tests and benches must see the real (single) CPU device; only
repro.launch.dryrun/roofline force 512 host devices, in subprocesses."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
