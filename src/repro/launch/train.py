"""Federated LM training launcher (runs for real on whatever devices exist).

    PYTHONPATH=src python -m repro.launch.train \
        --arch olmo-1b --reduced --steps 50 --algorithm gpdmm --k 4

On CPU this drives the reduced configs (the ~100M-scale end-to-end example
lives in examples/train_federated_lm.py); on a real TPU mesh the same code
path drives the full configs via --mesh production.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_arch
from repro.configs.base import FederatedConfig, ShapeConfig
from repro.core import make as make_fed
from repro.core import make_scan_rounds
from repro.data.synthetic import lm_batches
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import build_train_step
from repro.models import build as build_model


def run(
    arch: str,
    *,
    reduced: bool = True,
    steps: int = 20,
    algorithm: str = "gpdmm",
    k: int = 2,
    eta: float = 0.3,
    m: int = 4,
    per_client_batch: int = 4,
    seq_len: int = 128,
    seed: int = 0,
    ckpt_dir: str | None = None,
    log_every: int = 5,
    uplink_bits: int | None = None,
    participation: float = 1.0,
    rounds_per_call: int = 1,
):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(
        cfg,
        fed=dataclasses.replace(
            cfg.fed, algorithm=algorithm, inner_steps=k, eta=eta, num_clients=m,
            layout="client_axis", uplink_bits=uplink_bits, participation=participation,
            rounds_per_call=rounds_per_call,
        ),
    )
    model = build_model(cfg)
    fed = make_fed(cfg.fed)

    key = jax.random.key(seed)
    params = model.init(key)
    state = fed.init(params, m)

    def client_grad(p, b):
        return jax.grad(lambda q: model.loss(q, b)[0])(p)

    # donate the round state: the arena/round update aliases its input
    # buffers in place instead of holding two copies of the (m, params) state.
    # With rounds_per_call > 1 the scan driver runs R full rounds per
    # dispatch over a leading-R batch stack (metrics come back stacked).
    R = max(1, rounds_per_call)
    if R > 1:
        scan_rounds = make_scan_rounds(fed, client_grad)

        @partial(jax.jit, donate_argnums=(0,))
        def step_fn(state, batches):
            return scan_rounds(state, batches)
    else:
        @partial(jax.jit, donate_argnums=(0,))
        def step_fn(state, batch):
            return fed.round(state, client_grad, batch)

    @jax.jit
    def eval_loss(params, batch):
        # server-model loss averaged over the same stacked batch
        losses = jax.vmap(lambda b: model.loss(params, b)[0])(batch)
        return losses.mean()

    history = []
    data = lm_batches(jax.random.key(seed + 1), steps, m, per_client_batch, seq_len, cfg.vocab_size)
    t0 = time.time()
    def metrics_row(metrics):
        # last-round values, whether stacked (R,) from the scan or scalars
        return {kk: float(jnp.asarray(v).reshape(-1)[-1])
                for kk, v in metrics.items() if kk != "trace"}

    if R > 1:
        # tail shorter than R (steps % R != 0) falls back to jitted,
        # donated per-round dispatches -- same step semantics, no eager path
        round_fn = jax.jit(
            lambda s, b: fed.round(s, client_grad, b), donate_argnums=(0,))
        pending = []
        i = 0
        last = metrics = None
        for batch in data:
            pending.append(batch)
            last = batch
            if len(pending) < R:
                continue
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *pending)
            pending = []
            state, metrics = step_fn(state, stacked)  # metrics stacked (R,)
            i += R
            if (i - R) // max(1, log_every) != i // max(1, log_every):
                row = {"round": i,
                       "server_loss": float(eval_loss(fed.server_params(state), last)),
                       **metrics_row(metrics)}
                history.append(row)
                print(f"[train] {json.dumps(row)}", flush=True)
        for batch in pending:
            state, metrics = round_fn(state, batch)
            i += 1
        if last is not None and (not history or history[-1]["round"] != i):
            # always log the FINAL state (the R=1 path's i == steps-1 row)
            row = {"round": i,
                   "server_loss": float(eval_loss(fed.server_params(state), last)),
                   **(metrics_row(metrics) if metrics is not None else {})}
            history.append(row)
            print(f"[train] {json.dumps(row)}", flush=True)
    else:
        for i, batch in enumerate(data):
            state, metrics = step_fn(state, batch)
            if i % log_every == 0 or i == steps - 1:
                loss = float(eval_loss(fed.server_params(state), batch))
                row = {"round": i, "server_loss": loss,
                       **{kk: float(v) for kk, v in metrics.items() if kk != "trace"}}
                history.append(row)
                print(f"[train] {json.dumps(row)}", flush=True)
    dt = time.time() - t0
    print(f"[train] {steps} rounds (K={k}, m={m}) in {dt:.1f}s; algo={algorithm}, "
          f"rounds_per_call={R}")

    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, {"server": fed.server_params(state)})
        print(f"[train] checkpoint saved to {ckpt_dir}")
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--algorithm", default="gpdmm",
                    choices=["gpdmm", "agpdmm", "scaffold", "fedavg", "fedsplit"])
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--eta", type=float, default=0.3)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--uplink-bits", type=int, default=None,
                    help="EF21 delta-quantised uplink (beyond paper)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients active per round (async PDMM)")
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="rounds per jitted dispatch (lax.scan round batching)")
    args = ap.parse_args()
    run(
        args.arch, reduced=args.reduced, steps=args.steps, algorithm=args.algorithm,
        k=args.k, eta=args.eta, m=args.clients, per_client_batch=args.batch,
        seq_len=args.seq, ckpt_dir=args.ckpt_dir,
        uplink_bits=args.uplink_bits, participation=args.participation,
        rounds_per_call=args.rounds_per_call,
    )


if __name__ == "__main__":
    main()
