"""Whole-round benchmark: per-leaf pytree path vs flat-arena + fused
round-tail path (ISSUE 1 tentpole acceptance).

The federated round is memory-bound elementwise math over the stacked
``(m, params)`` client state, so the figure of merit is full-state HBM
passes (one pass = reading or writing every element of one (m, N) state
tensor once).  The analytic counts below follow the op chains in
``core/gpdmm.py`` literally: per-leaf tree.map chains each re-read their
operands; a fused kernel is counted as its actual reads+writes; the
arena-resident state never repacks per round (only the server-sized x_s
row, 1/m of the state, excluded as O(1/m)).

Three problem shapes:
  * ``small``   -- the paper's least-squares scale (one tiny leaf).
  * ``lm_flat`` -- LM-scale flat parameter buffer (one (2^20,) leaf, m x N
                   = 8M f32).  The arena layout is exactly this flat view,
                   so the gradient boundary costs nothing.
  * ``lm_tree`` -- the same 1M params as a multi-leaf transformer-ish tree.
                   Here each inner step pays an unpack(x)/pack(g) round
                   trip at the pytree gradient oracle boundary (+4 passes
                   per step), reported honestly: the arena still wins the
                   round TAIL, the inner-loop boundary is the price of
                   per-leaf grads (on TPU the slices/concat fuse into the
                   grad computation; XLA:CPU materialises them).

Gradient math itself is identical on both paths (a trivial linear grad
keeps the round tail visible).  Emits a ``BENCH_round.json`` trajectory
(one record per problem x algorithm x variant x path) plus the CSV lines
the other benches use.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.base import FederatedConfig
from repro.core import make

PROBLEMS = {
    "small": {"m": 8, "shapes": {"w": (24,)}},
    "lm_flat": {"m": 8, "shapes": {"w": (1 << 20,)}},
    "lm_tree": {
        "m": 8,
        "shapes": {
            "embed": (512, 384),
            "blk0_w1": (768, 512),
            "blk0_w2": (512, 768),
            "blk1_w1": (768, 512),
            "blk1_w2": (512, 768),
            "bias": (768,),
        },
    },
}

VARIANTS = {
    "plain": {},
    "ef21": {"uplink_bits": 8},
    "partial": {"participation": 0.5},
}


def _params(shapes):
    k = jax.random.key(0)
    return {
        name: jax.random.normal(jax.random.fold_in(k, i), shape)
        for i, (name, shape) in enumerate(sorted(shapes.items()))
    }


def _grad_fn(p, _b):
    # grad of 0.15||x||^2: memory-bound, so the round tail stays visible
    return jax.tree.map(lambda x: 0.3 * x, p)


def round_passes(algo: str, variant: str, K: int, *, arena: bool, multi_leaf: bool) -> int:
    """Full-(m, N) elementwise HBM passes per round (reads + writes), grad
    math excluded (identical on both paths).  One fused_update = 4r + 1w."""
    if not arena:
        n = 1  # x_s broadcast to (m, N), materialised once per round
        n += 5 * K  # per-leaf fused updates
        n += 4 + 3  # lam_is (3r+1w) + uplink (2r+1w)
        if variant == "ef21":
            n += 3 + 3 + 3  # tree_sub + _qdq (reduce 1r, apply 1r+1w) + tree_add
        if variant == "partial":
            n += 3  # tree_select uplink
            if algo == "gpdmm":
                n += 3  # x_c carry select
        n += 1 + 3  # client mean (1r) + lam_s_new (2r+1w)
        return n
    n = 5 * K  # arena-wide fused updates; server row broadcasts in-kernel
    if multi_leaf:
        # pytree gradient-oracle boundary: unpack x (1r+1w) + pack g (1r+1w)
        # per inner step; zero for flat/single-leaf params (pure reshape)
        n += 4 * K
    n += 4  # fused round_tail, uplink-only (lam_is skipped off-trace): 3r + 1w
    if variant == "ef21":
        n += 2 + 4  # rowmax reduce (2r) + fused qdq apply (3r+1w)
    if variant == "partial":
        n += 3
        if algo == "gpdmm":
            n += 3
    n += 1 + 3  # client mean + fused dual_from_uplink (2r+1w)
    return n


def bench_round(problem: str, algo: str, variant: str, K: int = 4):
    spec = PROBLEMS[problem]
    m = spec["m"]
    params = _params(spec["shapes"])
    multi_leaf = len(spec["shapes"]) > 1
    n = sum(int(jnp.size(v)) for v in params.values())
    batch = {"dummy": jnp.zeros((m, 1))}
    records = []
    for arena in [False, True]:
        cfg = FederatedConfig(algorithm=algo, inner_steps=K, eta=0.1,
                              use_arena=arena, **VARIANTS[variant])
        opt = make(cfg)
        state = opt.init(params, m)

        fn = jax.jit(lambda s: opt.round(s, _grad_fn, batch)[0])
        us = time_fn(fn, state)
        passes = round_passes(algo, variant, K, arena=arena, multi_leaf=multi_leaf)
        state_bytes = m * n * 4
        eff_gbps = passes * state_bytes / (us * 1e-6) / 1e9
        path = "arena" if arena else "pytree"
        records.append({
            "problem": problem, "algo": algo, "variant": variant, "path": path,
            "m": m, "n_params": n, "K": K,
            "us_per_round": round(us, 1),
            "hbm_passes": passes,
            "state_bytes": state_bytes,
            "effective_GBps": round(eff_gbps, 2),
        })
        emit(f"round_{problem}_{algo}_{variant}_{path}", us,
             f"passes={passes},effective_GBps={eff_gbps:.2f}")
    pyt, arn = records
    dp = (pyt["hbm_passes"] - arn["hbm_passes"]) / pyt["hbm_passes"]
    print(f"  -> {problem}/{algo}/{variant}: passes {pyt['hbm_passes']} -> "
          f"{arn['hbm_passes']} ({dp:+.0%}), time {pyt['us_per_round']:.0f} -> "
          f"{arn['us_per_round']:.0f} us")
    return records


def run(out_path: str = "BENCH_round.json"):
    trajectory = []
    for problem in PROBLEMS:
        for algo in ["gpdmm", "agpdmm"]:
            for variant in VARIANTS:
                trajectory.extend(bench_round(problem, algo, variant))
    payload = {
        "bench": "round_bench",
        "note": "hbm_passes are analytic full-(m,N) elementwise passes per "
                "round (grad math excluded, identical on both paths); "
                "effective_GBps = passes * state_bytes / wall_time.  The "
                "lm_tree rows include the pytree gradient-oracle boundary "
                "(+4 passes/step) the arena pays for multi-leaf trees.",
        "trajectory": trajectory,
    }
    pathlib.Path(out_path).write_text(json.dumps(payload, indent=2))
    print(f"[round_bench] wrote {len(trajectory)} records to {out_path}")
    return payload


if __name__ == "__main__":
    run()
