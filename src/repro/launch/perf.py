import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""SSPerf hillclimbing driver: A/B a config override against the baseline on
the full production config (16x16 mesh), reporting the roofline-relevant
deltas (per-device memory, HLO flops/bytes, collective bytes/count).

    PYTHONPATH=src python -m repro.launch.perf --arch deepseek-v2-lite-16b \
        --shape train_4k --set moe_fused_dispatch=True --tag H1

Writes experiments/perf/<arch>_<shape>_<tag>.json with {baseline, variant,
delta}.  The EXPERIMENTS.md SSPerf log references these artifacts.
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax

from repro.configs import get_arch, get_shape
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.steps import build_step

REPORT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "perf"


def _parse_val(v: str):
    if v in ("True", "False"):
        return v == "True"
    if v == "None":
        return None
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def measure(cfg, shape, *, multi_pod=False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = build_step(cfg, shape, mesh)
    # monotonic perf_counter, not time.time: compile-time deltas between
    # baseline and variant are part of the A/B report
    t0 = time.perf_counter()
    with mesh_context(mesh):
        lowered = jax.jit(
            bundle.fn,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums,
        ).lower(*bundle.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    flops, bytes_acc = hlo_stats.flops_and_bytes(compiled)
    mem = hlo_stats.memory_stats(compiled)
    coll = hlo_stats.collective_bytes(compiled.as_text())
    return {
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "memory_peak_gib": mem["peak_bytes_est"] / 2**30,
        "memory_args_gib": mem["argument_bytes"] / 2**30,
        "collective_bytes_mib": coll["total"]["bytes"] / 2**20,
        "collective_count": coll["total"]["count"],
        "collective_detail": {
            k: {"count": v["count"], "mib": round(v["bytes"] / 2**20, 1)}
            for k, v in coll.items() if k != "total"
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable); fed.* allowed")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-baseline", action="store_true",
                    help="reuse baseline from an existing report with this tag")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    shape = get_shape(args.shape)

    var = cfg
    fed_over = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if k.startswith("fed."):
            fed_over[k[4:]] = _parse_val(v)
        else:
            var = dataclasses.replace(var, **{k: _parse_val(v)})
    if fed_over:
        var = dataclasses.replace(var, fed=dataclasses.replace(var.fed, **fed_over))

    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    out_path = REPORT_DIR / f"{args.arch}_{args.shape}_{args.tag}.json"

    if args.skip_baseline and out_path.exists():
        base = json.loads(out_path.read_text())["baseline"]
    else:
        print(f"[perf] baseline {args.arch} {args.shape} ...")
        base = measure(cfg, shape, multi_pod=args.multi_pod)
    print(f"[perf] variant  {args.tag}: {args.set} ...")
    variant = measure(var, shape, multi_pod=args.multi_pod)

    def pct(b, v):
        return None if not b else round(100.0 * (v - b) / b, 2)

    delta = {
        k: pct(base[k], variant[k])
        for k in ("hlo_flops_per_device", "hlo_bytes_per_device",
                  "memory_peak_gib", "memory_args_gib",
                  "collective_bytes_mib", "collective_count")
    }
    report = {
        "arch": args.arch, "shape": args.shape, "tag": args.tag,
        "overrides": args.set, "mesh": "2x16x16" if args.multi_pod else "16x16",
        "baseline": base, "variant": variant, "delta_pct": delta,
    }
    out_path.write_text(json.dumps(report, indent=2))
    print(json.dumps({"delta_pct": delta,
                      "baseline_coll": base["collective_detail"],
                      "variant_coll": variant["collective_detail"]}, indent=2))


if __name__ == "__main__":
    main()
