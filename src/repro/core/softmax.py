"""Softmax regression -- the objective of the paper's Table I / Fig. 3
experiments (one class per client, maximum heterogeneity); the 2-class case
is logistic regression.

Parameters follow the experiments' flat-vector convention: one ``(F*C + C,)``
vector holding the row-major weight matrix ``W (F, C)`` followed by the bias
``b (C,)``, so the same problem runs on every federated algorithm with a
single-leaf parameter tree.

``oracle()`` annotates the per-client grad with the arena-native fast path
(``core.api`` protocol): the softmax cross-entropy gradient has the closed
form

    err = (softmax(x W + b) - onehot(y)) / B
    gW  = x^T err,   gb = sum_b err

so ``grad_arena`` evaluates it directly on the packed ``(m, width)`` buffer
-- slicing W and b out of each row via the spec's slice table and writing one
packed gradient buffer back.  Zero unpack/pack boundary passes per inner
step (the gradient is NOT affine in w, so the fused K-step kernel does not
apply -- the scan path with this oracle is the hot path here).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.api import make_oracle


@dataclasses.dataclass(frozen=True)
class SoftmaxRegression:
    n_features: int = 784
    n_classes: int = 10

    @property
    def dim(self) -> int:
        return self.n_features * self.n_classes + self.n_classes

    # -- flat-vector layout -------------------------------------------------
    def unravel(self, w):
        F, C = self.n_features, self.n_classes
        return w[: F * C].reshape(F, C), w[F * C :]

    def init_params(self):
        return jnp.zeros((self.dim,), jnp.float32)

    # -- objective ----------------------------------------------------------
    def loss(self, w, batch):
        """Mean cross-entropy; batch = {"x": (B, F), "y": (B,) int labels}."""
        W, b = self.unravel(w)
        logp = jax.nn.log_softmax(batch["x"] @ W + b)
        onehot = jax.nn.one_hot(batch["y"], self.n_classes)
        return -jnp.mean(jnp.sum(onehot * logp, axis=-1))

    def accuracy(self, w, x, y):
        W, b = self.unravel(w)
        pred = jnp.argmax(x @ W + b, axis=-1)
        return jnp.mean((pred == y).astype(jnp.float32))

    # -- gradient oracles ----------------------------------------------------
    def _err(self, W, b, batch):
        """(softmax(xW + b) - onehot(y)) / B -- the shared residual."""
        p = jax.nn.softmax(batch["x"] @ W + b)
        onehot = jax.nn.one_hot(batch["y"], self.n_classes)
        return (p - onehot) / batch["y"].shape[-1]

    def grad(self, w, batch):
        """Closed-form grad of ``loss`` (== jax.grad(loss), tested)."""
        W, b = self.unravel(w)
        err = self._err(W, b, batch)
        gW = batch["x"].T @ err
        return jnp.concatenate([gW.reshape(-1), jnp.sum(err, axis=0)])

    def oracle(self):
        F, C = self.n_features, self.n_classes

        def grad_arena(spec):
            (e,) = spec.leaves  # the flat (F*C + C,) leaf at offset 0
            assert e.size == self.dim, (e.size, self.dim)
            w = spec.width

            def ga(xa, batch):
                # xa: (m, width); batch leaves (m, B, ...)
                W = xa[:, : F * C].reshape(xa.shape[0], F, C)
                b = xa[:, F * C : F * C + C]
                p = jax.nn.softmax(jnp.einsum("mbf,mfc->mbc", batch["x"], W) + b[:, None])
                onehot = jax.nn.one_hot(batch["y"], C)
                err = (p - onehot) / batch["y"].shape[-1]
                gW = jnp.einsum("mbf,mbc->mfc", batch["x"], err)
                g = jnp.concatenate(
                    [gW.reshape(xa.shape[0], F * C), jnp.sum(err, axis=1)], axis=-1
                )
                return jnp.pad(g, ((0, 0), (0, w - self.dim))) if w != self.dim else g

            return ga

        def curvature_arena(spec):
            # Softmax cross-entropy Hessian: (1/B) sum_b G_b kron (diag(p_b)
            # - p_b p_b^T) over augmented features [x_b | 1] (the bias rides
            # as a constant feature).  lambda_max(diag(p) - p p^T) <= 1/2,
            # so L_i <= lambda_max(Xa_i^T Xa_i) / (2B) -- a point-free upper
            # bound (the logistic-regression bound generalised), estimated
            # by batched power iteration on the per-client augmented Gram
            # blocks.  An upper bound is the safe direction for stepsizes:
            # eta_i = safety / L_i only shrinks.
            def curv(xa, batch):
                from repro.core import autotune

                x = batch["x"]  # (m, B, F)
                B = x.shape[1]
                ones = jnp.ones(x.shape[:2] + (1,), x.dtype)
                xaug = jnp.concatenate([x, ones], axis=-1)
                G = jnp.einsum("mbf,mbg->mfg", xaug, xaug) / (2.0 * B)
                return autotune.power_iter_arena(G)

            return curv

        return make_oracle(self.grad, grad_arena=grad_arena,
                           curvature_arena=curvature_arena)
