"""Pure-jnp oracles for every kernel.  Deliberately naive and readable --
these are the ground truth the Pallas kernels and the chunked XLA paths are
tested against (``tests/test_kernels.py`` sweeps shapes/dtypes and hypothesis
cases and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, q_pos, k_pos, *, causal=True, window=None):
    """Naive masked softmax attention.

    q: (B, Sq, H, hd); k: (B, Sk, Hkv, hd); v: (B, Sk, Hkv, vd).
    q_pos: (Sq,) int32 absolute positions; k_pos: (Sk,) int32, -1 = empty slot.
    GQA: H % Hkv == 0; query group g attends to kv head g // (H // Hkv).
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / jnp.sqrt(hd).astype(jnp.float32)
    valid = k_pos[None, :] >= 0
    if causal:
        valid = valid & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
    scores = jnp.where(valid[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhgqk,bkhv->bqhgv", probs, vf)
    return out.reshape(B, Sq, H, vf.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# rwkv6 wkv recurrence
# ---------------------------------------------------------------------------

def wkv6_ref(r, k, v, w, u, s0):
    """Sequential RWKV-6 recurrence (data-dependent decay).

    r, k, w: (B, S, H, K); v: (B, S, H, V); u: (H, K); s0: (B, H, K, V).
    State update: S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Output:       y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    Returns y: (B, S, H, V), s_final: (B, H, K, V).  All math in f32.
    """
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)
    sf = s0.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,K), (B,H,K), (B,H,V), (B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    s_final, ys = jax.lax.scan(step, sf, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,V)
    return y.astype(r.dtype), s_final


# ---------------------------------------------------------------------------
# fused federated client update (paper eq. (20))
# ---------------------------------------------------------------------------

def fused_update_ref(x, g, xs, lam, step, rho):
    """Generalised federated inner step (paper eq. (20) and relatives):

        x' = x - step * (g + rho * (x - xs) + lam)

    GPDMM/AGPDMM: step = 1/(1/eta + rho); Inexact FedSplit: step = eta,
    lam = None (the dual term drops -- one fewer HBM read); SCAFFOLD:
    step = eta, rho = 0, lam = c - c_i.  All elementwise; f32 accumulate.
    """
    xf, gf, xsf = (a.astype(jnp.float32) for a in (x, g, xs))
    acc = gf + rho * (xf - xsf)
    if lam is not None:
        acc = acc + lam.astype(jnp.float32)
    return (xf - step * acc).astype(x.dtype)


# ---------------------------------------------------------------------------
# rg-lru linear recurrence
# ---------------------------------------------------------------------------

def lru_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t  (elementwise), a/b: (B, S, D), h0: (B, D)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    hs_final, hs = jax.lax.scan(step, h0.astype(jnp.float32), (jnp.moveaxis(af, 1, 0), jnp.moveaxis(bf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype), hs_final
