"""CI wall-time regression gate for the round benchmark.

Compares a fresh ``round_bench`` run against the committed
``BENCH_round.json`` baseline and FAILS (exit 1) if ``us_per_round`` for any
gated cell -- (algo in {gpdmm, agpdmm, scaffold, fedavg}, variant=plain,
path=arena), per problem shape / oracle / driver -- regresses more than
``--max-regress`` (default 20%).  SCAFFOLD joined the gate with ISSUE 3 (the
paper's primary baseline); AGPDMM and FedAvg joined with ISSUE 4, so every
algorithm the paper's figures compare now has its arena hot path guarded --
a regression in any one of them would silently skew the cross-algorithm
wall-time story.  ISSUE 5 adds the (gpdmm, partial, arena_cohort) cell: the
cohort-sampled partial-participation round whose whole point is being
cheaper than the masked full-population round.  ISSUE 7 adds the
(gpdmm, stale, arena) cell: the bounded-staleness async round (delay
schedule + fused stale_mix admission), guarded so the robustness layer
never silently taxes the async hot path.

Hardware neutrality: the committed baseline was produced on a different
machine than the CI runner, and absolute wall times swing with runner
class / load.  When the same-run pytree sibling cell (path=pytree,
oracle=tree, same problem/variant/driver/K) exists in both files, the gate
therefore compares the NORMALISED time arena/pytree against the baseline's
same ratio -- a slow runner slows both paths, the ratio doesn't move;
only a change that makes the gated hot path slower *relative to the
reference path it must beat* trips the gate.  Cells without a sibling fall
back to the absolute comparison.

Records are matched on the full (problem, algo, variant, path, oracle,
driver) key at the same K.  NEW cells (fresh-only) are reported but never
fail the gate, so adding shapes doesn't break CI; a GATED baseline cell
missing from the fresh run DOES fail -- otherwise dropping a hot path from
the bench would silently disable its guard.

    PYTHONPATH=src:. python benchmarks/round_bench.py --out BENCH_round_fresh.json
    PYTHONPATH=src:. python benchmarks/regression_gate.py \
        --baseline BENCH_round.json --fresh BENCH_round_fresh.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

GATED = [
    {"algo": "gpdmm", "variant": "plain", "path": "arena"},
    {"algo": "agpdmm", "variant": "plain", "path": "arena"},
    {"algo": "scaffold", "variant": "plain", "path": "arena"},
    {"algo": "fedavg", "variant": "plain", "path": "arena"},
    # ISSUE 5: the cohort-sampled partial-participation round (gather ->
    # fused cohort inner loop -> scatter); normalised by the same-run pytree
    # partial sibling like every arena cell
    {"algo": "gpdmm", "variant": "partial", "path": "arena_cohort"},
    # ISSUE 7: the bounded-staleness async round (delay schedule, fused
    # stale_mix admission at max_staleness=2); normalised by its same-run
    # pytree stale sibling like every arena cell
    {"algo": "gpdmm", "variant": "stale", "path": "arena"},
    # ISSUE 8: the host-resident population-store round (host gather ->
    # staged device body -> host scatter + incremental f64 mean); a pytree
    # sibling exists for the same shape, so this normalises like the rest
    {"algo": "gpdmm", "variant": "partial", "path": "popstore"},
    # ISSUE 10: the fused residual_norm kernel (the early-termination
    # criterion: per-row dx2/x2 in one pass over the state arena + its
    # snapshot) -- every tol > 0 round pays it, so a regression taxes the
    # whole early-exit path.  Normalised by the same-run screen_uplink
    # kernel cell (see _sibling_key).
    {"algo": "residual_norm", "variant": "plain", "path": "kernel_xla"},
]
# "topology" (ISSUE 4) distinguishes the gpdmm_graph rows (star/ring/
# complete at the same problem shape); records predating it key as None
KEY_FIELDS = ("problem", "algo", "variant", "path", "oracle", "driver", "K",
              "topology")


def _is_gated(rec) -> bool:
    return any(all(rec.get(k) == v for k, v in cell.items()) for cell in GATED)


def _index(payload):
    out = {}
    for rec in payload["trajectory"]:
        # pre-ISSUE-2 baselines lack oracle/driver: default to the cell the
        # old bench actually measured
        key = tuple(rec.get(f, {"oracle": "native", "driver": "per_round"}.get(f))
                    for f in KEY_FIELDS)
        out[key] = rec
    return out


def _sibling_key(key):
    """The same-run reference cell a gated cell is normalised by: the pytree
    round for arena cells; for the residual_norm kernel cell (no pytree
    sibling exists) the same-run screen_uplink kernel -- another single-pass
    reduction over the same (m, width) arena shape, so the ratio stays
    hardware-neutral."""
    problem, algo, variant, _path, _oracle, driver, K, topology = key
    if algo == "residual_norm":
        return (problem, "screen_uplink", "plain", "kernel_xla", "native",
                "per_call", 0, None)
    return (problem, algo, variant, "pytree", "tree", driver, K, topology)


def gate(baseline_path: str, fresh_path: str, max_regress: float) -> int:
    base = _index(json.loads(pathlib.Path(baseline_path).read_text()))
    fresh = _index(json.loads(pathlib.Path(fresh_path).read_text()))
    failures, checked = [], 0
    for key, rec in sorted(fresh.items()):
        if not _is_gated(rec):
            continue
        ref = base.get(key)
        if ref is None:
            print(f"[gate] NEW cell (no baseline, skipped): {key}")
            continue
        checked += 1
        sib = _sibling_key(key)
        if sib in fresh and sib in base:
            # hardware-neutral: arena time normalised by the same run's
            # pytree sibling, compared against the baseline's same ratio
            got = rec["us_per_round"] / max(fresh[sib]["us_per_round"], 1e-9)
            want = ref["us_per_round"] / max(base[sib]["us_per_round"], 1e-9)
            unit = "x pytree" if sib[3] == "pytree" else f"x {sib[1]}"
        else:
            got, want = rec["us_per_round"], ref["us_per_round"]
            unit = "us/round (absolute: no pytree sibling)"
        bad = got > want * (1.0 + max_regress)
        status = "FAIL" if bad else "ok"
        print(f"[gate] {status} {key}: {want:.3f} -> {got:.3f} {unit} "
              f"(x{got / max(want, 1e-9):.2f} of baseline)")
        if bad:
            failures.append(key)
    for key in sorted(set(base) - set(fresh)):
        if _is_gated(base[key]):
            # a vanished gated cell means the guard went inert (e.g. the
            # bench dropped the algo): that FAILS -- otherwise removing the
            # hot path from the bench would silently disable its gate
            print(f"[gate] FAIL gated baseline cell missing from fresh run: {key}")
            failures.append(key)
    print(f"[gate] {checked} gated cells checked, {len(failures)} regression(s)/"
          f"missing (threshold +{max_regress:.0%})")
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_round.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="allowed fractional us_per_round increase")
    args = ap.parse_args()
    sys.exit(gate(args.baseline, args.fresh, args.max_regress))


if __name__ == "__main__":
    main()
