"""Exact PDMM on the centralised (star) graph, eqs. (14)-(15):

    clients:  x_i^{r+1}       = argmin_x f_i(x) + rho/2 ||x - x_s^r + lam_{s|i}^r/rho||^2
              lam_{i|s}^{r+1} = rho (x_s^r - x_i^{r+1}) - lam_{s|i}^r
    server:   x_s^{r+1}       = mean_i (x_i^{r+1} - lam_{i|s}^{r+1}/rho)
              lam_{s|i}^{r+1} = rho (x_i^{r+1} - x_s^{r+1}) - lam_{i|s}^{r+1}

Requires a prox oracle (closed-form for the paper's least-squares problems --
see ``core.quadratic``).  The FedSplit equivalence (rho = 1/gamma,
z_{s|i} = x_s - gamma lam_{s|i}) is asserted in tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import tree_util as T
from repro.core.api import FedOpt, resolved_rho


def _round(cfg: FederatedConfig, state, prox_fn, batch=None, per_step_batches=False):
    del batch, per_step_batches
    rho = resolved_rho(cfg)
    x_s, lam_s = state["x_s"], state["lam_s"]
    m = jax.tree.leaves(lam_s)[0].shape[0]
    x_s_b = T.tree_broadcast(x_s, m)

    v = T.tmap(lambda s, l: s - l / rho, x_s_b, lam_s)
    x_i = prox_fn(v, rho)  # prox_fn maps the stacked client dim itself
    lam_is = T.tmap(lambda s, x, l: rho * (s - x) - l, x_s_b, x_i, lam_s)
    uplink = T.tmap(lambda x, l: x - l / rho, x_i, lam_is)
    x_s_new = T.tree_client_mean(uplink)
    x_s_new_b = T.tree_broadcast(x_s_new, m)
    lam_s_new = T.tmap(lambda x, s, l: rho * (x - s) - l, x_i, x_s_new_b, lam_is)

    new_state = {"x_s": x_s_new, "lam_s": lam_s_new, "round": state["round"] + 1}
    metrics = {"lam_sum_norm": T.tree_norm(T.tree_client_sum(lam_s_new))}
    return new_state, metrics


def make_exact(cfg: FederatedConfig) -> FedOpt:
    def init(params, m):
        return {
            "x_s": params,
            "lam_s": T.tree_zeros_like(T.tree_broadcast(params, m)),
            "round": jnp.zeros((), jnp.int32),
        }

    return FedOpt(
        name="pdmm_exact",
        init=init,
        round=partial(_round, cfg),
        server_params=lambda s: s["x_s"],
    )
