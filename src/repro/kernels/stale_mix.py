"""Pallas TPU kernel for the fused stale-uplink admission mix (ISSUE 7).

ONE pass over the uplink, cache, and stale-buffer arenas emits, per client
row,

  * the MIXED contribution row that enters the server mean:
    ``base = fresh ? uplink : cache`` (today's masked select, bit-exact),
    then ``base + w * (stale - base)`` on the rows whose stale uplink is
    admitted this round (``w = gamma**lateness > 0``), and
  * the updated stale buffer: delayed clients' uplink rows stored in their
    (free) slot, every other slot carried through.

All the per-client admission bookkeeping (occupancy, age, lateness,
deadline) is layout-independent integer math done OUTSIDE the kernel
(``core.staleness``); the kernel only consumes three per-client scalars --
``fresh``, ``store``, ``w`` -- broadcast to ``(m, LANES)`` f32 rows so each
grid step reads them as ``(1, LANES)`` VMEM blocks and broadcasts them
against the ``(block, LANES)`` data tiles (no SMEM scalar plumbing).

The admitted-mix guard ``where(w > 0, base + w*(stale - base), base)`` is
load-bearing for the synchronous collapse: at ``w == 0`` the select returns
``base`` BITWISE (no ``-0.0 + 0.0`` sign flips, no ``0 * inf`` NaNs from a
non-finite buffered row), which is what makes ``max_staleness=0`` collapse
to today's masked round exactly.

Layout: grid ``(m, rows_p // block)`` over the ``(m, rows_p, LANES)`` tiled
views; outputs are written block-by-block (no accumulation).  ``cache`` is
either the ``(width,)`` broadcast server row (SCAFFOLD's zero-delta
baseline) or the ``(m, width)`` per-client ``u_hat`` cache.  Zero padding
is preserved: pad columns are zero on every operand, and both the select
and the mix map 0 -> 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_update import LANES, assert_vmem_budget
from repro.kernels.round_tail import _resolve_block, _tile


def _stale_mix_kernel(u_ref, c_ref, b_ref, f_ref, s_ref, w_ref,
                      mix_ref, bo_ref, *, per_row: bool):
    u = u_ref[0].astype(jnp.float32)  # (br, LANES)
    c = (c_ref[0] if per_row else c_ref[...]).astype(jnp.float32)
    buf = b_ref[0].astype(jnp.float32)
    fresh = f_ref[0]  # (LANES,) constant row, broadcasts over br
    w = w_ref[0]
    base = jnp.where(fresh > 0.5, u, c)
    mix = jnp.where(w > 0.0, base + w * (buf - base), base)
    mix_ref[0] = mix.astype(mix_ref.dtype)
    bo_ref[0] = jnp.where(s_ref[0] > 0.5, u, buf).astype(bo_ref.dtype)


def stale_mix_pallas(uplink, cache, buf, fresh, store, w, *, block=None,
                     interpret: bool = False):
    """uplink/buf: (m, width) arenas; cache: (width,) broadcast row or
    (m, width); fresh/store: (m,) bool; w: (m,) f32 admission weights.
    Returns ``(mixed (m, width), buf_new (m, width))``."""
    m, width = uplink.shape
    per_row = cache.ndim == 2
    pad = (-width) % LANES
    if pad:
        # zero on every operand: the select and the mix both map 0 -> 0,
        # so padded and unpadded widths mix identically
        uplink = jnp.pad(uplink, ((0, 0), (0, pad)))
        buf = jnp.pad(buf, ((0, 0), (0, pad)))
        cache = jnp.pad(cache, ((0, 0), (0, pad)) if per_row else ((0, pad),))
        width += pad
    br = _resolve_block(block, width // LANES)
    assert_vmem_budget(5, br)
    ut, _, rows_p = _tile(uplink, br)
    ct, _, _ = _tile(cache, br)
    bt, _, _ = _tile(buf, br)
    const = lambda v: jnp.broadcast_to(  # noqa: E731
        v.astype(jnp.float32)[:, None], (m, LANES))
    client_bs = pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0))
    cache_bs = (client_bs if per_row
                else pl.BlockSpec((br, LANES), lambda i, j: (j, 0)))
    scalar_bs = pl.BlockSpec((1, LANES), lambda i, j: (i, 0))
    mixed, buf_new = pl.pallas_call(
        functools.partial(_stale_mix_kernel, per_row=per_row),
        grid=(m, rows_p // br),
        in_specs=[client_bs, cache_bs, client_bs,
                  scalar_bs, scalar_bs, scalar_bs],
        out_specs=(client_bs, client_bs),
        out_shape=(jax.ShapeDtypeStruct((m, rows_p, LANES), uplink.dtype),
                   jax.ShapeDtypeStruct((m, rows_p, LANES), buf.dtype)),
        interpret=interpret,
    )(ut, ct, bt, const(fresh), const(store), const(w))
    w_out = width - pad
    untile = lambda t: t.reshape(m, rows_p * LANES)[:, :w_out]  # noqa: E731
    return untile(mixed), untile(buf_new)
