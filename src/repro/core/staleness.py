"""Bounded-staleness async round engine (ISSUE 7): real semantics for the
``delay`` fault class.

PR 6 mapped every delayed uplink onto total silence -- the finished work
was thrown away.  Stochastic/asynchronous PDMM converges under stale
updates with randomly inactive nodes (Sherson et al., arXiv:1706.02654;
Zhang & Heusdens, arXiv:1702.00841), so this module keeps the delayed
client's uplink IN FLIGHT instead: stored into a stale-buffer arena the
round it was produced, delivered ``s`` rounds later, and admitted into the
server mean with a staleness-discounted weight.

State (one in-flight slot per client, rides inside the federated state so
it checkpoints/donates/resumes with everything else):

    stale_buf  (m, width) | stacked pytree -- the buffered uplink rows
    stale_age  (m,) int32 -- rounds the slot has been in flight; -1 = empty
    stale_lat  (m,) int32 -- the slot's drawn lateness; 0 = empty

Per-round schedule (pure integer bookkeeping, identical on the arena and
pytree paths):

    occ      = age >= 0                    slot holds an in-flight row
    age'     = occ ? age + 1 : age         one more round in flight
    arriving = occ & (age' >= lat)         the row lands THIS round
    admit    = arriving & (lat <= max_staleness)
    w        = admit ? stale_gamma**lat : 0
    store    = delayed & (~occ | arriving) one slot: busy means the new
                                           delayed uplink is dropped (the
                                           client degrades to silence)

A row sent at round r with lateness 1 therefore arrives at round r + 1
with weight ``stale_gamma**1``.  Deadline demotion happens earlier, at
plan time (``core.faults.plan``): a drawn lateness past ``deadline`` never
reaches this module.  The delayed client's LOCAL state (primal carry,
control variate) keeps the silence contract -- only the uplink travels
late -- so the round tails exclude delayed rows from the fresh mask and the
arriving row mixes into the server's cached view on landing.

Synchronous collapse: with ``max_staleness=0`` no arrival is ever admitted
(lateness >= 1), every mixed row IS the masked select (``ops.stale_mix``
guards ``w > 0`` bitwise), and delayed clients are excluded from the fresh
mask exactly as the silence contract excludes them -- the async round is
bit-identical to today's synchronous masked round (tests/test_staleness.py
pins this for all four algorithms on both layouts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import arena as arena_mod
from repro.core import tree_util as T
from repro.core.faults import FaultPlan, async_on  # noqa: F401  (re-export)
from repro.kernels import ops

# state keys this engine owns (compared-ignored by the collapse tests,
# merged into every algorithm's init when async_on)
STATE_KEYS = ("stale_buf", "stale_age", "stale_lat")


def init_arena(spec, m: int) -> dict:
    """Fresh stale-slot state for the packed arena layout."""
    return {
        "stale_buf": arena_mod.zeros(spec, m),
        "stale_age": jnp.full((m,), -1, jnp.int32),
        "stale_lat": jnp.zeros((m,), jnp.int32),
    }


def init_tree(params, m: int) -> dict:
    """Fresh stale-slot state for the per-leaf pytree layout."""
    return {
        "stale_buf": T.tree_zeros_like(T.tree_broadcast(params, m)),
        "stale_age": jnp.full((m,), -1, jnp.int32),
        "stale_lat": jnp.zeros((m,), jnp.int32),
    }


def _schedule(cfg: FederatedConfig, fplan: FaultPlan, age, lat):
    """The round's slot bookkeeping; see the module docstring for the
    algebra.  Returns (store, w, arriving, admit, age_new, lat_new)."""
    occ = age >= 0
    age_t = jnp.where(occ, age + 1, age)
    arriving = occ & (age_t >= lat)
    admit = arriving & (lat <= cfg.max_staleness)
    w = jnp.where(
        admit,
        jnp.float32(cfg.stale_gamma) ** lat.astype(jnp.float32),
        jnp.float32(0.0))
    free = ~occ | arriving
    store = fplan.delayed & free
    age_new = jnp.where(store, 0, jnp.where(arriving, -1, age_t))
    lat_new = jnp.where(store, fplan.lateness, jnp.where(arriving, 0, lat))
    return store, w, arriving, admit, age_new, lat_new


def fresh_mask(mask, fplan: FaultPlan):
    """The round's FRESH-uplink mask: the combined participation/fault/
    screen mask with delayed clients excluded (their uplink is in flight,
    not in this round's mean)."""
    alive = ~fplan.delayed
    return alive if mask is None else mask & alive


def stale_metrics(store, arriving, admit) -> dict:
    """Stale-slot counters (f32 scalars, scan-stackable)."""
    f32 = jnp.float32
    return {
        "stale_buffered": jnp.sum(store.astype(f32)),
        "stale_admitted": jnp.sum(admit.astype(f32)),
        "stale_dropped": jnp.sum((arriving & ~admit).astype(f32)),
    }


def step_arena(cfg: FederatedConfig, fplan: FaultPlan, uplink, cache, mask,
               state):
    """One async round step over the packed arena.

    ``uplink``: the (m, width) transmitted rows (post EF21/injection);
    ``cache``: the (m, width) u_hat cache or the (width,) server baseline
    row (SCAFFOLD/zero-delta); ``mask``: the combined silence/screen mask.
    Returns ``(mixed, fresh, state_updates, metrics)`` -- ``mixed`` is what
    enters the server mean AND the new cache, ``fresh`` the effective
    active mask the callers use for their carry selects and drift metrics.
    The mix reads the OLD buffer, so a row arriving this round and a new
    store into the same slot compose in one pass (``ops.stale_mix``)."""
    age, lat, buf = state["stale_age"], state["stale_lat"], state["stale_buf"]
    store, w, arriving, admit, age_new, lat_new = _schedule(cfg, fplan, age, lat)
    fresh = fresh_mask(mask, fplan)
    mixed, buf_new = ops.stale_mix(uplink, cache, buf, fresh, store, w)
    updates = {"stale_buf": buf_new, "stale_age": age_new,
               "stale_lat": lat_new}
    return mixed, fresh, updates, stale_metrics(store, arriving, admit)


def step_tree(cfg: FederatedConfig, fplan: FaultPlan, uplink, cache, mask,
              state):
    """``step_arena`` over stacked client pytrees (leading dim m on every
    leaf); the schedule is shared, the mix runs per leaf with the same
    f32-and-guard arithmetic as ``ops.stale_mix``'s xla path."""
    age, lat, buf = state["stale_age"], state["stale_lat"], state["stale_buf"]
    store, w, arriving, admit, age_new, lat_new = _schedule(cfg, fplan, age, lat)
    fresh = fresh_mask(mask, fplan)
    base = T.tree_select(fresh, uplink, cache)

    def mix_leaf(b, s):
        shape = (-1,) + (1,) * (b.ndim - 1)
        wk = w.reshape(shape)
        bf = b.astype(jnp.float32)
        mixf = bf + wk * (s.astype(jnp.float32) - bf)
        return jnp.where(wk > 0, mixf.astype(b.dtype), b)

    mixed = T.tmap(mix_leaf, base, buf)
    buf_new = T.tree_select(store, uplink, buf)
    updates = {"stale_buf": buf_new, "stale_age": age_new,
               "stale_lat": lat_new}
    return mixed, fresh, updates, stale_metrics(store, arriving, admit)
