"""Local optimizers (substrate -- no optax in this environment)."""
from repro.optim.optimizers import adam, sgd, apply_updates, clip_by_global_norm
from repro.optim.schedules import constant, cosine, linear_warmup

__all__ = [
    "adam",
    "sgd",
    "apply_updates",
    "clip_by_global_norm",
    "constant",
    "cosine",
    "linear_warmup",
]
