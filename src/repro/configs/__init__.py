"""Architecture/shape config registry: ``get_arch("llama3-8b")``, ``get_shape("train_4k")``."""
from __future__ import annotations

from repro.configs.base import ArchConfig, FederatedConfig, ShapeConfig, SHAPES, validate

from repro.configs.rwkv6_1p6b import CONFIG as _rwkv6
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2
from repro.configs.llama3_8b import CONFIG as _llama3
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.llama4_maverick_400b import CONFIG as _llama4
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.yi_34b import CONFIG as _yi

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        _rwkv6,
        _rgemma,
        _dsv2,
        _llama3,
        _olmo,
        _stablelm,
        _llama4,
        _llava,
        _musicgen,
        _yi,
    )
}

for _c in ARCHS.values():
    validate(_c)


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ArchConfig",
    "FederatedConfig",
    "ShapeConfig",
    "ARCHS",
    "SHAPES",
    "get_arch",
    "get_shape",
    "validate",
]
