"""Federated LM training launcher (runs for real on whatever devices exist).

    PYTHONPATH=src python -m repro.launch.train \
        --arch olmo-1b --reduced --steps 50 --algorithm gpdmm --k 4

On CPU this drives the reduced configs (the ~100M-scale end-to-end example
lives in examples/train_federated_lm.py); on a real TPU mesh the same code
path drives the full configs via --mesh production.

Checkpointing: ``--ckpt-dir`` saves the FULL federated state (every arena
buffer, the server pytree, and the round counter) at the end of the run;
``--resume`` restores the latest checkpoint and continues the SAME
trajectory -- the synthetic data stream is re-keyed from the restored round
counter, so save-at-r + resume equals the uninterrupted run at f32
(tests/test_cohort.py pins this).  Partial-participation runs on the cohort
engine (``core.api.use_cohort``) feed cohort-sized batches from
``data.synthetic.cohort_lm_batches`` -- data is generated only for the
clients that actually fire each round.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_arch
from repro.configs.base import FederatedConfig, ShapeConfig
from repro.core import make as make_fed
from repro.core import make_scan_rounds
from repro.core.api import use_arena, use_cohort
from repro.data.synthetic import cohort_lm_batches, lm_batches
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import build_train_step
from repro.models import build as build_model


def run(
    arch: str,
    *,
    reduced: bool = True,
    steps: int = 20,
    algorithm: str = "gpdmm",
    k: int = 2,
    eta: float = 0.3,
    m: int = 4,
    per_client_batch: int = 4,
    seq_len: int = 128,
    seed: int = 0,
    ckpt_dir: str | None = None,
    resume: bool = False,
    log_every: int = 5,
    uplink_bits: int | None = None,
    participation: float = 1.0,
    rounds_per_call: int = 1,
):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(
        cfg,
        fed=dataclasses.replace(
            cfg.fed, algorithm=algorithm, inner_steps=k, eta=eta, num_clients=m,
            layout="client_axis", uplink_bits=uplink_bits, participation=participation,
            rounds_per_call=rounds_per_call,
        ),
    )
    model = build_model(cfg)
    fed = make_fed(cfg.fed)

    key = jax.random.key(seed)
    params = model.init(key)

    # fingerprint saved with every checkpoint and checked on --resume: a
    # restored state only continues the SAME trajectory if the run that
    # wrote it used the same optimiser/data hyper-parameters
    run_config = {
        "arch": arch, "reduced": reduced, "algorithm": algorithm, "k": k,
        "eta": eta, "m": m, "per_client_batch": per_client_batch,
        "seq_len": seq_len, "seed": seed, "uplink_bits": uplink_bits,
        "participation": participation,
    }

    start = 0
    if resume:
        if not ckpt_dir:
            raise ValueError("--resume needs --ckpt-dir")
        last = ckpt.latest_step(ckpt_dir)
        if last is None:
            raise FileNotFoundError(f"--resume: no checkpoints under {ckpt_dir}")
        payload = ckpt.load(ckpt_dir, last)
        if "fed_state" not in payload:
            raise ValueError(
                f"checkpoint step {last} under {ckpt_dir} has no 'fed_state' "
                "(written by a pre-ISSUE-5 launcher that saved only server "
                "params); it cannot resume a trajectory -- retrain, or load "
                "payload['server'] manually for serving")
        saved_cfg = payload.get("config", {})
        diffs = {kk: (saved_cfg.get(kk), vv) for kk, vv in run_config.items()
                 if saved_cfg.get(kk) != vv}
        if diffs:
            raise ValueError(
                f"--resume config mismatch vs checkpoint (saved, requested): "
                f"{diffs}; resuming would NOT continue the same trajectory")
        # the FULL federated state (arena buffers + server pytree + round
        # counter) resumes; the data stream re-keys from the round counter,
        # so the continuation is the uninterrupted trajectory.  fed.init is
        # skipped entirely -- at population scale the (m, width) arena
        # buffers it would broadcast just to be overwritten are the bulk of
        # the job's memory
        state = payload["fed_state"]
        start = int(payload["round"])
        print(f"[train] resumed full fed state at round {start} from {ckpt_dir}")
    else:
        state = fed.init(params, m)
    if start >= steps:
        print(f"[train] checkpoint already at round {start} >= steps {steps}; "
              f"nothing to do")
        return []

    def client_grad(p, b):
        return jax.grad(lambda q: model.loss(q, b)[0])(p)

    # donate the round state: the arena/round update aliases its input
    # buffers in place instead of holding two copies of the (m, params) state.
    # With rounds_per_call > 1 the scan driver runs R full rounds per
    # dispatch over a leading-R batch stack (metrics come back stacked).
    R = max(1, rounds_per_call)
    if R > 1:
        scan_rounds = make_scan_rounds(fed, client_grad)

        @partial(jax.jit, donate_argnums=(0,))
        def step_fn(state, batches):
            return scan_rounds(state, batches)
    else:
        @partial(jax.jit, donate_argnums=(0,))
        def step_fn(state, batch):
            return fed.round(state, client_grad, batch)

    @jax.jit
    def eval_loss(params, batch):
        # server-model loss averaged over the same stacked batch
        losses = jax.vmap(lambda b: model.loss(params, b)[0])(batch)
        return losses.mean()

    history = []
    # cohort engine active -> feed cohort-sized batches (rows = the round's
    # active clients, sorted by id; the engine's pass-through recognises the
    # cohort-sized leading dim) so data is never generated for silent clients
    cohort = use_cohort(cfg.fed, m) and use_arena(cfg.fed, params)
    n_rounds = steps - start
    data_key = jax.random.key(seed + 1)
    if cohort:
        data = cohort_lm_batches(
            data_key, n_rounds, m, per_client_batch, seq_len, cfg.vocab_size,
            participation=participation, fed_seed=cfg.fed.seed, start=start,
        )
    else:
        data = lm_batches(data_key, n_rounds, m, per_client_batch, seq_len,
                          cfg.vocab_size, start=start)
    # cohort batches only cover the round's active clients, so evaluating
    # the server loss on them would track the cohort's topics, not the
    # population objective (incomparable across participation settings):
    # hold out ONE fixed full-population batch for the logged loss instead
    eval_batch = None
    if cohort:
        eval_batch = next(lm_batches(jax.random.key(seed + 2), 1, m,
                                     per_client_batch, seq_len, cfg.vocab_size))
    t0 = time.time()
    def metrics_row(metrics):
        # last-round values, whether stacked (R,) from the scan or scalars
        return {kk: float(jnp.asarray(v).reshape(-1)[-1])
                for kk, v in metrics.items() if kk != "trace"}

    if R > 1:
        # tail shorter than R (steps % R != 0) falls back to jitted,
        # donated per-round dispatches -- same step semantics, no eager path
        round_fn = jax.jit(
            lambda s, b: fed.round(s, client_grad, b), donate_argnums=(0,))
        pending = []
        i = start
        last = metrics = None
        for batch in data:
            pending.append(batch)
            last = batch
            if len(pending) < R:
                continue
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *pending)
            pending = []
            state, metrics = step_fn(state, stacked)  # metrics stacked (R,)
            i += R
            if (i - R) // max(1, log_every) != i // max(1, log_every):
                eb = eval_batch if eval_batch is not None else last
                row = {"round": i,
                       "server_loss": float(eval_loss(fed.server_params(state), eb)),
                       **metrics_row(metrics)}
                history.append(row)
                print(f"[train] {json.dumps(row)}", flush=True)
        for batch in pending:
            state, metrics = round_fn(state, batch)
            i += 1
        if last is not None and (not history or history[-1]["round"] != i):
            # always log the FINAL state (the R=1 path's i == steps-1 row)
            eb = eval_batch if eval_batch is not None else last
            row = {"round": i,
                   "server_loss": float(eval_loss(fed.server_params(state), eb)),
                   **(metrics_row(metrics) if metrics is not None else {})}
            history.append(row)
            print(f"[train] {json.dumps(row)}", flush=True)
    else:
        for i, batch in enumerate(data, start=start):
            state, metrics = step_fn(state, batch)
            if (i - start) % log_every == 0 or i == steps - 1:
                eb = eval_batch if eval_batch is not None else batch
                loss = float(eval_loss(fed.server_params(state), eb))
                row = {"round": i, "server_loss": loss,
                       **{kk: float(v) for kk, v in metrics.items() if kk != "trace"}}
                history.append(row)
                print(f"[train] {json.dumps(row)}", flush=True)
    dt = time.time() - t0
    print(f"[train] {n_rounds} rounds (K={k}, m={m}) in {dt:.1f}s; algo={algorithm}, "
          f"rounds_per_call={R}" + (", cohort batches" if cohort else ""))

    if ckpt_dir:
        # the FULL fed state (arena buffers, server pytree, round counter),
        # not just server params: `load` + --resume continues the exact
        # trajectory.  "server" stays for serve-side consumers.
        done = int(state["round"])
        ckpt.save(ckpt_dir, done, {
            "server": fed.server_params(state),
            "fed_state": state,
            "round": done,
            "config": run_config,
        })
        print(f"[train] full-state checkpoint (round {done}) saved to {ckpt_dir}")
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--algorithm", default="gpdmm",
                    choices=["gpdmm", "agpdmm", "scaffold", "fedavg", "fedsplit"])
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--eta", type=float, default=0.3)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest full-state checkpoint from "
                         "--ckpt-dir and continue the same trajectory")
    ap.add_argument("--uplink-bits", type=int, default=None,
                    help="EF21 delta-quantised uplink (beyond paper)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients active per round (async PDMM; "
                         "< 1 runs the cohort-sampled round engine)")
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="rounds per jitted dispatch (lax.scan round batching)")
    args = ap.parse_args()
    run(
        args.arch, reduced=args.reduced, steps=args.steps, algorithm=args.algorithm,
        k=args.k, eta=args.eta, m=args.clients, per_client_batch=args.batch,
        seq_len=args.seq, seed=args.seed, ckpt_dir=args.ckpt_dir, resume=args.resume,
        uplink_bits=args.uplink_bits, participation=args.participation,
        rounds_per_call=args.rounds_per_call,
    )


if __name__ == "__main__":
    main()
