"""Shared layers: norms, rotary embeddings, gated MLPs, init helpers.

Every init function returns ``(params, specs)`` where ``specs`` mirrors the
param pytree with tuples of *logical* axis names per dim.  Logical axes are
mapped to mesh axes by ``repro.sharding.specs``:

    embed   -- model dimension rows (FSDP-shardable)
    ff      -- feed-forward hidden
    heads   -- attention heads (q)
    kv      -- kv heads
    vocab   -- vocabulary
    experts -- MoE experts
    rnn     -- recurrent width
    None    -- replicated
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = Any
Specs = Any


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, axes, dtype, scale: Optional[float] = None):
    """Normal(0, scale) init; default scale = 1/sqrt(fan_in)."""
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    w = (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)
    return w, tuple(axes)


def zeros_init(shape, axes, dtype):
    return jnp.zeros(shape, dtype=dtype), tuple(axes)


def const_init(value, shape, axes, dtype):
    return jnp.full(shape, value, dtype=dtype), tuple(axes)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(kind: str, dim: int):
    """Norm params are always f32."""
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), jnp.float32)}, {"scale": ("embed",)}
    if kind == "layernorm":
        return (
            {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)},
            {"scale": ("embed",), "bias": ("embed",)},
        )
    if kind == "nonparam_ln":  # OLMo: no learnable affine
        return {}, {}
    raise ValueError(kind)


def norm_apply(kind: str, params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        y = y * params["scale"]
    elif kind in ("layernorm", "nonparam_ln"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * params["scale"] + params["bias"]
    else:
        raise ValueError(kind)
    return y.astype(dt)


def groupnorm_heads(x, n_heads, eps: float = 64e-5):
    """Per-head LayerNorm used on the RWKV wkv output. x: (..., H*hd)."""
    dt = x.dtype
    shp = x.shape
    xf = x.astype(jnp.float32).reshape(*shp[:-1], n_heads, shp[-1] // n_heads)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.reshape(shp).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions (...,) -> (cos, sin) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def rope_apply(x, cos, sin):
    """x (..., S, H, hd) with cos/sin (..., S, hd//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over head axis
    s = sin[..., None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    wi, si = dense_init(k1, (d_model, d_ff), ("embed", "ff"), dtype)
    wg, sg = dense_init(k2, (d_model, d_ff), ("embed", "ff"), dtype)
    wo, so = dense_init(k3, (d_ff, d_model), ("ff", "embed"), dtype)
    return {"wi": wi, "wg": wg, "wo": wo}, {"wi": si, "wg": sg, "wo": so}


def mlp_apply(params, x, act: str = "silu"):
    h = x @ params["wi"]
    g = x @ params["wg"]
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return (h * g) @ params["wo"]


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d_model, dtype):
    w, s = dense_init(key, (vocab, d_model), ("vocab", "embed"), dtype, scale=1.0)
    return {"w": w}, {"w": s}


def embed_apply(params, tokens):
    return jnp.take(params["w"], tokens, axis=0)


def head_apply(embed_or_head_w, x):
    """x (..., D) @ W^T -> logits (..., V). f32 logits for a stable softmax."""
    return (x @ embed_or_head_w.T.astype(x.dtype)).astype(jnp.float32)
