"""Benchmark harness: one module per paper figure/table + kernel micro-benches
and the theory-rate instrument.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig1
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of: fig1,fig2,tab1,kernels,theory,beyond")
    ap.add_argument("--fast", action="store_true", help="trim round counts")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from benchmarks import (beyond_paper, fig1_fedsplit, fig2_lsq,
                            kernels_bench, tab1_softmax, theory_rate)

    jobs = {
        "fig1": lambda: fig1_fedsplit.run(),
        "fig2": lambda: fig2_lsq.run(rounds=60 if args.fast else 200),
        "tab1": lambda: tab1_softmax.run(rounds=20 if args.fast else 60,
                                         ks=(1, 5, 40) if args.fast else (1, 5, 10, 30, 40)),
        "kernels": kernels_bench.run,
        "theory": theory_rate.run,
        "beyond": beyond_paper.run,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, job in jobs.items():
        if want and name not in want:
            continue
        t0 = time.time()
        try:
            job()
            print(f"# [{name}] done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
