"""Pallas TPU kernel for the RWKV-6 chunked recurrence (data-dependent decay).

TPU adaptation of the (GPU, CUDA) reference: instead of one thread-block per
(batch, head) scanning time serially, the grid is (B*H, S/C) with the chunk
axis iterated sequentially (TPU grids execute in order); the running state
S in R^{KxV} lives in a VMEM scratch buffer across chunk steps, and the
intra-chunk interactions become (C x C) MXU matmuls -- the same
chunk-parallel decomposition as ``ops._wkv6_chunked_xla`` (the oracle is
``ref.wkv6_ref``):

    y_t = r_t exp(la_{t-1}) S_chunk0
        + sum_{tau<t} [r_t . k_tau . exp(la_{t-1}-la_tau)] v_tau
        + (r_t . u . k_t) v_t
    S  <- exp(la_C) S + (k exp(la_C - la))^T v

Block shapes: (1, C, K) tiles of r/k/v/w per grid step; C=64, K=V=64 keeps
every operand MXU-aligned and the scratch + operands well under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref, s_scr, *, nc: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (C, V)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)  # (1, K)
    s = s_scr[...]  # (K, V)

    C = r.shape[0]
    lw = jnp.log(jnp.maximum(w, 1e-38))
    la = jnp.cumsum(lw, axis=0)
    la_prev = la - lw

    # inter-chunk term
    y = jnp.dot(r * jnp.exp(la_prev), s, preferred_element_type=jnp.float32)
    # intra-chunk pairwise term (strict lower triangle)
    diff = la_prev[:, None, :] - la[None, :, :]  # (C, C, K)
    dec = jnp.exp(jnp.minimum(diff, 0.0))
    att = jnp.sum(r[:, None, :] * dec * k[None, :, :], axis=-1)  # (C, C)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    t_j = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    att = jnp.where(t_i > t_j, att, 0.0)
    y = y + jnp.dot(att, v, preferred_element_type=jnp.float32)
    bonus = jnp.sum(r * u * k, axis=-1, keepdims=True)  # (C, 1)
    y = y + bonus * v

    la_end = la[-1:]  # (1, K)
    dec_k = k * jnp.exp(la_end - la)  # (C, K)
    s_new = jnp.exp(la_end).T * s + jnp.dot(dec_k.T, v, preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _fin():
        sout_ref[0] = s_new


def wkv6_pallas(r, k, v, w, u, s0, *, chunk: int = 64, interpret: bool = False):
    """Shapes as ``ref.wkv6_ref``: r,k,w (B,S,H,K); v (B,S,H,V); u (H,K);
    s0 (B,H,K,V).  Returns (y (B,S,H,V), s_final (B,H,K,V) f32)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    nc = S // C
    BH = B * H

    def to_bh(a, d):
        return jnp.moveaxis(a, 2, 1).reshape(BH, S, d)

    rb, kb, wb = to_bh(r, K), to_bh(k, K), to_bh(w, K)
    vb = to_bh(v, V)
    ub = jnp.broadcast_to(u[None], (B, H, K)).reshape(BH, K)
    s0b = s0.reshape(BH, K, V)

    seq_spec = lambda d: pl.BlockSpec((1, C, d), lambda bh, c: (bh, c, 0))  # noqa: E731
    y, s_fin = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=(BH, nc),
        in_specs=[
            seq_spec(K),
            seq_spec(K),
            seq_spec(V),
            seq_spec(K),
            pl.BlockSpec((1, K), lambda bh, c: (bh, 0)),
            pl.BlockSpec((1, K, V), lambda bh, c: (bh, 0, 0)),
        ],
        out_specs=[
            seq_spec(V),
            pl.BlockSpec((1, K, V), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, V), r.dtype),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rb, kb, vb, wb, ub, s0b)

    y = jnp.moveaxis(y.reshape(B, H, S, V), 1, 2)
    return y, s_fin.reshape(B, H, K, V)
