"""Paper Fig. 1: Inexact FedSplit on least squares -- the improper client init
x^{r,0} = z_{s|i} stalls for finite K; the x_s init converges.

Reproduces the qualitative claim with the paper's geometry (m=25 clients,
A_i in R^{5000x500}).  Emits the optimality-gap trajectory endpoints.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.base import FederatedConfig
from repro.core import make, quadratic

ROUNDS = 300


def run(m=25, n=5000, d=500):
    prob = quadratic.generate(jax.random.key(0), m=m, n=n, d=d)
    x0 = jnp.zeros((prob.d,))
    eta = 1.0 / prob.L
    results = {}
    for init in ["z", "xs"]:
        for K in [1, 3]:
            cfg = FederatedConfig(algorithm="fedsplit", inner_steps=K, eta=eta,
                                  fedsplit_init=init, rho=prob.L / 10.0)
            opt = make(cfg)

            @jax.jit
            def round_fn(s):
                s, _ = opt.round(s, prob.grad, prob.batch())
                return s

            s = opt.init(x0, prob.m)
            us = time_fn(round_fn, s, iters=3, warmup=1)
            for _ in range(ROUNDS):
                s = round_fn(s)
            gap = float(prob.gap(opt.server_params(s)))
            results[(init, K)] = gap
            emit(f"fig1_inexact_fedsplit_init={init}_K={K}", us,
                 f"gap_after_{ROUNDS}_rounds={gap:.3e}")
    # the paper's claim, asserted
    assert results[("xs", 1)] < 1e-3 * max(results[("z", 1)], 1e-12), results
    assert results[("xs", 3)] < 1e-3 * max(results[("z", 3)], 1e-12), results
    return results


if __name__ == "__main__":
    run()
