"""Batched serving launcher: prefill a batch of prompts, then decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 4 --prompt-len 64 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import build as build_model


def run(arch: str, *, reduced: bool = True, batch: int = 4, prompt_len: int = 64,
        new_tokens: int = 16, seed: int = 0, greedy: bool = True):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.key(seed)
    params = model.init(key)

    if cfg.n_codebooks > 1:
        prompts = jax.random.randint(key, (batch, cfg.n_codebooks, prompt_len), 0, cfg.vocab_size)
    else:
        prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    b = {"tokens": prompts}
    if cfg.frontend == "vision":
        b["patches"] = jax.random.normal(jax.random.fold_in(key, 1),
                                         (batch, cfg.n_prefix_tokens, cfg.frontend_dim))

    prefill = jax.jit(lambda p, bb: model.prefill(p, bb, prompt_len + new_tokens + cfg.n_prefix_tokens))
    decode = jax.jit(model.decode)

    t0 = time.time()
    logits, cache = prefill(params, b)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    def pick(lg):
        if cfg.n_codebooks > 1:
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)  # (B, K)
            return nxt[:, :, None]
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]

    out_tokens = []
    t0 = time.time()
    for _ in range(new_tokens):
        nxt = pick(logits)
        logits, cache = decode(params, cache, nxt)
        out_tokens.append(nxt)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=-1)
    print(f"[serve] arch={arch} batch={batch} prompt={prompt_len} new={new_tokens}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode {t_decode/new_tokens*1e3:.2f} ms/token")
    print(f"[serve] sample generated ids: {jax.device_get(gen)[0][..., :8]}")
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    # --reduced defaults on; --full is the ONLY way to reach full-size
    # serving (a store_true flag that already defaults True is a no-op)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    run(args.arch, reduced=args.reduced, batch=args.batch,
        prompt_len=args.prompt_len, new_tokens=args.new_tokens)


if __name__ == "__main__":
    main()
