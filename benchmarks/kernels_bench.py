"""Kernel micro-benchmarks (CPU wall-time for the XLA paths; the Pallas
kernels are the TPU target and are timed in interpret mode only for
correctness, not speed).  Derived column: achieved GB/s or GFLOP/s on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import topology
from repro.kernels import ops, ref


def bench_fused_update():
    n = 4 * 1024 * 1024
    k = jax.random.key(0)
    args = [jax.random.normal(jax.random.fold_in(k, i), (n,)) for i in range(4)]
    fn = jax.jit(lambda x, g, xs, lam: ops.fused_update(x, g, xs, lam, 0.01, 2.0))
    us = time_fn(fn, *args)
    gbps = (5 * n * 4) / (us * 1e-6) / 1e9
    emit("kernel_fused_update_xla_16M", us, f"effective_GBps={gbps:.2f}")


def bench_wkv6():
    B, S, H, K, V = 2, 1024, 8, 64, 64
    key = jax.random.key(1)
    r, k_, w_ = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, K)) * 0.5 for i in range(3))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, V)) * 0.5
    w = jnp.exp(-jnp.exp(w_))
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, K)) * 0.1
    s0 = jnp.zeros((B, H, K, V))
    chunked = jax.jit(lambda *a: ops.wkv6(*a, chunk=64, impl="xla"))
    us_c = time_fn(chunked, r, k_, v, w, u, s0)
    seq = jax.jit(ref.wkv6_ref)
    us_s = time_fn(seq, r, k_, v, w, u, s0)
    emit("kernel_wkv6_chunked_xla_B2S1024", us_c, f"speedup_vs_sequential={us_s/us_c:.2f}x")
    emit("kernel_wkv6_sequential_ref_B2S1024", us_s, "baseline")


def bench_flash():
    B, S, H, Hkv, hd = 1, 2048, 8, 2, 64
    key = jax.random.key(2)
    q = jax.random.normal(key, (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd), jnp.bfloat16)
    pos = jnp.arange(S)
    flash = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, pos, pos, causal_skip=True))
    rect = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, pos, pos, causal_skip=False))
    naive = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, pos, pos))
    us_f = time_fn(flash, q, k, v)
    us_r = time_fn(rect, q, k, v)
    us_n = time_fn(naive, q, k, v)
    emit("kernel_flash_xla_causal_skip_S2048", us_f, f"vs_naive={us_n/us_f:.2f}x")
    emit("kernel_flash_xla_rectangular_S2048", us_r, f"causal_skip_saves={(us_r-us_f)/us_r:.1%}")


def bench_neighbor_reduce():
    """Graph-PDMM dual reduce + flip over a 16-node ring's edge-dual arena
    (32 directed slots x 1M lanes).  The XLA cells are the CPU reference
    (segment-sum / gather); the Pallas kernels are the TPU target and are
    validated in interpret mode by tests/test_topology.py."""
    t = topology.ring(16)
    w = 1 << 20
    k = jax.random.key(3)
    z = jax.random.normal(k, (t.n_slots, w))
    x = jax.random.normal(jax.random.fold_in(k, 1), (t.n, w))
    red = jax.jit(lambda zz: ops.neighbor_reduce(
        zz, seg=t.src, first=t.first_flags(), sgn=t.sgn, n=t.n, impl="xla"))
    us_r = time_fn(red, z)
    gbps_r = (t.n_slots + t.n) * w * 4 / (us_r * 1e-6) / 1e9
    emit("kernel_neighbor_reduce_xla_ring16_1M", us_r, f"effective_GBps={gbps_r:.2f}")
    flip = jax.jit(lambda zz, xx: ops.edge_flip(
        zz, xx, 2.0, rev=t.rev, nbr=t.nbr, sgn=t.sgn, impl="xla"))
    us_f = time_fn(flip, z, x)
    gbps_f = 3 * t.n_slots * w * 4 / (us_f * 1e-6) / 1e9
    emit("kernel_edge_flip_xla_ring16_1M", us_f, f"effective_GBps={gbps_f:.2f}")


def run():
    bench_fused_update()
    bench_wkv6()
    bench_flash()
    bench_neighbor_reduce()


if __name__ == "__main__":
    run()
