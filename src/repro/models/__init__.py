"""Model substrate: layers, attention variants, MoE, RWKV6, RG-LRU, stack."""
from repro.models.model import Model, build

__all__ = ["Model", "build"]
