"""Parses compiled/lowered HLO text for collective traffic.

``cost_analysis()`` does not expose collective bytes, so we sum operand/result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the (SPMD-partitioned) module.  Ops inside ``while``
bodies appear once in the text; callers that scan over layers extrapolate via
the 1-unit/2-unit diff (see launch.roofline).

CPU-backend caveat (recorded in EXPERIMENTS.md): XLA:CPU *promotes* bf16
all-reduces to f32 -- the HLO shows ``convert(bf16 dot) -> f32 all-reduce``
with a ``to_apply=%add.N.clone_promoted`` reducer.  On TPU those collectives
stay bf16, so parsed byte totals are an UPPER bound (up to 2x) for
bf16-activation models; A/B deltas remain comparable since both sides are
promoted identically.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# shape tokens like f32[16,128]{1,0} or bf16[2,4096] or pred[]
_SHAPE_RE = re.compile(r"\b(pred|[sub]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")
# an HLO instruction: "%name = <result-shape-or-tuple> opcode(...)"
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\]{},:#\s]*?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_kind: {"count": n, "bytes": result_bytes_total}} plus a
    "total" entry.  Bytes are the result-shape sizes (for all-gather that is
    the gathered size; for all-reduce the tensor size -- a reasonable proxy
    for per-device link traffic in a ring implementation)."""
    out: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for m in _INSTR_RE.finditer(hlo_text):
        result_shapes, kind = m.group(1), m.group(2)
        # skip the -done halves of async pairs (counted at -start)
        if hlo_text[m.start():m.end()].rstrip("(").endswith("-done"):
            continue
        b = _shape_bytes(result_shapes)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    total = {"count": sum(v["count"] for v in out.values()),
             "bytes": sum(v["bytes"] for v in out.values())}
    result = dict(out)
    result["total"] = total
    return result


def flops_and_bytes(compiled) -> tuple[float, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_est": int(ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
    }
