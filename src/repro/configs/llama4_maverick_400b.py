"""llama4-maverick-400b-a17b -- MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 128 routed experts
top-1 + 1 shared expert, MoE interleaved every other layer (the published
Maverick layout; this is what makes 128 experts x 48L land at ~400B total /
~17B active).  Text backbone only (early-fusion frontend is out of scope per
the assignment's modality carve-out).

Federated layout: ``fsdp`` with m=4 clients -- 128 full dual copies of a 400B
model cannot fit HBM; see DESIGN.md SS Arch-applicability.
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("dense", "moe"),
    attn_kind="gqa",
    rope_theta=500_000.0,
    n_experts=128,
    n_shared_experts=1,
    top_k=1,
    moe_d_ff=8192,
    norm_kind="rmsnorm",
    subquadratic=False,  # long_500k skipped (full attention; see DESIGN.md)
    fed=FederatedConfig(algorithm="gpdmm", layout="fsdp", num_clients=4),
    microbatch=64,  # grad-accum chunks per inner step (activation memory)
    source="hf:meta-llama/Llama-4-Maverick-17B-128E",
)
