"""Assembles the jit-able step functions + fully-sharded input specs for any
(architecture x input-shape x mesh) combination.

  * train  -> one federated ROUND (the paper's technique: K inexact-PDMM
              client steps + the single server all-reduce), clients mapped
              onto the mesh per FederatedConfig.layout.
  * prefill -> full-sequence forward returning last-token logits + cache.
  * decode -> one token against a seq_len-deep cache.

``build_step(arch, shape, mesh)`` returns a ``StepBundle`` with the function,
example ShapeDtypeStruct args, and in/out shardings -- consumed by both the
dry-run driver and the real train/serve launchers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import make as make_fed
from repro.core import make_scan_rounds as make_fed_scan
from repro.core.api import use_arena as fed_use_arena
from repro.core.api import use_cohort as fed_use_cohort
from repro.core.tree_util import cohort_count
from repro.models import build as build_model


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any  # None -> compiler-chosen
    meta: dict
    donate_argnums: tuple = ()  # state/cache aliasing (halves decode memory)


def _tok_dtype():
    return jnp.int32


def num_clients(cfg: ArchConfig, mesh) -> int:
    if cfg.fed.layout == "fsdp":
        return cfg.fed.num_clients or 4
    return sh.axis_size(mesh, sh.client_axes(mesh))


def batch_struct(cfg: ArchConfig, shape: ShapeConfig, *, stacked_m: Optional[int],
                 rounds: Optional[int] = None):
    """ShapeDtypeStructs for one batch (training: leading client dim m;
    ``rounds=R`` prepends the round dim of the round-batched scan driver)."""
    S = shape.seq_len
    B = shape.global_batch if stacked_m is None else shape.global_batch // stacked_m
    lead = () if stacked_m is None else (stacked_m,)
    if rounds is not None:
        lead = (rounds,) + lead
    d: dict[str, Any] = {}
    if cfg.n_codebooks > 1:
        d["tokens"] = jax.ShapeDtypeStruct((*lead, B, cfg.n_codebooks, S), _tok_dtype())
    elif cfg.frontend == "vision":
        s_text = S - cfg.n_prefix_tokens
        d["tokens"] = jax.ShapeDtypeStruct((*lead, B, s_text), _tok_dtype())
        d["patches"] = jax.ShapeDtypeStruct(
            (*lead, B, cfg.n_prefix_tokens, cfg.frontend_dim), jnp.bfloat16
        )
    else:
        d["tokens"] = jax.ShapeDtypeStruct((*lead, B, S), _tok_dtype())
    if shape.kind == "train":
        d["targets"] = jax.ShapeDtypeStruct(d["tokens"].shape, _tok_dtype())
    return d


# ---------------------------------------------------------------------------
# training (federated round)
# ---------------------------------------------------------------------------

def build_train_step(cfg: ArchConfig, shape: ShapeConfig, mesh) -> StepBundle:
    assert shape.kind == "train"
    model = build_model(cfg)
    fed = make_fed(cfg.fed)
    m = num_clients(cfg, mesh)
    layout = cfg.fed.layout

    nmb = cfg.microbatch
    if nmb:
        # clamp to the per-client batch: on the multi-pod mesh m doubles and
        # the per-client batch halves (e.g. 256/32 = 8 < microbatch 16)
        b_client = shape.global_batch // m
        while nmb > 1 and b_client % nmb:
            nmb -= 1
        nmb = min(nmb, b_client)
    if nmb and nmb > 1:
        def client_grad(params, client_batch):
            # grad accumulation over microbatches: activation memory /nmb
            def split(x):
                b = x.shape[0]
                assert b % nmb == 0, (b, nmb)
                return x.reshape(nmb, b // nmb, *x.shape[1:])

            mb = jax.tree.map(split, client_batch)

            def acc(g, mb_i):
                gi = jax.grad(lambda p: model.loss(p, mb_i)[0])(params)
                return jax.tree.map(jnp.add, g, gi), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            g, _ = jax.lax.scan(acc, g0, mb)
            return jax.tree.map(lambda x, p: (x / nmb).astype(p.dtype), g, params)
    else:
        def client_grad(params, client_batch):
            return jax.grad(lambda p: model.loss(p, client_batch)[0])(params)

    R = cfg.fed.rounds_per_call
    if R > 1:
        # round-batched driver: R full rounds inside ONE jitted lax.scan
        # with the (donated) state carried in place -- one dispatch instead
        # of R, amortising per-round launch overhead.  Batch leaves carry a
        # leading R dim; metrics come back stacked (R, ...).  tol > 0 adds
        # the per-round fixed-point residual metrics the early-exit host
        # loop reads (tol == 0 compiles the identical fixed-budget graph).
        train_step = make_fed_scan(fed, client_grad, tol=cfg.fed.tol)
    else:
        def train_step(fed_state, batch):
            new_state, metrics = fed.round(fed_state, client_grad, batch)
            if cfg.fed.tol > 0.0:  # static gate, same contract as the scan
                from repro.core import autotune
                metrics = {**metrics,
                           **autotune.state_residual(fed_state, new_state)}
            return new_state, metrics

    # shapes + shardings
    param_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_shard = sh.param_shardings(mesh, model.specs(), param_shapes, layout=layout)
    state_shapes = jax.eval_shape(lambda p: fed.init(p, m), param_shapes)
    stacked = sh.stacked_shardings(mesh, p_shard, layout=layout)
    rep = sh.replicated(mesh)

    # arena-resident client state (use_arena, non-fsdp): one (m, width)
    # buffer -- client dim over the client axes, packed width replicated
    # (leaves are concatenated, so per-leaf TP specs don't apply).  Covers
    # every algorithm's stacked residents: lam_s/x_c/u_hat (GPDMM/AGPDMM),
    # z_s (FedSplit), c_i/u_hat (SCAFFOLD/FedAvg); the server-sized x_s and
    # c stay pytrees under the per-leaf parameter shardings.
    cax = sh.client_axes(mesh) if layout == "client_axis" else None
    arena_shard = NamedSharding(mesh, P(cax, None))
    cax_size = sh.axis_size(mesh, cax) if cax else 1

    def rows_shard(v):
        # graph-PDMM arenas: the node-primal (n, width) and edge-dual
        # (2|E|, width) row counts follow the topology, not m -- shard the
        # row dim over the client axes only when it divides evenly (a star's
        # m + 1 node rows don't), else replicate (both are small relative to
        # the m-stacked client state)
        return arena_shard if cax and v.shape[0] % cax_size == 0 else rep

    def state_shardings(shapes):
        out = {}
        for k, v in shapes.items():
            if k in ("x_s", "c"):
                out[k] = p_shard
            elif k in ("lam_s", "x_c", "c_i", "z_s", "u_hat"):
                out[k] = arena_shard if isinstance(v, jax.ShapeDtypeStruct) else stacked
            elif k in ("x", "z"):  # graph-PDMM node/edge-dual arenas
                out[k] = rows_shard(v)
            else:  # round counter etc.
                out[k] = jax.tree.map(lambda _: rep, v)
        return out

    st_shard = state_shardings(state_shapes)
    # cohort-sampled rounds (ISSUE 5): the engine only reads batches for the
    # active cohort, so the batch structs shrink to m_active rows (rows =
    # the round's active clients sorted by id, the cohort data-stream
    # contract) while the POPULATION arena keeps its client-axis sharding
    # above.  Per-client batch size still divides by the population m -- the
    # global batch is defined per round, not per cohort.
    m_batch = m
    if fed_use_cohort(cfg.fed, m) and fed_use_arena(cfg.fed, param_shapes):
        m_batch = cohort_count(m, cfg.fed.participation)
    b_struct = batch_struct(cfg, shape, stacked_m=m, rounds=R if R > 1 else None)
    if m_batch != m:
        # ONE surgery: shrink the client dim (axis 0, or 1 under the round
        # dim) of the per-round struct; the shard struct below derives from
        # it, so the two can't drift apart
        cdim = 1 if R > 1 else 0
        b_struct = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape[:cdim] + (m_batch,) + s.shape[cdim + 1:], s.dtype),
            b_struct,
        )
    # per-round view (round dim dropped -- it is scanned over, never sharded)
    b_round_struct = (jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), b_struct)
        if R > 1 else b_struct)
    b_shard = sh.batch_shardings(mesh, b_round_struct, stacked=True, layout=layout)
    if R > 1:  # round dim is scanned over, never sharded
        b_shard = jax.tree.map(
            lambda s: NamedSharding(mesh, P(None, *s.spec)), b_shard
        )

    metrics_shapes = jax.eval_shape(train_step, state_shapes, b_struct)[1]
    out_shardings = (st_shard, jax.tree.map(lambda _: rep, metrics_shapes))

    return StepBundle(
        name="train_step",
        fn=train_step,
        args=(state_shapes, b_struct),
        in_shardings=(st_shard, b_shard),
        out_shardings=out_shardings,
        meta={
            "m": m,
            "m_active": m_batch,  # batch rows per round (cohort engine)
            "layout": layout,
            "K": cfg.fed.inner_steps,
            "algorithm": cfg.fed.algorithm,
            "rounds_per_call": R,
        },
        donate_argnums=(0,),  # fed_state is consumed each round
    )


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _serve_layout(cfg: ArchConfig) -> str:
    # big-model serving reuses the FSDP parameter layout (params sharded over
    # data x model; XLA all-gathers per layer); others keep pure TP.
    return cfg.fed.layout


def _cache_seq_axis(cfg: ArchConfig, mesh) -> Optional[str]:
    """SSPerf H2: shard the cache's seq dim over "model" when the head dim
    cannot use that axis -- GQA kv-heads not divisible (yi/llama3 kv=8 on a
    16-way axis) or MLA's head-free compressed cache.  Without this the
    32k-deep cache is replicated across the model axis (observed 61 GiB of
    decode arguments per device on yi-34b)."""
    if not cfg.shard_cache_seq:
        return None
    model_size = sh.axis_size(mesh, "model")
    if cfg.attn_kind == "mla" or cfg.n_kv_heads % model_size != 0:
        return "model"
    return None


def build_prefill_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                       window_override: Optional[int] = None) -> StepBundle:
    assert shape.kind == "prefill"
    model = build_model(cfg, window_override=window_override)
    cap = shape.seq_len + 8  # room for a few decode steps

    def prefill_step(params, batch):
        return model.prefill(params, batch, cap)

    param_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_shard = sh.param_shardings(mesh, model.specs(), param_shapes, layout=_serve_layout(cfg))
    b_struct = batch_struct(cfg, shape, stacked_m=None)
    b_shard = sh.batch_shardings(mesh, b_struct, stacked=False)

    # Pin the output shardings: left compiler-chosen, GSPMD replicates the
    # 32k-deep cache on every device (observed 240 GiB/device on yi-34b).
    B = shape.global_batch
    cache_struct = model.cache_shapes(B, cap)
    c_shard = {
        "layers": sh.cache_shardings(
            mesh, cache_struct["layers"], model.cache_specs()["layers"],
            seq_axis=_cache_seq_axis(cfg, mesh),
        ),
        "pos": sh.replicated(mesh),
    }
    logits_sds = jax.eval_shape(prefill_step, param_shapes, b_struct)[0]
    l_shard = sh.logits_shardings(mesh, logits_sds)

    return StepBundle(
        name="prefill_step",
        fn=prefill_step,
        args=(param_shapes, b_struct),
        in_shardings=(p_shard, b_shard),
        out_shardings=(l_shard, c_shard),
        meta={"cap": cap},
    )


def build_decode_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                      window_override: Optional[int] = None) -> StepBundle:
    assert shape.kind == "decode"
    model = build_model(cfg, window_override=window_override)
    B = shape.global_batch
    cap = shape.seq_len

    def decode_step(params, cache, tokens):
        return model.decode(params, cache, tokens)

    param_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_shard = sh.param_shardings(mesh, model.specs(), param_shapes, layout=_serve_layout(cfg))
    cache_struct = model.cache_shapes(B, cap)
    c_shard = {
        "layers": sh.cache_shardings(
            mesh, cache_struct["layers"], model.cache_specs()["layers"],
            seq_axis=_cache_seq_axis(cfg, mesh),
        ),
        "pos": sh.replicated(mesh),
    }
    if cfg.n_codebooks > 1:
        tok = jax.ShapeDtypeStruct((B, cfg.n_codebooks, 1), _tok_dtype())
    else:
        tok = jax.ShapeDtypeStruct((B, 1), _tok_dtype())
    t_shard = sh.batch_shardings(mesh, tok, stacked=False)

    return StepBundle(
        name="decode_step",
        fn=decode_step,
        args=(param_shapes, cache_struct, tok),
        in_shardings=(p_shard, c_shard, t_shard),
        # logits sharding compiler-chosen; cache out mirrors cache in so the
        # donation aliases cleanly
        out_shardings=(None, c_shard),
        meta={"cap": cap, "window_override": window_override},
        donate_argnums=(1,),  # the cache is updated in place
    )


def build_step(cfg: ArchConfig, shape: ShapeConfig, mesh) -> StepBundle:
    """Dispatch on the shape kind, applying the documented long_500k policy."""
    window_override = None
    if shape.name == "long_500k":
        if not cfg.supports_shape(shape):
            raise ValueError(
                f"{cfg.name} skips long_500k (full attention, no SW variant; see DESIGN.md)"
            )
        if not cfg.subquadratic:
            window_override = cfg.sw_variant_window
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh)
    return build_decode_step(cfg, shape, mesh, window_override=window_override)
