"""Flat client-state arena + fused round-tail kernels (ISSUE 1 tentpole).

Covers: pack/unpack round trips across dtypes and odd (non-multiple-of-128)
leaf sizes, interpret-mode parity of every round-tail kernel against the
per-leaf pytree reference, arena-vs-pytree parity of whole GPDMM/AGPDMM/
FedSplit rounds (incl. the EF21-quantised and partial-participation
variants), the KKT invariant on the arena path, and the VMEM budget guard.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs.base import FederatedConfig
from repro.core import arena, fedsplit, make, quadratic
from repro.core import tree_util as T
from repro.kernels import ops
from repro.kernels.fused_update import BLOCK_ROWS, fused_update_pallas

IMPLS = ["xla", "pallas_interpret"]

# odd, non-multiple-of-128 leaf sizes on purpose (incl. a scalar)
ODD_TREE_SHAPES = {"a": (7,), "b": {"w": (3, 50), "s": ()}, "c": (130,)}


def odd_tree(key, dtype=jnp.float32, m=None):
    leaves = {}
    ks = iter(jax.random.split(key, 8))

    def mk(shape):
        lead = () if m is None else (m,)
        return jax.random.normal(next(ks), lead + shape).astype(dtype)

    leaves = {"a": mk((7,)), "b": {"w": mk((3, 50)), "s": mk(())}, "c": mk((130,))}
    return leaves


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pack_unpack_roundtrip(dtype):
    tree = odd_tree(jax.random.key(0), dtype)
    spec = arena.ArenaSpec.from_tree(tree)
    row = spec.pack(tree)
    assert row.shape == (spec.width,) and spec.width % arena.LANES == 0
    back = spec.unpack(row)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pack_unpack_stacked_roundtrip(dtype):
    m = 5
    tree = odd_tree(jax.random.key(1), dtype, m=m)
    spec = arena.ArenaSpec.from_tree(tree, stacked=True)
    buf = spec.pack_stacked(tree)
    assert buf.shape == (m, spec.width)
    back = spec.unpack_stacked(buf)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_slice_table_lane_aligned():
    spec = arena.ArenaSpec.from_tree(odd_tree(jax.random.key(2)))
    off = 0
    for e in spec.leaves:
        assert e.offset == off and e.offset % arena.LANES == 0
        assert e.padded % arena.LANES == 0 and e.padded >= e.size
        off += e.padded
    assert spec.width == off
    assert sum(spec.leaf_rows()) == spec.n_rows


def test_padding_stays_zero():
    tree = odd_tree(jax.random.key(3), m=4)
    spec = arena.ArenaSpec.from_tree(tree, stacked=True)
    buf = spec.pack_stacked(tree)
    mask = np.ones((spec.width,), bool)
    for e in spec.leaves:
        mask[e.offset:e.offset + e.size] = False
    assert np.all(np.asarray(buf)[:, mask] == 0.0)


def test_leaf_view_matches_leaf():
    tree = odd_tree(jax.random.key(4), m=3)
    spec = arena.ArenaSpec.from_tree(tree, stacked=True)
    buf = spec.pack_stacked(tree)
    leaves = jax.tree.leaves(tree)
    for i in range(len(spec.leaves)):
        np.testing.assert_array_equal(np.asarray(spec.leaf_view(buf, i)), np.asarray(leaves[i]))


# ---------------------------------------------------------------------------
# kernel parity (interpret mode) vs the pytree reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_round_tail_parity(impl, dtype):
    m, rho = 5, 2.5
    tree = odd_tree(jax.random.key(5), dtype, m=m)
    spec = arena.ArenaSpec.from_tree(tree, stacked=True)
    lam_tree = odd_tree(jax.random.key(6), dtype, m=m)
    xs_tree = odd_tree(jax.random.key(7), dtype)
    xs_b = T.tree_broadcast(xs_tree, m)
    lam_is_t = T.tmap(lambda s, xr, l: rho * (s - xr) - l, xs_b, tree, lam_tree)
    up_t = T.tmap(lambda xr, l: xr - l / rho, tree, lam_is_t)

    lam_is, up = ops.round_tail(
        spec.pack_stacked(tree), spec.pack_stacked(lam_tree), spec.pack(xs_tree), rho, impl=impl
    )
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(lam_is, np.float32), np.asarray(spec.pack_stacked(lam_is_t), np.float32),
        atol=tol, rtol=tol)
    np.testing.assert_allclose(
        np.asarray(up, np.float32), np.asarray(spec.pack_stacked(up_t), np.float32),
        atol=tol, rtol=tol)

    lam_new = ops.dual_from_uplink(up, spec.pack(xs_tree), rho, impl=impl)
    exp = rho * (np.asarray(up, np.float32) - np.asarray(spec.pack(xs_tree), np.float32)[None])
    np.testing.assert_allclose(np.asarray(lam_new, np.float32), exp, atol=tol, rtol=tol)

    # uplink-only hot-path variant: same uplink, no lam_is output
    none_lam, up2 = ops.round_tail(
        spec.pack_stacked(tree), spec.pack_stacked(lam_tree), spec.pack(xs_tree), rho,
        with_lam_is=False, impl=impl)
    assert none_lam is None
    np.testing.assert_allclose(np.asarray(up2, np.float32), np.asarray(up, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ef21_parity(impl, bits, dtype):
    """Fused EF21 == per-leaf tree_quantize_delta, incl. the per-(client,
    leaf) max-abs quantisation scale granularity."""
    m = 6
    u_tree = odd_tree(jax.random.key(8), dtype, m=m)
    uh_tree = jax.tree.map(lambda t: t * 0.7, u_tree)
    spec = arena.ArenaSpec.from_tree(u_tree, stacked=True)
    ref = spec.pack_stacked(T.tree_quantize_delta(u_tree, uh_tree, bits))
    got = ops.ef21_update(
        spec.pack_stacked(u_tree), spec.pack_stacked(uh_tree), bits, spec.leaf_rows(), impl=impl
    )
    tol = 1e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("impl", IMPLS)
def test_fused_update_arena_parity(impl):
    m = 4
    tree = odd_tree(jax.random.key(9), m=m)
    spec = arena.ArenaSpec.from_tree(tree, stacked=True)
    x = spec.pack_stacked(tree)
    g = x * 0.3
    lam = x * 0.1 + 0.05
    xs = spec.pack(odd_tree(jax.random.key(10)))
    out = ops.fused_update_arena(x, g, xs, lam, 0.05, 3.0, impl=impl)
    exp = np.asarray(x) - 0.05 * (np.asarray(g) + 3.0 * (np.asarray(x) - np.asarray(xs)[None]) + np.asarray(lam))
    np.testing.assert_allclose(np.asarray(out), exp, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("impl", IMPLS)
def test_fused_update_nolam(impl):
    """lam=None drops the dual term (FedSplit's lam-free step): one fewer
    HBM read, same math as lam=0."""
    k = jax.random.key(11)
    x, g, xs = (jax.random.normal(jax.random.fold_in(k, i), (5, 300)) for i in range(3))
    out = ops.fused_update(x, g, xs, None, 0.05, 3.0, impl=impl)
    exp = np.asarray(x) - 0.05 * (np.asarray(g) + 3.0 * (np.asarray(x) - np.asarray(xs)))
    np.testing.assert_allclose(np.asarray(out), exp, atol=1e-5, rtol=1e-5)


def test_vmem_budget_guard():
    """block sizes whose f32 working set exceeds the documented cap are
    rejected; the unified default passes."""
    x = jnp.ones((256,))
    with pytest.raises(AssertionError, match="VMEM"):
        fused_update_pallas(x, x, x, x, 0.1, 1.0, block=100_000, interpret=True)
    out = fused_update_pallas(x, x, x, x, 0.1, 1.0, block=BLOCK_ROWS, interpret=True)
    assert out.shape == x.shape


# ---------------------------------------------------------------------------
# whole-round parity: arena path == pytree path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prob():
    return quadratic.generate(jax.random.key(0), m=8, n=120, d=24)


VARIANTS = {
    "plain": {},
    "ef21": {"uplink_bits": 8},
    "partial": {"participation": 0.5},
    "ef21+partial": {"uplink_bits": 8, "participation": 0.5},
    "last_iter": {"use_avg": False},
}


@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm"])
@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_round_parity_arena_vs_pytree(prob, algo, variant):
    """GPDMM/AGPDMM rounds on the arena path are bitwise-comparable (within
    dtype tolerance) to the pytree path -- the ISSUE's acceptance criterion."""
    kw = dict(algorithm=algo, inner_steps=3, eta=0.5 / prob.L, **VARIANTS[variant])
    x0 = jnp.zeros((prob.d,))
    batch = prob.batch()
    states = {}
    for use_arena in [True, False]:
        opt = make(FederatedConfig(use_arena=use_arena, **kw))
        s = opt.init(x0, prob.m)
        for _ in range(5):
            s, metrics = opt.round(s, prob.grad, batch)
        states[use_arena] = (s, metrics)
    sa, ma = states[True]
    sp, mp = states[False]
    assert set(sa) == set(sp)
    spec = arena.ArenaSpec.from_tree(sp["x_s"])
    for ka in sorted(sa):
        got, want = sa[ka], sp[ka]
        if ka != "x_s" and ka != "round":
            want = spec.pack_stacked(want)  # arena path keeps clients packed
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(got)[0]), np.asarray(jax.tree.leaves(want)[0]),
            atol=1e-5, rtol=1e-5, err_msg=f"{algo}/{variant}: state[{ka}]")
    for km in ma:
        if km == "used_arena":  # records the layout decision: differs by design
            continue
        np.testing.assert_allclose(float(ma[km]), float(mp[km]), atol=1e-4,
                                   err_msg=f"{algo}/{variant}: metrics[{km}]")


@pytest.mark.parametrize("init", ["z", "xs"])
def test_fedsplit_round_parity(prob, init):
    x0 = jnp.zeros((prob.d,))
    batch = prob.batch()
    states = {}
    for use_arena in [True, False]:
        opt = make(FederatedConfig(algorithm="fedsplit", inner_steps=3, eta=1.0 / prob.L,
                                   fedsplit_init=init, rho=prob.L / 10, use_arena=use_arena))
        s = opt.init(x0, prob.m)
        for _ in range(5):
            s, _ = opt.round(s, prob.grad, batch)
        states[use_arena] = s
    np.testing.assert_allclose(np.asarray(states[True]["x_s"]), np.asarray(states[False]["x_s"]),
                               atol=1e-5, rtol=1e-5)
    spec = arena.ArenaSpec.from_tree(states[False]["x_s"])
    np.testing.assert_allclose(np.asarray(states[True]["z_s"]),
                               np.asarray(spec.pack_stacked(states[False]["z_s"])),
                               atol=1e-5, rtol=1e-5)


def test_trace_parity(prob):
    """return_trace quantities (theory checks) match across paths."""
    x0 = jnp.zeros((prob.d,))
    traces = {}
    for use_arena in [True, False]:
        opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=3, eta=0.5 / prob.L,
                                   use_arena=use_arena))
        s = opt.init(x0, prob.m)
        s, metrics = opt.round(s, prob.grad, prob.batch(), return_trace=True)
        traces[use_arena] = metrics["trace"]
    for k in traces[True]:
        np.testing.assert_allclose(np.asarray(traces[True][k]), np.asarray(traces[False][k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)


def test_mixed_dtype_falls_back_to_pytree():
    """Mixed-dtype trees (bf16 weights + f32 norms) take the pytree path:
    a single arena buffer would promote everything to the widest dtype --
    2x the client-state HBM and a numerical divergence."""
    params = {"w": jnp.ones((37, 5), jnp.bfloat16), "b": jnp.zeros((3,), jnp.float32)}

    def grad_fn(p, _b):
        return jax.tree.map(lambda x: (0.3 * x.astype(jnp.float32)).astype(x.dtype), p)

    batch = {"d": jnp.zeros((4, 1))}
    outs = {}
    for use_arena in [True, False]:
        opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=2, eta=0.1,
                                   use_arena=use_arena))
        s = opt.init(params, 4)
        # both configs must produce the identical (pytree) state layout
        assert jax.tree.leaves(s["lam_s"])[0].shape[1:] != (0,)  # smoke
        for _ in range(2):
            s, _ = opt.round(s, grad_fn, batch)
        outs[use_arena] = s["x_s"]
    for a, b in zip(jax.tree.leaves(outs[True]), jax.tree.leaves(outs[False])):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_svrg_parity():
    """SVRG per-step-batch inner loop matches across paths."""
    key = jax.random.key(5)
    m, d, K = 4, 16, 3
    params = jnp.zeros((d,))
    batch = {"w": jax.random.normal(key, (K, m, d))}

    def grad_fn(x, b):
        return 0.3 * x + 0.01 * b["w"]

    outs = {}
    for use_arena in [True, False]:
        opt = make(FederatedConfig(algorithm="gpdmm", inner_steps=K, eta=0.1,
                                   variance_reduction="svrg", use_arena=use_arena))
        s = opt.init(params, m)
        for _ in range(3):
            s, _ = opt.round(s, grad_fn, batch, per_step_batches=True)
        outs[use_arena] = s["x_s"]
    np.testing.assert_allclose(np.asarray(outs[True]), np.asarray(outs[False]), atol=1e-6)


# ---------------------------------------------------------------------------
# KKT invariant (eq. 25) on the arena path, for ANY parameter pytree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["gpdmm", "agpdmm"])
def test_kkt_invariant_arena(prob, algo):
    opt = make(FederatedConfig(algorithm=algo, inner_steps=3, eta=0.5 / prob.L, use_arena=True))
    s = opt.init(jnp.zeros((prob.d,)), prob.m)
    for _ in range(10):
        s, metrics = opt.round(s, prob.grad, prob.batch())
        assert float(metrics["lam_sum_norm"]) < 1e-3


@st.composite
def _pytrees(draw):
    n_leaves = draw(st.integers(1, 3))
    tree = {}
    for i in range(n_leaves):
        shape = tuple(draw(st.lists(st.integers(1, 6), min_size=1, max_size=2)))
        tree[f"w{i}"] = jnp.full(shape, float(i + 1))
    return tree


@settings(max_examples=10, deadline=None)
@given(params=_pytrees(), algo=st.sampled_from(["gpdmm", "agpdmm"]),
       m=st.integers(2, 4), k=st.integers(1, 3))
def test_kkt_invariant_arena_property(params, algo, m, k):
    """sum_i lam_{s|i} == 0 holds on the arena path for arbitrary pytrees."""
    opt = make(FederatedConfig(algorithm=algo, inner_steps=k, eta=0.1, use_arena=True))

    def grad_fn(p, _b):
        return jax.tree.map(lambda x: 0.3 * x, p)

    s = opt.init(params, m)
    s2, metrics = opt.round(s, grad_fn, {"dummy": jnp.zeros((m, 1))})
    assert jax.tree.structure(s2) == jax.tree.structure(s)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s2)):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert float(metrics["lam_sum_norm"]) < 1e-4
