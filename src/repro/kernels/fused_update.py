"""Pallas TPU kernel for the paper's fused federated client step (eq. 20):

    x' = x - step * (g + rho * (x - xs) + lam)

Why a kernel: the GPDMM/AGPDMM inner loop runs K times per round over every
parameter; it is purely memory-bound (arithmetic intensity ~1 flop/byte).  An
unfused XLA graph reads/writes intermediate tensors; the fusion does exactly
4 HBM reads + 1 write per element, the roofline minimum.

Tiling: inputs are flattened and tiled (BLOCK_ROWS, 128) -- the TPU lane width
-- so the kernel is a straight VMEM-resident vector op per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# THE canonical lane width and block default: core.arena packs to LANES
# multiples and every round-tail kernel tiles against it, so both sides
# import these from here -- exactly one knob each.
LANES = 128
BLOCK_ROWS = 256  # 256 x 128 x 4B x 5 arrays ~ 0.7 MB of VMEM per step


def ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m

# VMEM budget for the f32 working set of one grid step: n_arrays x block x
# LANES x 4 B must stay under this.  8 MiB = half the ~16 MiB/core VMEM,
# leaving the other half for Pallas' double-buffered pipeline copies.
VMEM_CAP_BYTES = 8 * 1024 * 1024


def assert_vmem_budget(n_arrays: int, block: int) -> None:
    need = n_arrays * block * LANES * 4
    assert need <= VMEM_CAP_BYTES, (
        f"block={block}: {n_arrays} arrays x {block} rows x {LANES} lanes x 4 B "
        f"= {need} B of VMEM exceeds the {VMEM_CAP_BYTES} B budget "
        f"(max block here: {VMEM_CAP_BYTES // (n_arrays * LANES * 4)})"
    )


def eq20(x, g, xs, lam, step: float, rho: float):
    """The f32 eq. (20) arithmetic, shared by every fused-update kernel body
    (here and in round_tail.py) so the formula has ONE source of truth.
    Inputs are f32 arrays; ``lam=None`` drops the dual term."""
    acc = g + rho * (x - xs)
    if lam is not None:
        acc = acc + lam
    return x - step * acc


def _kernel(x_ref, g_ref, xs_ref, lam_ref, o_ref, *, step: float, rho: float):
    f32 = jnp.float32
    out = eq20(x_ref[...].astype(f32), g_ref[...].astype(f32),
               xs_ref[...].astype(f32), lam_ref[...].astype(f32), step, rho)
    o_ref[...] = out.astype(o_ref.dtype)


def _kernel_nolam(x_ref, g_ref, xs_ref, o_ref, *, step: float, rho: float):
    # lam-free variant (Inexact FedSplit): one fewer HBM read per element
    f32 = jnp.float32
    out = eq20(x_ref[...].astype(f32), g_ref[...].astype(f32),
               xs_ref[...].astype(f32), None, step, rho)
    o_ref[...] = out.astype(o_ref.dtype)


def fused_update_pallas(x, g, xs, lam, step, rho, *, block: int = BLOCK_ROWS, interpret: bool = False):
    args = [x, g, xs] if lam is None else [x, g, xs, lam]
    assert_vmem_budget(len(args) + 1, block)
    shape, dtype = x.shape, x.dtype
    n = x.size
    tile = block * LANES
    n_pad = (tile - n % tile) % tile

    def flat(a):
        a = a.reshape(-1)
        if n_pad:
            a = jnp.pad(a, (0, n_pad))
        return a.reshape(-1, LANES)

    flats = [flat(a) for a in args]
    rows = flats[0].shape[0]
    grid = (rows // block,)
    bs = pl.BlockSpec((block, LANES), lambda i: (i, 0))
    kernel = _kernel_nolam if lam is None else _kernel
    out = pl.pallas_call(
        functools.partial(kernel, step=float(step), rho=float(rho)),
        grid=grid,
        in_specs=[bs] * len(flats),
        out_specs=bs,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), dtype),
        interpret=interpret,
    )(*flats)
    return out.reshape(-1)[:n].reshape(shape)
