"""Pallas TPU kernels for the cohort engine's arena row movement
(``core.api`` cohort plumbing, ISSUE 5).

The cohort-sampled round touches the population arena exactly twice per
resident buffer: a GATHER of the active rows into the ``(m_active, width)``
cohort buffer before the fused inner loop, and a SCATTER of the updated rows
back afterwards.  Both ride the scalar-prefetch index maps (the
``neighbor_reduce.edge_flip`` idiom), so neither materialises a permutation
or an intermediate copy:

  * ``row_gather_pallas``  -- out[t] = arr[idx[t]]: the cohort index rides
    the INPUT index map; one read of the gathered rows + one write of the
    cohort buffer.

  * ``row_scatter_pallas`` -- out[i] = rows[pos[i]] if mask[i] else dst[i]:
    rather than aliased in-place writes, the scatter is phrased as a gather
    over the POPULATION grid via the inverse position table
    ``pos[idx[t]] = t`` (built by the ``ops.row_scatter`` wrapper), selecting
    per population row between its fresh cohort row and its kept carry --
    every output row is written exactly once, no input/output aliasing
    contract needed, and the silent rows stream straight through.

Unlike the static topology tables in ``neighbor_reduce.py``, ``idx``/
``pos``/``mask`` here are DYNAMIC (drawn per round from the participation
RNG): scalar-prefetch operands are SMEM values, not compile-time constants,
so the same compiled kernel serves every round's cohort.

Both kernels tile rows as ``(block, 128)`` under the shared 8 MiB VMEM
budget and block-size conventions of ``round_tail.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_update import LANES, assert_vmem_budget
from repro.kernels.round_tail import _resolve_block, _tile, _untile


def _gather_kernel(idx_ref, src_ref, o_ref):
    o_ref[0] = src_ref[0]


def row_gather_pallas(arr, idx, *, block=None, interpret: bool = False):
    """arr: (m, width) population buffer; idx: (m_active,) int32 row ids.
    Returns the (m_active, width) cohort buffer out[t] = arr[idx[t]].  The
    gather rides the scalar-prefetch input index map -- no permuted copy."""
    m, w = arr.shape
    mc = idx.shape[0]
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(2, br)
    at, _, rows_p = _tile(arr, br)
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(mc, rows_p // br),
            in_specs=[
                pl.BlockSpec((1, br, LANES), lambda t, j, idx: (idx[t], j, 0)),
            ],
            out_specs=pl.BlockSpec((1, br, LANES), lambda t, j, idx: (t, j, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((mc, rows_p, LANES), arr.dtype),
        interpret=interpret,
    )(jnp.asarray(idx, jnp.int32), at)
    return _untile(out, w, (mc,))


def _scatter_kernel(pos_ref, mask_ref, rows_ref, dst_ref, o_ref):
    i = pl.program_id(0)
    o_ref[0] = jnp.where(mask_ref[i] != 0, rows_ref[0], dst_ref[0])


def row_scatter_pallas(dst, pos, mask, rows, *, block=None, interpret: bool = False):
    """dst: (m, width) population buffer; rows: (m_active, width) cohort
    buffer; pos: (m,) int32 with pos[i] = the cohort position of population
    row i (any in-range value at silent rows); mask: (m,) int32, 1 = active.
    Returns the scattered population buffer (out[i] = rows[pos[i]] at active
    rows, dst[i] elsewhere).  Phrased as a population-grid gather, so every
    output row is written once and no aliasing contract is needed."""
    m, w = dst.shape
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(3, br)
    dt, _, rows_p = _tile(dst, br)
    rt, _, _ = _tile(rows, br)
    out = pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(m, rows_p // br),
            in_specs=[
                pl.BlockSpec((1, br, LANES), lambda i, j, pos, mk: (pos[i], j, 0)),
                pl.BlockSpec((1, br, LANES), lambda i, j, pos, mk: (i, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, br, LANES), lambda i, j, pos, mk: (i, j, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, rows_p, LANES), dst.dtype),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32), jnp.asarray(mask, jnp.int32), rt, dt)
    return _untile(out, w, (m,))
