"""End-to-end driver: federated training of a transformer LM with the paper's
optimisers on heterogeneous synthetic data (each client draws from its own
topic distribution), comparing GPDMM / AGPDMM / FedAvg.

Default preset is CPU-sized (~20M params, 60 rounds, a few minutes).  The
``--preset 100m`` configuration (d_model 768, 12 layers, ~110M params, 300
rounds) is the assignment's "train a ~100M model for a few hundred steps"
driver -- run it on real accelerators or leave it overnight on CPU.

    PYTHONPATH=src python examples/train_federated_lm.py
    PYTHONPATH=src python examples/train_federated_lm.py --preset 100m --algos gpdmm
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import FederatedConfig
from repro.core import make as make_fed
from repro.data.synthetic import lm_batches
from repro.models import build

PRESETS = {
    # (d_model, n_layers, d_ff, vocab, heads, steps, per_client_batch, seq)
    "small": (256, 4, 1024, 4096, 4, 60, 4, 128),
    "100m": (768, 12, 3072, 16384, 12, 300, 8, 256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--algos", default="gpdmm,agpdmm,fedavg")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--k", type=int, default=4)
    # eta 0.05 is in the stable region for these presets (0.5 diverges:
    # the prox-gradient step stops contracting on the non-convex loss)
    ap.add_argument("--eta", type=float, default=0.05)
    args = ap.parse_args()

    d, L, ff, vocab, heads, steps, pcb, seq = PRESETS[args.preset]
    base = get_arch("olmo-1b").reduced()
    cfg = dataclasses.replace(
        base, d_model=d, n_layers=L, d_ff=ff, vocab_size=vocab,
        n_heads=heads, n_kv_heads=heads, head_dim=d // heads,
    )
    model = build(cfg)
    n_params = sum(int(jnp.size(x)) for x in jax.tree.leaves(model.init(jax.random.key(0))))
    print(f"# preset={args.preset}: {n_params/1e6:.1f}M params, "
          f"{steps} rounds, m={args.clients}, K={args.k}")

    m = args.clients
    results = {}
    for algo in args.algos.split(","):
        fed = make_fed(FederatedConfig(algorithm=algo, inner_steps=args.k, eta=args.eta))
        params = model.init(jax.random.key(0))
        state = fed.init(params, m)

        def grad_fn(p, b):
            return jax.grad(lambda q: model.loss(q, b)[0])(p)

        @jax.jit
        def step_fn(state, batch):
            return fed.round(state, grad_fn, batch)

        @jax.jit
        def eval_loss(p, batch):
            return jax.vmap(lambda b: model.loss(p, b)[0])(batch).mean()

        curve = []
        for i, batch in enumerate(
            lm_batches(jax.random.key(1), steps, m, pcb, seq, cfg.vocab_size)
        ):
            state, _ = step_fn(state, batch)
            if i % max(1, steps // 10) == 0 or i == steps - 1:
                loss = float(eval_loss(fed.server_params(state), batch))
                curve.append((i, loss))
                print(f"[{algo:8s}] round {i:4d}  server loss {loss:.4f}", flush=True)
        results[algo] = curve

    print("\n# final server losses (heterogeneous clients, K="
          f"{args.k}):")
    for algo, curve in results.items():
        print(f"#   {algo:8s} {curve[-1][1]:.4f}")
    print(json.dumps({a: c for a, c in results.items()}))


if __name__ == "__main__":
    main()
