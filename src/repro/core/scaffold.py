"""SCAFFOLD (Karimireddy et al. 2020), eqs. (29)-(30) of the paper, as the
primary baseline.  Control variates c (server) and c_i (clients) compensate
client heterogeneity; both directions transmit TWO variables per round
(x and c), which is the communication contrast with GPDMM the paper draws.

    x_i^{r,0}   = x_s^r
    x_i^{r,k+1} = x_i^{r,k} - eta (grad f_i(x_i^{r,k}) - c_i^r + c^r)
    c_i^{r+1}   = c_i^r - c^r + (x_s^r - x_i^{r,K}) / (K eta)
    x_s^{r+1}   = x_s^r + eta_g mean_i (x_i^{r,K} - x_s^r)   (all-reduce #1)
    c^{r+1}     = c^r + mean_i (c_i^{r+1} - c_i^r)           (all-reduce #2)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import tree_util as T
from repro.core.api import FedOpt
from repro.kernels import ops


def _round(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches=False):
    K, eta = cfg.inner_steps, cfg.eta
    x_s, c, c_i = state["x_s"], state["c"], state["c_i"]
    m = jax.tree.leaves(c_i)[0].shape[0]
    x_s_b = T.tree_broadcast(x_s, m)
    c_b = T.tree_broadcast(c, m)
    # lam := c - c_i enters the shared fused step with rho = 0
    lam = T.tree_sub(c_b, c_i)
    vgrad = jax.vmap(grad_fn)

    def one_step(x, xs_k):
        b = xs_k if per_step_batches else batch
        g = vgrad(x, b)
        x_new = T.tmap(lambda xx, gg, ll: ops.fused_update(xx, gg, xx, ll, eta, 0.0), x, g, lam)
        return x_new, None

    if per_step_batches:
        x_K, _ = jax.lax.scan(one_step, x_s_b, batch)
    else:
        x_K, _ = jax.lax.scan(one_step, x_s_b, None, length=K)

    c_i_new = T.tmap(lambda ci, cc, s, xk: ci - cc + (s - xk) / (K * eta), c_i, c_b, x_s_b, x_K)
    # server: TWO all-reduces (x-delta and c-delta)
    dx = T.tree_client_mean(T.tree_sub(x_K, x_s_b))
    dc = T.tree_client_mean(T.tree_sub(c_i_new, c_i))
    x_s_new = T.tree_axpy(cfg.eta_g, dx, x_s)
    c_new = T.tree_add(c, dc)

    new_state = {
        "x_s": x_s_new,
        "c": c_new,
        "c_i": c_i_new,
        "round": state["round"] + 1,
    }
    metrics = {
        # invariant: sum_i (c_i - c) = 0 given zero init
        "c_sum_norm": T.tree_norm(T.tree_client_sum(T.tree_sub(c_i_new, T.tree_broadcast(c_new, m)))),
        "client_drift": jnp.mean(T.tree_client_sqnorms(T.tree_sub(x_K, x_s_b))),
    }
    return new_state, metrics


def make(cfg: FederatedConfig) -> FedOpt:
    def init(params, m):
        return {
            "x_s": params,
            "c": T.tree_zeros_like(params),
            "c_i": T.tree_zeros_like(T.tree_broadcast(params, m)),
            "round": jnp.zeros((), jnp.int32),
        }

    return FedOpt(
        name="scaffold",
        init=init,
        round=partial(_round, cfg),
        server_params=lambda s: s["x_s"],
    )
