"""Production mesh construction.

The target is TPU v5e: one pod = 16 x 16 = 256 chips with axes
("data", "model"); the multi-pod configuration stacks 2 pods = 512 chips with
axes ("pod", "data", "model").  Everything is a function -- importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many (CPU) devices a test process has."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )


# TPU v5e hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (~3 links usable per axis-neighbour topology)
