"""llava-next-mistral-7b -- VLM, anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Backbone: Mistral-7B (32L d_model=4096 32H GQA kv=8 d_ff=14336 vocab=32000).
The vision frontend (SigLIP/CLIP ViT + anyres tiling) is a STUB per the
assignment: ``input_specs()`` provides precomputed patch embeddings of shape
(batch, n_prefix_tokens, frontend_dim); the model owns only the 2-layer MLP
projector and the language decoder that consumes them.
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("dense",),
    attn_kind="gqa",
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    frontend="vision",
    n_prefix_tokens=576,  # one 24x24 CLIP-ViT-L/14 tile (base image)
    frontend_dim=1024,
    shard_cache_seq=True,  # SSPerf H2: kv=8 can't divide the 16-way model axis
    subquadratic=False,  # long_500k skipped (full attention; see DESIGN.md)
    fed=FederatedConfig(algorithm="gpdmm", layout="client_axis"),
    microbatch=16,  # grad-accum chunks per inner step (activation memory)
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
