"""musicgen-large -- decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048, 4 parallel codebooks with
the delay interleaving pattern.  The EnCodec conv codec is a STUB per the
assignment: the model consumes 4 token streams (summed codebook embeddings)
and emits 4 per-codebook heads.
"""
from repro.configs.base import ArchConfig, FederatedConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=("dense",),
    attn_kind="gqa",
    norm_kind="layernorm",
    act="gelu",
    frontend="audio",
    n_codebooks=4,
    subquadratic=False,  # long_500k skipped (full attention; see DESIGN.md)
    fed=FederatedConfig(algorithm="gpdmm", layout="client_axis"),
    microbatch=8,  # grad-accum chunks per inner step (activation memory)
    source="arXiv:2306.05284 (MusicGen)",
)
