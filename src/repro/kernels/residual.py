"""Pallas TPU kernel for the fused fixed-point residual norms (the
early-termination criterion, ``core.autotune``).

ONE pass over the ``(m, width)`` client-state arena and its previous-round
snapshot emits, per client row,

  * ``dx2_i = ||x_i - x_prev_i||^2``   (the fixed-point residual), and
  * ``x2_i  = ||x_i||^2``              (the normaliser),

so the host driver can evaluate pfb-clean's relative stopping rule
``||x - x_prev|| / ||x|| < tol`` without a second read of either buffer.
The per-client split (rather than a single server scalar) keeps the kernel
reusable for cohort paths -- the caller reduces over whichever rows
participated.

Layout: grid ``(m, rows_p // block)`` with the width blocks INNERMOST, so
each client's two per-lane accumulator rows -- ``(1, LANES)`` f32 blocks of
the tiny ``(m, LANES)`` outputs -- are revisited across the row's width
blocks and stay VMEM-resident (the same revisited-output accumulation
contract as ``screen`` / ``neighbor_reduce``).  The cheap cross-lane finish
(sum over LANES) runs on the ``(m, LANES)`` partials outside the kernel.

Zero padding -- the arena tail rows and the ``rows_p - rows`` tile pad,
zero on BOTH operands by the arena invariant -- contributes zero to both
sums, so padded and unpadded widths agree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_update import LANES, assert_vmem_budget
from repro.kernels.round_tail import _resolve_block, _tile


def _residual_kernel(x_ref, p_ref, dx_ref, x2_ref):
    j = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)  # (br, LANES)
    p = p_ref[0].astype(jnp.float32)
    d = x - p
    dx = jnp.sum(d * d, axis=0)  # (LANES,) per-lane partial
    x2 = jnp.sum(x * x, axis=0)

    @pl.when(j == 0)
    def _init():
        dx_ref[0] = dx
        x2_ref[0] = x2

    @pl.when(j != 0)
    def _acc():
        dx_ref[0] = dx_ref[0] + dx
        x2_ref[0] = x2_ref[0] + x2


def residual_norm_pallas(x, x_prev, *, block=None, interpret: bool = False):
    """x, x_prev: (m, width).  Returns ``(dx2 (m,) f32, x2 (m,) f32)`` --
    per-client ``||x - x_prev||^2`` and ``||x||^2`` in one fused pass."""
    m, w = x.shape
    assert x_prev.shape == (m, w), (x.shape, x_prev.shape)
    pad = (-w) % LANES
    if pad:
        # zero on BOTH operands: zero contribution to both sums -- identical
        # residual to the unpadded width (arena callers are always aligned)
        x = jnp.pad(x, ((0, 0), (0, pad)))
        x_prev = jnp.pad(x_prev, ((0, 0), (0, pad)))
        w += pad
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(2, br)
    xt, _, rows_p = _tile(x, br)
    pt, _, _ = _tile(x_prev, br)
    client_bs = pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0))
    acc_bs = pl.BlockSpec((1, LANES), lambda i, j: (i, 0))
    dx, x2 = pl.pallas_call(
        _residual_kernel,
        grid=(m, rows_p // br),  # width blocks innermost: accumulators stay hot
        in_specs=[client_bs, client_bs],
        out_specs=(acc_bs, acc_bs),
        out_shape=(jax.ShapeDtypeStruct((m, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((m, LANES), jnp.float32)),
        interpret=interpret,
    )(xt, pt)
    return jnp.sum(dx, axis=1), jnp.sum(x2, axis=1)
