"""Minimal optax-style optimizers as (init, update) pairs over pytrees.

The federated core has its own update rules (GPDMM's prox-gradient step);
these are the plain local optimizers used by the non-federated baselines and
the serving-side tooling.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mom": mom}

    def update(grads, state, params=None):
        step = state["step"] + 1
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g, state["mom"], grads)
            upd = jax.tree.map(lambda m: -lr_fn(step) * m, mom)
            return upd, {"step": step, "mom": mom}
        upd = jax.tree.map(lambda g: -lr_fn(step) * g, grads)
        return upd, {"step": step, "mom": None}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "nu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["nu"], grads)
        t = step.astype(jnp.float32)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1**t), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2**t), nu)
        upd = jax.tree.map(
            lambda m, v, p: (-lr_fn(step) * (m / (jnp.sqrt(v) + eps) + weight_decay * p.astype(jnp.float32))).astype(p.dtype),
            mu_hat, nu_hat, params,
        )
        return upd, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
    gn = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn
