"""Convergence-theory instruments for GPDMM (Theorems 1 & 2).

* ``gpdmm_beta``    -- the linear rate bound beta of Theorem 1.
* ``q_functional``  -- the Lyapunov quantity Q^r of eq. (35); the test-suite
  asserts Q^{r+1} <= beta Q^r along real GPDMM trajectories.
* ``kkt_residuals`` -- the three KKT conditions of eq. (7) evaluated at the
  current iterates (primal consensus, dual feasibility, gradient match).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import tree_util as T
from repro.core.api import resolved_rho


def gpdmm_gammas(L: float, mu: float, eta: float, rho: float, theta: float, phi: float):
    g1 = min((1.0 - theta) / (2.0 * L * eta**2), (1.0 / eta - L) / 2.0)
    g2 = min(theta * mu * phi / (2.0 * rho**2), g1 * eta**2 / 2.0)
    return g1, g2


def gpdmm_beta(L: float, mu: float, eta: float, rho: float, theta: float = 0.5, phi: float = 0.5) -> float:
    """Theorem 1 rate: Q^{r+1} <= beta Q^r, requires 1/eta > L >= mu > 0,
    theta, phi in (0,1) with theta*mu*phi/(4 rho^2) < 1/(4 rho)."""
    assert 1.0 / eta > L >= mu > 0, (eta, L, mu)
    assert 0 < theta < 1 and 0 < phi < 1
    assert theta * mu * phi / (4 * rho**2) < 1.0 / (4 * rho), "phi too large for this rho"
    _, g2 = gpdmm_gammas(L, mu, eta, rho, theta, phi)
    b1 = (1.0 / (4 * rho) - g2 / 2.0) / (1.0 / (4 * rho))
    b2 = (1.0 / eta - theta * mu) / (1.0 / eta - theta * mu * phi)
    beta = max(b1, b2)
    assert 0 < beta < 1, beta
    return beta


def q_functional(
    cfg: FederatedConfig,
    *,
    x_c_prev,  # stacked (m, d): x_i^{r-1,K}
    x_bar,  # stacked (m, d): x-bar_i^{r,K}
    lam_is,  # stacked (m, d): lam_{i|s}^{r+1}
    x_star,  # (d,)
    lam_star,  # (m, d): lam*_{i|s} = grad f_i(x*)
    L: float,
    mu: float,
    theta: float = 0.5,
    phi: float = 0.5,
):
    """Q^r of eq. (35) for vector-valued least-squares states."""
    rho = resolved_rho(cfg)
    eta, K = cfg.eta, cfg.inner_steps
    _, g2 = gpdmm_gammas(L, mu, eta, rho, theta, phi)
    t1 = (1.0 / eta - theta * mu) / (2.0 * K) * jnp.sum((x_c_prev - x_star[None]) ** 2)
    resid = rho * (x_bar - x_star[None]) + (lam_is - lam_star)
    t2 = (1.0 / (4 * rho) - g2 / 2.0) * jnp.sum(resid**2)
    return t1 + t2


def kkt_residuals(problem, x_s, lam_s):
    """Residuals of eq. (7) on the least-squares problem.

    lam_s: stacked (m, d) server duals lam_{s|i}; lam_{i|s} = -lam_{s|i} at a
    fixed point.  Returns dict of scalars, all -> 0 at the optimum.
    """
    grad_at_xs = jnp.einsum("mde,e->md", problem.AtA, x_s) - problem.Atb
    return {
        "grad_match": jnp.linalg.norm(grad_at_xs - (-lam_s)) / problem.m,
        "dual_sum": jnp.linalg.norm(lam_s.sum(0)),
        "primal_gap": problem.gap(x_s),
    }
