"""Pallas TPU kernels for the fused GPDMM/AGPDMM round tail over the flat
client-state arena (``core.arena``).

After the K inner steps, the pytree round runs ~6 separate per-leaf passes
(``lam_is``, uplink, EF21 sub/quantise/add, participation select, server
mean, ``lam_s_new``), each re-reading the full ``(m, params)`` state from
HBM.  On the arena the same math becomes three fused kernels:

  * ``round_tail_pallas``   -- lam_is = rho (x_s - x_ref) - lam_s  and the
                               uplink u = x_ref - lam_is / rho in ONE pass:
                               3 reads + 2 writes instead of ~4 passes.
  * ``ef21_*``              -- EF21 quantise-delta in TWO passes: a rowwise
                               max-abs reduction (the only full read of
                               u/u_hat) + the quantise-dequantise-integrate
                               apply, instead of the tree_sub -> per-leaf
                               _qdq (2 passes) -> tree_add chain.
  * ``fused_update_arena_pallas`` -- the eq. (20) inner step over the whole
                               packed buffer with the server row broadcast
                               in-kernel, so the K-step scan issues ONE
                               pallas_call per step instead of one per leaf.

All kernels tile a client row as ``(rows = width // 128, 128)``; the arena
pads every leaf to a 128-lane multiple, so tiles never straddle leaves and
the EF21 per-(client, leaf) quantisation scale is a static row-segment
reduction (same semantics as the per-leaf pytree path).

Server-row operands use a broadcast index map (block ``(j,)`` for every
client ``i``) -- the (m, width) broadcast is never materialised in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_update import (
    BLOCK_ROWS, LANES, assert_vmem_budget, ceil_to as _ceil_to, eq20,
)


def _tile(arr, block: int):
    """(m, width) or (width,) -> (..., rows_p, LANES) with rows_p a multiple
    of ``block`` (zero-padded)."""
    w = arr.shape[-1]
    assert w % LANES == 0, f"arena width {w} not a multiple of {LANES}"
    rows = w // LANES
    rows_p = _ceil_to(rows, block)
    t = arr.reshape(arr.shape[:-1] + (rows, LANES))
    if rows_p != rows:
        pad = [(0, 0)] * (t.ndim - 2) + [(0, rows_p - rows), (0, 0)]
        t = jnp.pad(t, pad)
    return t, rows, rows_p


def _untile(t, width: int, lead):
    return t.reshape(lead + (-1,))[..., :width]


def _resolve_block(block, rows: int) -> int:
    block = block or BLOCK_ROWS
    # clamp to the (8-sublane-aligned) problem size so small paper-scale
    # problems don't pad a 1-row state out to a full default block
    return min(block, max(8, _ceil_to(rows, 8)))


# ---------------------------------------------------------------------------
# (a) lam_is + uplink in one pass
# ---------------------------------------------------------------------------

def _round_tail_kernel(xr_ref, lam_ref, xs_ref, lam_is_ref, up_ref, *, rho: float):
    xr = xr_ref[0].astype(jnp.float32)
    lam = lam_ref[0].astype(jnp.float32)
    xs = xs_ref[...].astype(jnp.float32)
    lam_is = rho * (xs - xr) - lam
    lam_is_ref[0] = lam_is.astype(lam_is_ref.dtype)
    up_ref[0] = (xr - lam_is / rho).astype(up_ref.dtype)


def _uplink_kernel(xr_ref, lam_ref, xs_ref, up_ref, *, rho: float):
    # uplink only (lam_is algebraically eliminated): u = 2 x_ref - x_s + lam/rho
    xr = xr_ref[0].astype(jnp.float32)
    lam = lam_ref[0].astype(jnp.float32)
    xs = xs_ref[...].astype(jnp.float32)
    up_ref[0] = (xr - (rho * (xs - xr) - lam) / rho).astype(up_ref.dtype)


def round_tail_pallas(x_ref, lam_s, x_s, rho, *, with_lam_is: bool = True,
                      block=None, interpret: bool = False):
    """x_ref, lam_s: (m, width); x_s: (width,) server row.  Returns
    (lam_is, uplink), both (m, width).  ``with_lam_is=False`` (the training
    hot path: both callers discard lam_is outside traces) skips the second
    output entirely -- 3 reads + 1 write -- and returns (None, uplink)."""
    m, w = x_ref.shape
    dtype = x_ref.dtype
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(5 if with_lam_is else 4, br)
    xt, _, rows_p = _tile(x_ref, br)
    lt, _, _ = _tile(lam_s, br)
    st, _, _ = _tile(x_s, br)
    grid = (m, rows_p // br)
    client_bs = pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0))
    server_bs = pl.BlockSpec((br, LANES), lambda i, j: (j, 0))
    out_sds = jax.ShapeDtypeStruct((m, rows_p, LANES), dtype)
    if not with_lam_is:
        up = pl.pallas_call(
            functools.partial(_uplink_kernel, rho=float(rho)),
            grid=grid,
            in_specs=[client_bs, client_bs, server_bs],
            out_specs=client_bs,
            out_shape=out_sds,
            interpret=interpret,
        )(xt, lt, st)
        return None, _untile(up, w, (m,))
    lam_is, up = pl.pallas_call(
        functools.partial(_round_tail_kernel, rho=float(rho)),
        grid=grid,
        in_specs=[client_bs, client_bs, server_bs],
        out_specs=(client_bs, client_bs),
        out_shape=(out_sds, out_sds),
        interpret=interpret,
    )(xt, lt, st)
    return _untile(lam_is, w, (m,)), _untile(up, w, (m,))


# ---------------------------------------------------------------------------
# SCAFFOLD control-variate refresh (the per-client half of the round tail;
# the two server all-reduces stay jnp means -- they ARE the collectives)
# ---------------------------------------------------------------------------

def _scaffold_cv_kernel(ci_ref, xk_ref, c_ref, xs_ref, o_ref, *, alpha: float):
    f32 = jnp.float32
    ci = ci_ref[0].astype(f32)
    xk = xk_ref[0].astype(f32)
    c = c_ref[...].astype(f32)
    xs = xs_ref[...].astype(f32)
    o_ref[0] = (ci - c + alpha * (xs - xk)).astype(o_ref.dtype)


def _scaffold_cv_kernel_valpha(ci_ref, xk_ref, c_ref, xs_ref, a_ref, o_ref):
    # per-client alpha = 1/(K eta_i) loaded as a (1, LANES) row operand
    # (core.autotune's per-client stepsizes)
    f32 = jnp.float32
    ci = ci_ref[0].astype(f32)
    xk = xk_ref[0].astype(f32)
    c = c_ref[...].astype(f32)
    xs = xs_ref[...].astype(f32)
    o_ref[0] = (ci - c + a_ref[0, 0] * (xs - xk)).astype(o_ref.dtype)


def scaffold_cv_pallas(c_i, x_K, c_s, x_s, alpha, *, block=None, interpret: bool = False):
    """SCAFFOLD eq. (30) control-variate update in ONE pass:

        c_i' = c_i - c + (x_s - x_K) * alpha        (alpha = 1/(K eta))

    c_i, x_K: (m, width) client buffers; c_s, x_s: (width,) server rows
    (broadcast in-kernel, never materialised at (m, width)).  ``alpha``:
    scalar (baked constant) or (m,) per-client values (auto-eta) riding a
    broadcast row operand.  2 client reads + 1 write instead of the ~5-pass
    per-leaf tmap chain."""
    m, w = c_i.shape
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(5, br)
    ct, _, rows_p = _tile(c_i, br)
    xt, _, _ = _tile(x_K, br)
    cst, _, _ = _tile(c_s, br)
    st, _, _ = _tile(x_s, br)
    client_bs = pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0))
    server_bs = pl.BlockSpec((br, LANES), lambda i, j: (j, 0))
    args = [ct, xt, cst, st]
    in_specs = [client_bs, client_bs, server_bs, server_bs]
    if jnp.ndim(alpha) > 0:
        assert alpha.shape == (m,), alpha.shape
        args.append(jnp.broadcast_to(
            alpha.astype(jnp.float32)[:, None], (m, LANES)))
        in_specs.append(pl.BlockSpec((1, LANES), lambda i, j: (i, 0)))
        kernel = _scaffold_cv_kernel_valpha
    else:
        kernel = functools.partial(_scaffold_cv_kernel, alpha=float(alpha))
    out = pl.pallas_call(
        kernel,
        grid=(m, rows_p // br),
        in_specs=in_specs,
        out_specs=client_bs,
        out_shape=jax.ShapeDtypeStruct((m, rows_p, LANES), c_i.dtype),
        interpret=interpret,
    )(*args)
    return _untile(out, w, (m,))


# ---------------------------------------------------------------------------
# lam_s' = rho (u - x_s') -- the post-all-reduce dual refresh
# ---------------------------------------------------------------------------

def _dual_kernel(u_ref, xs_ref, o_ref, *, rho: float):
    u = u_ref[0].astype(jnp.float32)
    xs = xs_ref[...].astype(jnp.float32)
    o_ref[0] = (rho * (u - xs)).astype(o_ref.dtype)


def dual_from_uplink_pallas(uplink, x_s, rho, *, block=None, interpret: bool = False):
    """uplink: (m, width); x_s: (width,).  Returns lam_s' = rho (u - x_s)."""
    m, w = uplink.shape
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(3, br)
    ut, _, rows_p = _tile(uplink, br)
    st, _, _ = _tile(x_s, br)
    out = pl.pallas_call(
        functools.partial(_dual_kernel, rho=float(rho)),
        grid=(m, rows_p // br),
        in_specs=[
            pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((br, LANES), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, rows_p, LANES), uplink.dtype),
        interpret=interpret,
    )(ut, st)
    return _untile(out, w, (m,))


# ---------------------------------------------------------------------------
# (b) fused EF21: rowwise max-abs reduce + quantise-dequantise-integrate
# ---------------------------------------------------------------------------

def _rowmax_kernel(u_ref, uh_ref, o_ref):
    d = u_ref[0].astype(jnp.float32) - uh_ref[0].astype(jnp.float32)
    o_ref[0] = jnp.max(jnp.abs(d), axis=-1)


def ef21_rowmax_pallas(u, u_hat, *, block=None, interpret: bool = False):
    """Per-(client, 128-lane row) max-abs of (u - u_hat): (m, rows) f32.
    The only full-size read of the reduction pass."""
    m, w = u.shape
    rows = w // LANES
    br = _resolve_block(block, rows)
    assert_vmem_budget(2, br)
    ut, _, rows_p = _tile(u, br)
    ht, _, _ = _tile(u_hat, br)
    out = pl.pallas_call(
        _rowmax_kernel,
        grid=(m, rows_p // br),
        in_specs=[
            pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, rows_p), jnp.float32),
        interpret=interpret,
    )(ut, ht)
    return out[:, :rows]


def _qdq_kernel(u_ref, uh_ref, scale_ref, o_ref, *, lo: float):
    u = u_ref[0].astype(jnp.float32)
    uh = uh_ref[0].astype(jnp.float32)
    s = scale_ref[0][:, None]  # (br, 1) broadcast over lanes
    q = jnp.clip(jnp.round((u - uh) / s), -lo, lo)
    o_ref[0] = (uh + q * s).astype(o_ref.dtype)


def ef21_apply_pallas(u, u_hat, row_scales, bits: int, *, block=None, interpret: bool = False):
    """Integrated server view u_hat' = u_hat + qdq(u - u_hat) in one pass.
    ``row_scales``: (m, rows) f32 per-128-lane-row scale (already max/lo,
    clamped), expanded from the per-leaf segment maxima."""
    m, w = u.shape
    rows = w // LANES
    br = _resolve_block(block, rows)
    assert_vmem_budget(4, br)
    lo = float(2 ** (bits - 1) - 1)
    ut, _, rows_p = _tile(u, br)
    ht, _, _ = _tile(u_hat, br)
    st = row_scales
    if rows_p != rows:
        st = jnp.pad(st, ((0, 0), (0, rows_p - rows)), constant_values=1.0)
    out = pl.pallas_call(
        functools.partial(_qdq_kernel, lo=lo),
        grid=(m, rows_p // br),
        in_specs=[
            pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, br), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, rows_p, LANES), u.dtype),
        interpret=interpret,
    )(ut, ht, st)
    return _untile(out, w, (m,))


# ---------------------------------------------------------------------------
# (c) arena-wide eq. (20) inner step with in-kernel server-row broadcast
# ---------------------------------------------------------------------------

def _update_kernel(x_ref, g_ref, xs_ref, lam_ref, o_ref, *, step: float, rho: float):
    f32 = jnp.float32
    out = eq20(x_ref[0].astype(f32), g_ref[0].astype(f32),
               xs_ref[...].astype(f32), lam_ref[0].astype(f32), step, rho)
    o_ref[0] = out.astype(o_ref.dtype)


def _update_kernel_nolam(x_ref, g_ref, xs_ref, o_ref, *, step: float, rho: float):
    # lam-free variant (SCAFFOLD/FedAvg, rho = 0 plain SGD steps): one fewer
    # full (m, width) HBM read per inner step
    f32 = jnp.float32
    out = eq20(x_ref[0].astype(f32), g_ref[0].astype(f32),
               xs_ref[...].astype(f32), None, step, rho)
    o_ref[0] = out.astype(o_ref.dtype)


def _update_kernel_vstep(x_ref, g_ref, xs_ref, lam_ref, step_ref, o_ref, *, rho: float):
    # per-client stepsize loaded as a (1, LANES) row operand (core.autotune)
    f32 = jnp.float32
    out = eq20(x_ref[0].astype(f32), g_ref[0].astype(f32),
               xs_ref[...].astype(f32), lam_ref[0].astype(f32),
               step_ref[0, 0], rho)
    o_ref[0] = out.astype(o_ref.dtype)


def _update_kernel_nolam_vstep(x_ref, g_ref, xs_ref, step_ref, o_ref, *, rho: float):
    f32 = jnp.float32
    out = eq20(x_ref[0].astype(f32), g_ref[0].astype(f32),
               xs_ref[...].astype(f32), None, step_ref[0, 0], rho)
    o_ref[0] = out.astype(o_ref.dtype)


def fused_update_arena_pallas(x, g, x_s, lam, step, rho, *, block=None, interpret: bool = False):
    """x, g: (m, width); x_s: (width,) server row (broadcast in-kernel);
    lam: (m, width) or None (dual term dropped).  ``step``: scalar (baked as
    a compile-time constant -- the pre-auto-eta path, bitwise unchanged) or
    (m,) per-client stepsizes riding a broadcast row operand
    (core.autotune).  One pallas_call over the whole packed buffer."""
    m, w = x.shape
    br = _resolve_block(block, w // LANES)
    assert_vmem_budget(4 if lam is None else 5, br)
    xt, _, rows_p = _tile(x, br)
    gt, _, _ = _tile(g, br)
    st, _, _ = _tile(x_s, br)
    client_bs = pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0))
    server_bs = pl.BlockSpec((br, LANES), lambda i, j: (j, 0))
    args, in_specs = [xt, gt, st], [client_bs, client_bs, server_bs]
    if lam is not None:
        lt, _, _ = _tile(lam, br)
        args.append(lt)
        in_specs.append(client_bs)
    if jnp.ndim(step) > 0:
        assert step.shape == (m,), step.shape
        args.append(jnp.broadcast_to(
            step.astype(jnp.float32)[:, None], (m, LANES)))
        in_specs.append(pl.BlockSpec((1, LANES), lambda i, j: (i, 0)))
        kernel = functools.partial(
            _update_kernel_nolam_vstep if lam is None else _update_kernel_vstep,
            rho=float(rho))
    else:
        kernel = functools.partial(
            _update_kernel_nolam if lam is None else _update_kernel,
            step=float(step), rho=float(rho))
    out = pl.pallas_call(
        kernel,
        grid=(m, rows_p // br),
        in_specs=in_specs,
        out_specs=client_bs,
        out_shape=jax.ShapeDtypeStruct((m, rows_p, LANES), x.dtype),
        interpret=interpret,
    )(*args)
    return _untile(out, w, (m,))
