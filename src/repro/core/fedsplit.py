"""FedSplit (Pathak & Wainwright 2020), eqs. (16)-(17), and Inexact FedSplit,
eqs. (18)-(19) -- including the IMPROPER client initialisation
x_i^{r,0} = z_{s|i}^r whose failure the paper diagnoses (Fig. 1).

Exact iterates (prox oracle):
    x_i^{r+1}    = prox_{gamma f_i}(z_{s|i}^r)
                 = argmin_x f_i(x) + 1/(2 gamma) ||x - z_{s|i}^r||^2
    z_{i|s}^{r+1} = 2 x_i^{r+1} - z_{s|i}^r
    x_s^{r+1}    = mean_i z_{i|s}^{r+1}
    z_{s|i}^{r+1} = 2 x_s^{r+1} - z_{i|s}^{r+1}

Inexact iterates: K gradient steps on h_i^r(x) = f_i(x) + ||x - z||^2/(2 gamma)
starting (faithfully, badly) from z_{s|i}^r; ``fedsplit_init="xs"`` switches to
the x_s^r init, under which the method converges (Fig. 1's dashed curves).

PDMM == FedSplit on the star graph (paper SSIII-B): with rho = 1/gamma and
z_{s|i} = x_s - gamma lam_{s|i}, the exact iterates coincide with
``core.pdmm``; ``tests/test_core.py`` asserts the trajectory identity.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core import arena
from repro.core import tree_util as T
from repro.core.api import FedOpt, arena_grad, resolved_rho, use_arena
from repro.kernels import ops


def _gamma(cfg: FederatedConfig) -> float:
    return cfg.gamma if cfg.gamma is not None else 1.0 / resolved_rho(cfg)


# ---------------------------------------------------------------------------
# exact (prox oracle)
# ---------------------------------------------------------------------------

def _round_exact(cfg: FederatedConfig, state, prox_fn, batch=None, per_step_batches=False):
    """prox_fn(z_i, rho) vmapped over clients: argmin f_i + rho/2 ||x - z||^2."""
    del batch, per_step_batches
    gamma = _gamma(cfg)
    z_s = state["z_s"]  # stacked (m, ...)
    x_i = prox_fn(z_s, 1.0 / gamma)  # prox_fn maps the stacked client dim itself
    z_is = T.tmap(lambda x, z: 2.0 * x - z, x_i, z_s)
    x_s = T.tree_client_mean(z_is)
    m = jax.tree.leaves(z_s)[0].shape[0]
    z_s_new = T.tmap(lambda s, z: 2.0 * s - z, T.tree_broadcast(x_s, m), z_is)
    new_state = {"x_s": x_s, "z_s": z_s_new, "round": state["round"] + 1}
    return new_state, {}


def make_exact(cfg: FederatedConfig) -> FedOpt:
    def init(params, m):
        return {
            "x_s": params,
            "z_s": T.tree_broadcast(params, m),  # z_{s|i}^0 = x_s^0 (lam = 0)
            "round": jnp.zeros((), jnp.int32),
        }

    return FedOpt(
        name="fedsplit_exact",
        init=init,
        round=partial(_round_exact, cfg),
        server_params=lambda s: s["x_s"],
    )


# ---------------------------------------------------------------------------
# inexact (K gradient steps, paper eq. (18))
# ---------------------------------------------------------------------------

def _round_inexact_arena(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches):
    """Inexact FedSplit over the flat arena: the K gradient steps and the
    reflect/average/reflect tail run on one (m, width) buffer per state
    tensor instead of per-leaf tree.map chains.  The gradient resolves via
    the ``core.api`` oracle protocol -- arena-native oracles evaluate on the
    packed buffer directly (0 boundary passes per step)."""
    gamma = _gamma(cfg)
    K, eta = cfg.inner_steps, cfg.eta
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    z = state["z_s"]  # arena-resident (m, width)
    m = z.shape[0]
    x_s_row = spec.pack(state["x_s"])
    grad_a, _native = arena_grad(grad_fn, spec)

    if cfg.fedsplit_init == "z":
        x0 = z  # the paper's diagnosed improper init
    elif cfg.fedsplit_init == "xs":
        x0 = jnp.broadcast_to(x_s_row[None], z.shape)
    else:
        raise ValueError(cfg.fedsplit_init)

    def one_step(x, xs_k):
        b = xs_k if per_step_batches else batch
        g = grad_a(x, b)
        # grad h = grad f + (x - z)/gamma: lam-free fused step, rho = 1/gamma
        return ops.fused_update(x, g, z, None, eta, 1.0 / gamma), None

    if per_step_batches:
        x_K, _ = jax.lax.scan(one_step, x0, batch)
    else:
        x_K, _ = jax.lax.scan(one_step, x0, None, length=K)

    z_is = 2.0 * x_K - z
    x_s_new = jnp.mean(z_is, axis=0)
    z_s_new = 2.0 * x_s_new[None] - z_is
    new_state = {
        "x_s": spec.unpack(x_s_new),
        "z_s": z_s_new,
        "round": state["round"] + 1,
    }
    drift = jnp.sum(jnp.square((x_K - x_s_row[None]).astype(jnp.float32)), axis=1)
    return new_state, {"client_drift": jnp.mean(drift),
                       "used_arena": jnp.ones((), jnp.float32)}


def _round_inexact(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches=False):
    if use_arena(cfg, state["x_s"]):
        return _round_inexact_arena(cfg, state, grad_fn, batch, per_step_batches)
    gamma = _gamma(cfg)
    K, eta = cfg.inner_steps, cfg.eta
    z_s, x_s = state["z_s"], state["x_s"]
    m = jax.tree.leaves(z_s)[0].shape[0]
    vgrad = jax.vmap(grad_fn)

    if cfg.fedsplit_init == "z":
        x0 = z_s  # the paper's diagnosed improper init
    elif cfg.fedsplit_init == "xs":
        x0 = T.tree_broadcast(x_s, m)
    else:
        raise ValueError(cfg.fedsplit_init)

    def one_step(x, xs_k):
        b = xs_k if per_step_batches else batch
        g = vgrad(x, b)
        # grad h = grad f + (x - z)/gamma: lam-free fused step, rho = 1/gamma
        x_new = T.tmap(
            lambda xx, gg, zz: ops.fused_update(xx, gg, zz, None, eta, 1.0 / gamma),
            x, g, z_s,
        )
        return x_new, None

    if per_step_batches:
        x_K, _ = jax.lax.scan(one_step, x0, batch)
    else:
        x_K, _ = jax.lax.scan(one_step, x0, None, length=K)

    z_is = T.tmap(lambda x, z: 2.0 * x - z, x_K, z_s)
    x_s_new = T.tree_client_mean(z_is)
    z_s_new = T.tmap(lambda s, z: 2.0 * s - z, T.tree_broadcast(x_s_new, m), z_is)
    new_state = {"x_s": x_s_new, "z_s": z_s_new, "round": state["round"] + 1}
    metrics = {
        "client_drift": jnp.mean(T.tree_client_sqnorms(T.tree_sub(x_K, T.tree_broadcast(x_s, m)))),
        "used_arena": jnp.zeros((), jnp.float32),
    }
    return new_state, metrics


def make_inexact(cfg: FederatedConfig) -> FedOpt:
    def init(params, m):
        if use_arena(cfg, params):
            spec = arena.ArenaSpec.from_tree(params)
            row = spec.pack(params)
            return {
                "x_s": params,
                "z_s": jnp.broadcast_to(row[None], (m, spec.width)),
                "round": jnp.zeros((), jnp.int32),
            }
        return {
            "x_s": params,
            "z_s": T.tree_broadcast(params, m),
            "round": jnp.zeros((), jnp.int32),
        }

    return FedOpt(
        name=f"fedsplit_inexact[{cfg.fedsplit_init}]",
        init=init,
        round=partial(_round_inexact, cfg),
        server_params=lambda s: s["x_s"],
    )
