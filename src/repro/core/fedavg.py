"""FedAvg (McMahan et al. 2017) -- the weakest baseline in the paper's
experiments: plain local SGD + parameter averaging, no dual/control state, so
it drifts under client heterogeneity when K > 1 (paper Fig. 2).

Arena fast path (``core.arena``): the K local-SGD steps share SCAFFOLD's
offset inner loop with the correction disabled -- affine oracles run the
WHOLE loop as one fused K-step kernel (lam-free, rho = 0), arena-native
oracles scan lam-free fused arena updates -- and the round tail is the
single uplink mean.  Plain FedAvg carries NO per-client state; the EF21 /
partial-participation variants add the arena-resident ``u_hat`` server view
(same cache contract as GPDMM: silent clients' cached uplink is reused, the
EF21 integrator accumulates quantised deltas), donated in place.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig
from repro.core import arena, faults, staleness
from repro.core import tree_util as T
from repro.core.api import (
    FedOpt, cohort_batch, run_cohort_inner, use_arena, use_cohort,
)
from repro.core.gpdmm import _eta_val, _step_for, participation_key, popstore_tail
from repro.core.scaffold import inner_steps_plain_arena
from repro.kernels import ops


def popstore_body(cfg: FederatedConfig, spec, m: int, grad_fn, per_step):
    """Device half of a host-popstore FedAvg round (see gpdmm.popstore_body):
    the cohort runs the plain K-step loop from the server row; only the
    staged ``u_hat`` rows (EF21 integrator / silence fallback) move, and the
    host driver maintains the population mean incrementally."""
    K, eta = cfg.inner_steps, _eta_val(cfg.eta)
    per_client = np.ndim(eta) > 0
    f32 = jnp.float32

    def body(server, staged, idx, round_idx, batch):
        x_s_row = spec.pack(server["x_s"])
        u_hat_c = staged["u_hat"]
        batch_c = cohort_batch(batch, idx, m, per_step)

        def inner(rows, b):
            eta_t = rows[0] if per_client else eta  # tiled with the batch
            mc = jax.tree.leaves(b)[0].shape[1 if per_step else 0]
            x0 = jnp.broadcast_to(x_s_row[None], (mc, spec.width))
            return inner_steps_plain_arena(
                spec, grad_fn, x0, x_s_row, b, K=K, eta=eta_t,
                per_step=per_step,
            )

        rows = (jnp.asarray(eta)[idx],) if per_client else ()
        x_K = run_cohort_inner(cfg, inner, rows, batch_c, per_step=per_step)
        uplink, keep_c, fm = popstore_tail(cfg, spec, x_s_row, u_hat_c, x_K,
                                           idx, round_idx, m)
        metrics = {
            "client_drift": T.masked_client_mean(
                jnp.sum(jnp.square((x_K - x_s_row[None]).astype(f32)),
                        axis=1), keep_c),
            "used_arena": jnp.ones((), f32),
        } | fm
        return {"u_hat": uplink}, {}, metrics

    return body


def _num_clients(state, batch, per_step_batches):
    """Plain FedAvg keeps no per-client state, so the client count comes
    from the batch layout ((m, ...) or (K, m, ...)); the EF21/partial
    variants carry u_hat and read m off it."""
    u_hat = state.get("u_hat")
    if u_hat is not None:
        return jax.tree.leaves(u_hat)[0].shape[0]
    b0 = jax.tree.leaves(batch)[0]
    return b0.shape[1] if per_step_batches else b0.shape[0]


def _round_arena_cohort(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches):
    """FedAvg round over the sampled cohort (see gpdmm._round_arena_cohort):
    no per-client optimiser rows move at all -- the cohort runs the plain
    K-step loop from the server row, the uplink scatters into the
    arena-resident u_hat cache, and the server mean over the scattered
    buffer realises (sum_active x_K + sum_silent u_hat) / m exactly as the
    masked path's mean-of-selected-rows."""
    K, eta = cfg.inner_steps, _eta_val(cfg.eta)
    per_client = np.ndim(eta) > 0
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    u_hat = state["u_hat"]  # guaranteed: participation < 1 carries the cache
    m = u_hat.shape[0]
    x_s_row = spec.pack(state["x_s"])
    idx, _mask = T.cohort_indices(
        participation_key(cfg, state["round"]), m, cfg.participation
    )
    batch_c = cohort_batch(batch, idx, m, per_step_batches)

    def inner(rows, b):
        eta_t = rows[0] if per_client else eta  # tiled with the batch
        mc = jax.tree.leaves(b)[0].shape[1 if per_step_batches else 0]
        x0 = jnp.broadcast_to(x_s_row[None], (mc, spec.width))
        return inner_steps_plain_arena(
            spec, grad_fn, x0, x_s_row, b, K=K, eta=eta_t,
            per_step=per_step_batches,
        )

    rows = (jnp.asarray(eta)[idx],) if per_client else ()
    x_K = run_cohort_inner(cfg, inner, rows, batch_c, per_step=per_step_batches)

    uplink = x_K
    if cfg.uplink_bits is not None:  # EF21 on the cohort's cached rows only
        uplink = ops.ef21_update(uplink, ops.row_gather(u_hat, idx),
                                 cfg.uplink_bits, spec.leaf_rows())
    fplan = faults.plan(cfg, state["round"], m)
    plan_c = faults.take(fplan, idx)
    uplink = faults.inject(cfg.faults, plan_c, uplink)
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep(cfg, uplink, x_s_row)
    keep_c = faults.combine_mask(None, plan_c, keep)
    if keep_c is not None:
        uplink = jnp.where(keep_c[:, None], uplink, ops.row_gather(u_hat, idx))
    u_hat_new = ops.row_scatter(u_hat, idx, uplink)
    x_s_new = jnp.mean(u_hat_new, axis=0)  # <- the round's single all-reduce
    new_state = {
        "u_hat": u_hat_new,
        "x_s": spec.unpack(x_s_new),
        "round": state["round"] + 1,
    }
    f32 = jnp.float32
    metrics = {
        "client_drift": T.masked_client_mean(
            jnp.sum(jnp.square((x_K - x_s_row[None]).astype(f32)), axis=1),
            keep_c),
        "used_arena": jnp.ones((), f32),
    }
    if fplan is not None or keep is not None:
        metrics |= faults.fault_metrics(
            fplan, None if plan_c is None else ~plan_c.silent, keep)
    return new_state, metrics


def _round_arena(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches):
    K, eta = cfg.inner_steps, cfg.eta
    spec = arena.ArenaSpec.from_tree(state["x_s"])
    m = _num_clients(state, batch, per_step_batches)
    if use_cohort(cfg, m):
        return _round_arena_cohort(cfg, state, grad_fn, batch, per_step_batches)
    x_s_row = spec.pack(state["x_s"])
    x0 = jnp.broadcast_to(x_s_row[None], (m, spec.width))

    x_K = inner_steps_plain_arena(
        spec, grad_fn, x0, x_s_row, batch, K=K, eta=eta, per_step=per_step_batches,
    )

    uplink = x_K
    new_state = {}
    u_hat = state.get("u_hat")  # arena-resident (m, width) or absent
    if cfg.uplink_bits is not None:  # fused EF21: 2 passes instead of ~4
        uplink = ops.ef21_update(uplink, u_hat, cfg.uplink_bits, spec.leaf_rows())
    # robustness layer: inject -> participation -> screen -> combined select
    fplan = faults.plan(cfg, state["round"], m)
    uplink = faults.inject(cfg.faults, fplan, uplink)
    pmask = None
    if cfg.participation < 1.0:
        pmask = T.participation_mask(
            participation_key(cfg, state["round"]), m, cfg.participation
        )
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep(cfg, uplink, x_s_row)
    mask = faults.combine_mask(pmask, fplan, keep)
    sm = {}
    if faults.async_on(cfg):
        # bounded-staleness engine: delayed rows buffer, arrivals mix into
        # the cached server view (u_hat guaranteed: async carries the cache)
        uplink, mask, stale_up, sm = staleness.step_arena(
            cfg, fplan, uplink, u_hat, mask, state)
        new_state |= stale_up
    elif mask is not None:
        # silent clients transmit nothing; the server keeps its cached view
        uplink = jnp.where(mask[:, None], uplink, u_hat)
    if u_hat is not None:
        new_state["u_hat"] = uplink
    x_s_new = jnp.mean(uplink, axis=0)  # <- the round's single all-reduce
    new_state |= {"x_s": spec.unpack(x_s_new), "round": state["round"] + 1}
    f32 = jnp.float32
    metrics = {
        # silent clients' x_K never enters the state: average the active set
        "client_drift": T.masked_client_mean(
            jnp.sum(jnp.square((x_K - x_s_row[None]).astype(f32)), axis=1),
            mask),
        "used_arena": jnp.ones((), f32),
    }
    if fplan is not None or keep is not None:
        tx = faults.combine_mask(pmask, fplan, None)
        if faults.async_on(cfg):
            tx = staleness.fresh_mask(tx, fplan)
        metrics |= faults.fault_metrics(fplan, tx, keep) | sm
    return new_state, metrics


def _round(cfg: FederatedConfig, state, grad_fn, batch, per_step_batches=False):
    if use_arena(cfg, state["x_s"]):
        return _round_arena(cfg, state, grad_fn, batch, per_step_batches)
    K, eta = cfg.inner_steps, _eta_val(cfg.eta)
    x_s = state["x_s"]
    m = _num_clients(state, batch, per_step_batches)
    x_s_b = T.tree_broadcast(x_s, m)
    vgrad = jax.vmap(grad_fn)

    def one_step(x, xs_k):
        b = xs_k if per_step_batches else batch
        g = vgrad(x, b)
        # plain SGD step: lam-free fused update with rho = 0 (xs unused)
        x_new = T.tmap(lambda xx, gg: ops.fused_update(
            xx, gg, xx, None, _step_for(eta, xx), 0.0), x, g)
        return x_new, None

    if per_step_batches:
        x_K, _ = jax.lax.scan(one_step, x_s_b, batch)
    else:
        x_K, _ = jax.lax.scan(one_step, x_s_b, None, length=K)

    uplink = x_K
    new_state = {}
    if cfg.uplink_bits is not None:  # beyond-paper: EF21 delta-quantised uplink
        uplink = T.tree_quantize_delta(uplink, state["u_hat"], cfg.uplink_bits)
    # robustness layer: inject -> participation -> screen -> combined select
    fplan = faults.plan(cfg, state["round"], m)
    uplink = faults.inject_tree(cfg.faults, fplan, uplink)
    pmask = None
    if cfg.participation < 1.0:
        pmask = T.participation_mask(
            participation_key(cfg, state["round"]), m, cfg.participation
        )
    keep = None
    if faults.screening_on(cfg):
        keep = faults.screen_keep_tree(cfg, uplink, x_s)
    mask = faults.combine_mask(pmask, fplan, keep)
    sm = {}
    if faults.async_on(cfg):
        # bounded-staleness engine: delayed rows buffer, arrivals mix
        uplink, mask, stale_up, sm = staleness.step_tree(
            cfg, fplan, uplink, state["u_hat"], mask, state)
        new_state |= stale_up
    elif mask is not None:
        uplink = T.tree_select(mask, uplink, state["u_hat"])
    if "u_hat" in state:
        new_state["u_hat"] = uplink  # the server's per-client view
    x_s_new = T.tree_client_mean(uplink)
    new_state |= {"x_s": x_s_new, "round": state["round"] + 1}
    metrics = {
        # silent clients' x_K never enters the state: average the active set
        "client_drift": T.masked_client_mean(
            T.tree_client_sqnorms(T.tree_sub(x_K, x_s_b)), mask),
        "used_arena": jnp.zeros((), jnp.float32),
    }
    if fplan is not None or keep is not None:
        tx = faults.combine_mask(pmask, fplan, None)
        if faults.async_on(cfg):
            tx = staleness.fresh_mask(tx, fplan)
        metrics |= faults.fault_metrics(fplan, tx, keep) | sm
    return new_state, metrics


def make(cfg: FederatedConfig) -> FedOpt:
    def init(params, m):
        needs_cache = (cfg.uplink_bits is not None or cfg.participation < 1.0
                       or faults.needs_cache(cfg))
        if use_arena(cfg, params):
            st = {"x_s": params, "round": jnp.zeros((), jnp.int32)}
            spec = arena.ArenaSpec.from_tree(params)
            if needs_cache:
                row = spec.pack(params)
                # server's cached per-client view: init == the round-0 uplink
                # from a client that never moved
                st["u_hat"] = jnp.broadcast_to(row[None], (m, spec.width))
            if faults.async_on(cfg):
                st |= staleness.init_arena(spec, m)
            return st
        st = {"x_s": params, "round": jnp.zeros((), jnp.int32)}
        if needs_cache:
            st["u_hat"] = T.tree_broadcast(params, m)
        if faults.async_on(cfg):
            st |= staleness.init_tree(params, m)
        return st

    return FedOpt(
        name="fedavg",
        init=init,
        round=partial(_round, cfg),
        server_params=lambda s: s["x_s"],
    )
