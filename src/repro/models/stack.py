"""Pattern-based decoder stack.

A config declares a repeating ``block_pattern`` (e.g. ``("rec","rec","local")``
for RecurrentGemma).  Layers are grouped as:

    [lead]  first_dense_layers explicit dense blocks (DeepSeek-V2's layer 0)
    [scan]  n_scan_units repetitions of the pattern, parameters stacked with a
            leading unit dim and iterated with ``lax.scan`` (compile time and
            HLO size independent of depth)
    [tail]  the remainder (< pattern length) explicit blocks

Block kinds: dense | local | moe | rwkv | rec.
``block_apply`` returns ``(x, new_cache, aux)``; caches are pytrees stacked
along the unit dim for the scanned segment.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import rwkv6 as W


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(key, cfg: ArchConfig, kind: str, dtype, dense_override: bool = False):
    """dense_override: build the lead dense layer of an otherwise-moe config."""
    k1, k2 = jax.random.split(key)
    n1, s1 = L.norm_init(cfg.norm_kind, cfg.d_model)
    n2, s2 = L.norm_init(cfg.norm_kind, cfg.d_model)
    p: dict[str, Any] = {"ln1": n1, "ln2": n2}
    s: dict[str, Any] = {"ln1": s1, "ln2": s2}
    if kind in ("dense", "local", "moe"):
        if cfg.attn_kind == "mla":
            p["attn"], s["attn"] = A.mla_init(k1, cfg, dtype)
        else:
            p["attn"], s["attn"] = A.gqa_init(k1, cfg, dtype)
        if kind == "moe" and not dense_override:
            p["moe"], s["moe"] = M.moe_init(k2, cfg, dtype)
        else:
            p["mlp"], s["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    elif kind == "rwkv":
        p["tm"], s["tm"] = W.rwkv_init(k1, cfg, dtype)
        # rwkv_init returns both halves; split storage for clarity
        tm_p, tm_s = p.pop("tm"), s.pop("tm")
        p["core"], s["core"] = tm_p, tm_s
    elif kind == "rec":
        p["rec"], s["rec"] = R.rglru_init(k1, cfg, dtype)
        p["mlp"], s["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    else:
        raise ValueError(kind)
    return p, s


def block_apply(
    cfg: ArchConfig,
    kind: str,
    params,
    x,
    *,
    mode: str,
    cache=None,
    pos=None,
    cache_cap: int = 0,
    window_override: Optional[int] = None,
    dense_override: bool = False,
    exact_moe: bool = False,
):
    aux = jnp.zeros((), jnp.float32)
    nk = cfg.norm_kind
    if kind in ("dense", "local", "moe"):
        window = cfg.window if kind == "local" else window_override
        h = L.norm_apply(nk, params["ln1"], x)
        if cfg.attn_kind == "mla":
            a_out, new_cache = A.mla_apply(cfg, params["attn"], h, mode=mode, cache=cache, pos=pos, cache_cap=cache_cap)
        else:
            a_out, new_cache = A.gqa_apply(
                cfg, params["attn"], h, mode=mode, cache=cache, pos=pos, window=window, cache_cap=cache_cap
            )
        x = x + a_out
        h = L.norm_apply(nk, params["ln2"], x)
        if kind == "moe" and not dense_override:
            # decode is always exact (T == B tokens, full capacity is cheap and
            # drops would make cached decoding diverge).  train/prefill default
            # to capacity-factor dispatch (static shapes, Switch/MaxText
            # behaviour; full capacity at 32k-token prefill would materialise
            # (E, T, D) buffers).  ``exact_moe`` opts a whole pipeline into
            # drop-free routing, e.g. for decode-vs-forward consistency checks.
            # fused dispatch is scoped to train/decode: at prefill scale
            # (~1M tokens) the single (T*k) slot table partitions worse under
            # GSPMD and compiled FLOPs/device regress 2.1x (SSPerf H1 iter 3),
            # while train measures -47% FLOPs and decode's tiny T always wins.
            m_out, aux = M.moe_apply(
                cfg, params["moe"], h, cfg.act,
                full_capacity=(mode == "decode") or exact_moe,
                fused=cfg.moe_fused_dispatch and mode != "prefill",
            )
        else:
            m_out = L.mlp_apply(params["mlp"], h, cfg.act)
        x = x + m_out
        return x, new_cache, aux

    if kind == "rwkv":
        cp = params["core"]
        st_tm = None if cache is None else {"tm_last": cache["tm_last"], "s": cache["s"]}
        h = L.norm_apply(nk, params["ln1"], x)
        y, tm_state = W.rwkv_time_mix(cfg, cp, h, mode=mode, state=st_tm)
        x = x + y
        st_cm = None if cache is None else {"cm_last": cache["cm_last"]}
        h = L.norm_apply(nk, params["ln2"], x)
        y, cm_state = W.rwkv_channel_mix(cfg, cp, h, mode=mode, state=st_cm)
        x = x + y
        new_cache = None
        if mode != "train":
            new_cache = {**tm_state, **cm_state}
        return x, new_cache, aux

    if kind == "rec":
        h = L.norm_apply(nk, params["ln1"], x)
        y, new_cache = R.rglru_apply(cfg, params["rec"], h, mode=mode, state=cache)
        x = x + y
        h = L.norm_apply(nk, params["ln2"], x)
        x = x + L.mlp_apply(params["mlp"], h, cfg.act)
        return x, new_cache, aux

    raise ValueError(kind)


def block_cache_shape(cfg: ArchConfig, kind: str, batch: int, cap: int, dtype, window_override=None):
    if kind in ("dense", "moe"):
        if cfg.attn_kind == "mla":
            return A.mla_cache_shape(cfg, batch, cap, dtype)
        return A.gqa_cache_shape(cfg, batch, cap, window_override, dtype)
    if kind == "local":
        return A.gqa_cache_shape(cfg, batch, cap, cfg.window, dtype)
    if kind == "rwkv":
        return W.rwkv_state_shape(cfg, batch, dtype)
    if kind == "rec":
        return R.rglru_state_shape(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# layer grouping
# ---------------------------------------------------------------------------

def layer_plan(cfg: ArchConfig):
    """Returns (n_lead, n_scan_units, tail_kinds)."""
    lead = cfg.first_dense_layers
    rest = cfg.n_layers - lead
    n_units = rest // cfg.pattern_len
    tail = cfg.block_pattern[: rest % cfg.pattern_len]
    return lead, n_units, tail


def stack_init(key, cfg: ArchConfig, dtype):
    lead, n_units, tail = layer_plan(cfg)
    keys = jax.random.split(key, 3)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    if lead:
        lp, ls = [], []
        for i, kk in enumerate(jax.random.split(keys[0], lead)):
            bp, bs = block_init(kk, cfg, cfg.block_pattern[0], dtype, dense_override=True)
            lp.append(bp)
            ls.append(bs)
        p["lead"], s["lead"] = lp, ls  # lists: tuple leaves are reserved for axis specs
    if n_units:
        def unit_init(k):
            up, us = {}, {}
            for bi, kind in enumerate(cfg.block_pattern):
                bp, bs = block_init(jax.random.fold_in(k, bi), cfg, kind, dtype)
                up[f"b{bi}"] = bp
                us[f"b{bi}"] = bs
            return up, us

        unit_keys = jax.random.split(keys[1], n_units)
        stacked = jax.vmap(lambda k: unit_init(k)[0])(unit_keys)
        _, unit_specs = unit_init(unit_keys[0])
        p["units"] = stacked
        # unit params get a leading "unit" axis (never sharded)
        s["units"] = jax.tree.map(lambda sp: ("unit",) + sp, unit_specs, is_leaf=lambda t: isinstance(t, tuple))
    if tail:
        tp, ts = [], []
        for bi, kind in enumerate(tail):
            bp, bs = block_init(jax.random.fold_in(keys[2], bi), cfg, kind, dtype)
            tp.append(bp)
            ts.append(bs)
        p["tail"], s["tail"] = tp, ts
    return p, s


def stack_apply(
    cfg: ArchConfig,
    params,
    x,
    *,
    mode: str,
    cache=None,
    pos=None,
    cache_cap: int = 0,
    window_override: Optional[int] = None,
    exact_moe: bool = False,
):
    """Returns (x, new_cache, aux_sum). ``cache``/new_cache structure:
    {"lead": tuple, "units": stacked pytree, "tail": tuple} (entries omitted
    when that segment is empty)."""
    lead, n_units, tail = layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}

    ba = functools.partial(
        block_apply, cfg, mode=mode, pos=pos, cache_cap=cache_cap,
        window_override=window_override, exact_moe=exact_moe,
    )

    if lead:
        caches = []
        for i in range(lead):
            c = None if cache is None else cache["lead"][i]
            x, nc, aux = ba(cfg.block_pattern[0], params["lead"][i], x, cache=c, dense_override=True)
            caches.append(nc)
            aux_total = aux_total + aux
        if mode != "train":
            new_cache["lead"] = caches

    if n_units:
        def unit_fn(x, unit_params, unit_cache):
            ncs = {}
            aux_u = jnp.zeros((), jnp.float32)
            for bi, kind in enumerate(cfg.block_pattern):
                c = None if unit_cache is None else unit_cache[f"b{bi}"]
                x, nc, aux = ba(kind, unit_params[f"b{bi}"], x, cache=c)
                ncs[f"b{bi}"] = nc
                aux_u = aux_u + aux
            return x, ncs, aux_u

        if cfg.remat and mode == "train":
            unit_fn = jax.checkpoint(unit_fn, static_argnums=())

        if not cfg.scan_layers:
            # unrolled path: used by the roofline probes (XLA cost analysis
            # counts a scan body once, so depth extrapolation needs unrolling)
            unit_caches = []
            aux_sum = jnp.zeros((), jnp.float32)
            for ui in range(n_units):
                up = jax.tree.map(lambda t: t[ui], params["units"])
                uc = None if cache is None else jax.tree.map(lambda t: t[ui], cache["units"])
                x, nc, aux = unit_fn(x, up, uc)
                unit_caches.append(nc)
                aux_sum = aux_sum + aux
            if mode != "train":
                new_cache["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *unit_caches)
            aux_total = aux_total + aux_sum
        elif mode == "train":
            def scan_body(c, up):
                y, _, aux = unit_fn(c, up, None)
                return y, aux
            x, auxs = jax.lax.scan(scan_body, x, params["units"])
        elif mode == "prefill":
            def scan_body(c, up):
                y, nc, aux = unit_fn(c, up, None)
                return y, (nc, aux)
            x, (unit_caches, auxs) = jax.lax.scan(scan_body, x, params["units"])
            new_cache["units"] = unit_caches
        else:  # decode
            def scan_body(c, inp):
                up, uc = inp
                y, nc, aux = unit_fn(c, up, uc)
                return y, (nc, aux)
            x, (unit_caches, auxs) = jax.lax.scan(scan_body, x, (params["units"], cache["units"]))
            new_cache["units"] = unit_caches
        if cfg.scan_layers:
            aux_total = aux_total + jnp.sum(auxs)

    if tail:
        caches = []
        for bi, kind in enumerate(tail):
            c = None if cache is None else cache["tail"][bi]
            x, nc, aux = ba(kind, params["tail"][bi], x, cache=c)
            caches.append(nc)
            aux_total = aux_total + aux
        if mode != "train":
            new_cache["tail"] = caches

    return x, (new_cache if mode != "train" else None), aux_total


def stack_cache_shapes(cfg: ArchConfig, batch: int, cap: int, dtype, window_override=None):
    """ShapeDtypeStruct pytree matching stack_apply's cache layout."""
    lead, n_units, tail = layer_plan(cfg)
    out: dict[str, Any] = {}
    bc = lambda kind: block_cache_shape(cfg, kind, batch, cap, dtype, window_override)  # noqa: E731
    if lead:
        out["lead"] = [bc(cfg.block_pattern[0]) for _ in range(lead)]
    if n_units:
        unit = {f"b{bi}": bc(kind) for bi, kind in enumerate(cfg.block_pattern)}
        out["units"] = jax.tree.map(
            lambda sds: jax.ShapeDtypeStruct((n_units,) + sds.shape, sds.dtype), unit
        )
    if tail:
        out["tail"] = [bc(kind) for kind in tail]
    return out


def block_cache_spec(cfg: ArchConfig, kind: str, window_override=None):
    """Logical-axis tuples matching block_cache_shape (for sharding)."""
    if kind in ("dense", "moe"):
        if cfg.attn_kind == "mla":
            return A.mla_cache_spec()
        return A.gqa_cache_spec(window_override)
    if kind == "local":
        return A.gqa_cache_spec(cfg.window)
    if kind == "rwkv":
        return W.rwkv_state_spec()
    if kind == "rec":
        return R.rglru_state_spec()
    raise ValueError(kind)


def stack_cache_specs(cfg: ArchConfig, window_override=None):
    """Logical-axis pytree matching stack_cache_shapes (unit dim prefixed)."""
    lead, n_units, tail = layer_plan(cfg)
    out: dict[str, Any] = {}
    bs = lambda kind: block_cache_spec(cfg, kind, window_override)  # noqa: E731
    if lead:
        out["lead"] = [bs(cfg.block_pattern[0]) for _ in range(lead)]
    if n_units:
        unit = {f"b{bi}": bs(kind) for bi, kind in enumerate(cfg.block_pattern)}
        out["units"] = jax.tree.map(
            lambda sp: ("unit",) + sp, unit, is_leaf=lambda t: isinstance(t, tuple)
        )
    if tail:
        out["tail"] = [bs(kind) for kind in tail]
    return out
